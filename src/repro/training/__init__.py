"""Training substrate: optimizer, trainer, checkpointing, data pipeline."""
from repro.training.optimizer import OptConfig, apply_updates, init_state
from repro.training.trainer import TrainConfig, Trainer, make_train_step
