"""Training loop: QAT/fp train_step, microbatch accumulation, pjit wiring,
checkpoint/restart, and the paper's Sec.-4 fine-tuning recipe.

``make_train_step`` builds the pure step; ``Trainer`` adds the operational
shell (sharded jit, periodic atomic checkpoints, resume, failure recovery).
Gradient accumulation runs as a lax.scan over microbatches -- on the
production mesh the per-microbatch gradient all-reduce is deferred to the
end by summing local grads first (XLA folds this into one reduction).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.training import checkpoint as ckpt_lib
from repro.training import optimizer as opt_lib


@dataclasses.dataclass
class TrainConfig:
    opt: opt_lib.OptConfig = opt_lib.OptConfig()
    microbatches: int = 1  # gradient accumulation factor
    accum_dtype: str = "float32"  # bf16 halves accumulator HBM traffic
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3


def make_train_step(loss_fn: Callable, tcfg: TrainConfig) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``loss_fn(params, batch) -> scalar``.  With microbatches > 1 the batch's
    leading axis is split and gradients are accumulated in f32.
    """

    def step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            bsz = batch["tokens"].shape[0] if "tokens" in batch else (
                jax.tree.leaves(batch)[0].shape[0]
            )

            def split(x):
                mb = tcfg.microbatches
                if x.shape[0] == bsz:  # standard (B, ...) input
                    return x.reshape(mb, bsz // mb, *x.shape[1:])
                if x.ndim >= 2 and x.shape[1] == bsz:  # e.g. mrope (3, B, S)
                    y = x.reshape(x.shape[0], mb, bsz // mb, *x.shape[2:])
                    return jnp.moveaxis(y, 1, 0)
                raise ValueError(f"cannot microbatch leaf of shape {x.shape}")

            micro = jax.tree.map(split, batch)

            acc_dt = jnp.dtype(tcfg.accum_dtype)

            def body(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grads_acc = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dt), grads_acc, grads
                )
                return (loss_acc + loss, grads_acc), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params
            )
            (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero), micro)
            loss = loss / tcfg.microbatches
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        new_params, new_opt, metrics = opt_lib.apply_updates(
            params, grads, opt_state, tcfg.opt
        )
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return step


class Trainer:
    """Operational shell: jit/pjit, checkpoints, restart-from-failure."""

    def __init__(
        self,
        loss_fn: Callable,
        params: Any,
        tcfg: TrainConfig,
        mesh=None,
        param_shardings=None,
        batch_shardings_fn: Optional[Callable] = None,
        plan=None,  # compiled repro.quant.QuantPlan (QAT runs under one)
        quant_state=None,  # repro.quant.QuantState (TTQ/INQ schedule record)
    ):
        self.tcfg = tcfg
        self.mesh = mesh
        self.plan = plan
        self.quant_state = quant_state
        # own the param buffers: the jitted step donates its inputs, so a
        # caller-shared pytree must not be destroyed under the caller
        self.params = jax.tree.map(jnp.array, params)
        self.opt_state = opt_lib.init_state(params, tcfg.opt)
        self.step_count = 0
        self.sync_count = 0  # host syncs issued by train() (metrics flushes)
        self._param_shardings = param_shardings
        if mesh is not None and param_shardings is not None:
            # place the params per the declared shardings and pin them as
            # the step's in/out shardings; opt state and metrics are left
            # for XLA to lay out consistently with the params it sees
            full_sh = self._aligned_shardings()
            self.params = jax.device_put(self.params, full_sh)
            self._jit_kwargs = dict(
                donate_argnums=(0, 1),
                in_shardings=(full_sh, None, None),
                out_shardings=(full_sh, None, None),
            )
        else:
            self._jit_kwargs = dict(donate_argnums=(0, 1))
        self._step = jax.jit(make_train_step(loss_fn, tcfg), **self._jit_kwargs)
        self._batch_shardings_fn = batch_shardings_fn

    def _aligned_shardings(self):
        """``param_shardings`` aligned leaf-by-leaf to ``self.params``: any
        leaf the caller's sharding tree does not cover (e.g. injected
        quantization-state leaves) is replicated."""
        from jax.sharding import NamedSharding, PartitionSpec

        flat = jax.tree_util.tree_flatten_with_path(self._param_shardings)[0]
        by_path = {kp: s for kp, s in flat}
        rep = NamedSharding(self.mesh, PartitionSpec())

        def pick(kp, leaf):
            return by_path.get(kp, rep)

        return jax.tree_util.tree_map_with_path(pick, self.params)

    def maybe_restore(self) -> int:
        """Resume from the newest intact checkpoint, plan included.

        A QAT run's compiled ``QuantPlan`` (with calibrated activation
        exponents) rides in the checkpoint manifest; it is surfaced on
        ``self.plan`` so later checkpoints keep carrying it AND so the
        caller can rebind its loss to the checkpointed precision table
        (``rebind_loss`` -- the loss closure given to ``__init__`` was
        built against a freshly compiled plan, which may differ)."""
        if not self.tcfg.ckpt_dir:
            return 0
        template = {"params": self.params, "opt": self.opt_state}
        step, manifest = ckpt_lib.latest_intact(self.tcfg.ckpt_dir)
        if step is not None:
            tree = ckpt_lib.restore(
                self.tcfg.ckpt_dir, step, template, manifest=manifest
            )
            self.params, self.opt_state = tree["params"], tree["opt"]
            self.step_count = step
            restored_plan = ckpt_lib.load_plan(
                ckpt_lib.step_dir(self.tcfg.ckpt_dir, step), manifest=manifest
            )
            if restored_plan is not None:
                self.plan = restored_plan
            qs_meta = ckpt_lib.load_quant_state(
                ckpt_lib.step_dir(self.tcfg.ckpt_dir, step), manifest=manifest
            )
            if qs_meta is not None:
                from repro.quant.state import QuantState

                self.quant_state = QuantState.from_meta(qs_meta)
        return self.step_count

    def rebind_loss(self, loss_fn: Callable) -> None:
        """Rebuild the jitted step around a new loss closure (e.g. one bound
        to the plan ``maybe_restore`` recovered from the checkpoint)."""
        self._step = jax.jit(
            make_train_step(loss_fn, self.tcfg), **self._jit_kwargs
        )

    def _maybe_advance_quant(self, i: int) -> None:
        """Fire any INQ schedule events due at step ``i`` (before the step
        runs): grow the frozen partition, snap it onto the current learned
        grid, and advance the resume cursor.  TTQ needs no schedule -- its
        scales train every step."""
        qs = self.quant_state
        if qs is None or qs.method != "inq" or self.plan is None:
            return
        from repro.quant import state as state_lib

        events = state_lib.inq_event_steps(qs.total_steps, qs.fractions)
        pos = qs.pos
        while pos < len(events) and i >= events[pos]:
            self.params = state_lib.advance_inq(
                self.params, self.plan, qs.fractions[pos]
            )
            pos += 1
        if pos != qs.pos:
            self.quant_state = dataclasses.replace(qs, pos=pos)

    def _save_ckpt(self, step: int) -> None:
        ckpt_lib.save(
            self.tcfg.ckpt_dir,
            step,
            {"params": self.params, "opt": self.opt_state},
            plan=self.plan,
            quant_state=(
                self.quant_state.to_meta() if self.quant_state is not None
                else None
            ),
        )
        ckpt_lib.retain(self.tcfg.ckpt_dir, self.tcfg.keep)

    def train(
        self, batch_fn: Callable[[int], Any], num_steps: int
    ) -> Dict[str, list]:
        history: Dict[str, list] = {"loss": [], "step": [], "wall": []}
        t0 = time.time()
        pending: list = []  # (step idx, on-device metrics) awaiting one sync

        def flush():
            # ONE host transfer for the whole pending window -- the loop
            # itself never blocks on a per-step float() materialization
            if not pending:
                return
            vals = jax.device_get([m for _, m in pending])
            self.sync_count += 1
            wall = time.time() - t0
            for (idx, _), m in zip(pending, vals):
                history["loss"].append(float(m["loss"]))
                history["step"].append(idx)
                history["wall"].append(wall)
            pending.clear()

        for i in range(self.step_count, self.step_count + num_steps):
            self._maybe_advance_quant(i)
            batch = batch_fn(i)
            if self._batch_shardings_fn is not None:
                batch = jax.device_put(batch, self._batch_shardings_fn(batch))
            self.params, self.opt_state, metrics = self._step(
                self.params, self.opt_state, batch
            )
            pending.append((i, metrics))
            if (
                self.tcfg.ckpt_dir
                and (i + 1) % self.tcfg.ckpt_every == 0
            ):
                flush()
                self._save_ckpt(i + 1)
        flush()
        self.step_count += num_steps
        return history
