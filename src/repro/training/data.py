"""Synthetic-but-deterministic data pipeline.

Counter-based: batch(step) is a pure function of (seed, step, arch), so
 * every data-parallel rank can rebuild its shard independently,
 * restart-after-failure resumes mid-epoch from the step counter alone
   (the checkpoint stores just `step`), and
 * hosts need no coordination or shared filesystem.

The generator emits a Zipf-ish token distribution with induced sequential
structure (next-token = f(prev) + noise) so that cross-entropy training has
actual signal for the QAT/fine-tuning experiments (Fig. 2 reproduction).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch: int = 8
    seq: int = 128
    structure: float = 0.8  # P(next token derived from current)


def _structured_tokens(key, batch: int, seq: int, vocab: int, structure: float):
    k1, k2, k3 = jax.random.split(key, 3)
    # Zipf-ish marginal via squared uniform
    u = jax.random.uniform(k1, (batch, seq + 1))
    base = (u * u * vocab).astype(jnp.int32)
    # induced structure: token[t+1] = (a * token[t] + b) % vocab with prob p
    follow = jax.random.uniform(k2, (batch, seq + 1)) < structure

    def step(tok, inp):
        b, f = inp
        nxt = jnp.where(f, (tok * 31 + 7) % vocab, b)
        return nxt, nxt

    _, toks = jax.lax.scan(
        step, base[:, 0], (base[:, 1:].T, follow[:, 1:].T)
    )
    toks = jnp.concatenate([base[:, :1], toks.T], axis=1)  # (B, S+1)
    return toks


def make_batch(cfg: ArchConfig, data: DataConfig, step: int) -> Dict[str, Any]:
    """Deterministic batch for ``step`` (host-side; jit-able too)."""
    key = jax.random.fold_in(jax.random.PRNGKey(data.seed), step)
    toks = _structured_tokens(key, data.batch, data.seq, cfg.vocab, data.structure)
    out: Dict[str, Any] = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    f = jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        kf = jax.random.fold_in(key, 1)
        out["frames"] = (
            jax.random.normal(kf, (data.batch, cfg.n_audio_frames, cfg.d_model)) * 0.1
        ).astype(f)
    if cfg.family == "vlm":
        from repro.models import vlm

        kv = jax.random.fold_in(key, 2)
        nv = cfg.n_frontend_tokens
        out["vision_embeds"] = (
            jax.random.normal(kv, (data.batch, nv, cfg.d_model)) * 0.1
        ).astype(f)
        out["positions"] = vlm.build_mrope_positions(
            data.batch, nv, data.seq
        )
    return out


def shard_for_rank(batch: Dict[str, Any], rank: int, world: int) -> Dict[str, Any]:
    """Slice a global batch for one data-parallel rank (multi-host path)."""

    def sl(x):
        if hasattr(x, "ndim") and x.ndim >= 1 and x.shape[0] % world == 0:
            per = x.shape[0] // world
            return x[rank * per : (rank + 1) * per]
        return x

    return {k: sl(v) for k, v in batch.items()}
