"""Fault-tolerant checkpointing (no external deps).

Design for 1000+ nodes:
  * step-atomic: write to ``step_<N>.tmp/`` then a single directory rename
    (rename is atomic on POSIX); readers never observe partial state.
  * content-integrity: every array file carries a sha256 in the manifest --
    a corrupted/truncated checkpoint is detected and ``restore_latest``
    falls back to the newest intact one (node-failure recovery).
  * mesh-agnostic: arrays are stored unsharded by path; ``restore`` fills a
    template pytree (from eval_shape) and can device_put onto ANY mesh =>
    elastic re-scale across restarts (128 -> 512 chips or back).
  * retention: keep the newest ``keep`` checkpoints.

On a real multi-host cluster each host writes only its addressable shards;
here (single host) we write the full array -- the manifest format already
carries per-array shape/dtype so the multi-host writer is a drop-in.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "name"):
            parts.append(str(e.name))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
    return "/".join(parts)


def _flat_with_paths(tree: Any) -> Dict[str, Any]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out[_path_str(path)] = leaf
    return out


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
    """Atomically persist ``tree`` at ``step``. Returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest: Dict[str, Any] = {"step": step, "arrays": {}, "extra": extra or {}}
    for name, leaf in _flat_with_paths(tree).items():
        arr = np.asarray(leaf)
        fname = hashlib.sha1(name.encode()).hexdigest()[:16] + ".npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        with open(fpath, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["arrays"][name] = {
            "file": fname,
            "sha256": digest,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def _verify(d: str) -> Optional[Dict]:
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        for meta in manifest["arrays"].values():
            fpath = os.path.join(d, meta["file"])
            with open(fpath, "rb") as fh:
                if hashlib.sha256(fh.read()).hexdigest() != meta["sha256"]:
                    return None
        return manifest
    except (OSError, ValueError, KeyError):
        return None


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                pass
    return sorted(steps)


def restore(
    ckpt_dir: str, step: int, template: Any, shardings: Any = None
) -> Any:
    """Fill ``template`` (pytree of arrays or ShapeDtypeStructs) from disk.
    ``shardings``: optional matching pytree of NamedSharding for elastic
    placement onto a (possibly different) mesh."""
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    manifest = _verify(d)
    if manifest is None:
        raise IOError(f"checkpoint {d} missing or corrupt")
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    flat_s = jax.tree.leaves(shardings) if shardings is not None else [None] * len(flat_t)
    leaves = []
    for (path, leaf), shard in zip(flat_t, flat_s):
        name = _path_str(path)
        meta = manifest["arrays"].get(name)
        if meta is None:
            raise KeyError(f"checkpoint missing array {name!r}")
        arr = np.load(os.path.join(d, meta["file"]))
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"{name}: shape {arr.shape} != template {leaf.shape}")
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(jax.tree.structure(template), leaves)


def restore_latest(
    ckpt_dir: str, template: Any, shardings: Any = None
) -> Tuple[Optional[int], Any]:
    """Newest intact checkpoint (corruption falls back to older ones)."""
    for step in reversed(list_steps(ckpt_dir)):
        d = os.path.join(ckpt_dir, f"step_{step:09d}")
        if _verify(d) is not None:
            return step, restore(ckpt_dir, step, template, shardings)
    return None, None


def retain(ckpt_dir: str, keep: int = 3) -> None:
    steps = list_steps(ckpt_dir)
    for step in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{step:09d}"), ignore_errors=True)
