"""Fault-tolerant, codec-based checkpointing (no external deps).

Design for 1000+ nodes:
  * step-atomic: write to ``step_<N>.tmp/`` then a single directory rename
    (rename is atomic on POSIX); readers never observe partial state.
  * content-integrity: every array file carries a sha256 in the manifest --
    a corrupted/truncated checkpoint is detected and ``restore_latest``
    falls back to the newest intact one (node-failure recovery).
  * mesh-agnostic: arrays are stored unsharded by path; ``restore`` fills a
    template pytree (from eval_shape) and can device_put onto ANY mesh =>
    elastic re-scale across restarts (128 -> 512 chips or back).
  * retention: keep the newest ``keep`` checkpoints.

Codec layer (manifest v2): leaves that are not plain arrays serialize
through a registered ``LeafCodec``.  The built-in ``qtensor`` codec makes
packed quantized weights first-class on disk -- a QTensor leaf becomes its
packed payload + scale table + scalar exponent (one sha256-checked .npy per
payload) plus static metadata (bits/group_size/shape/format tag) in the
manifest.  Payload shapes are format-specific projections of the logical
(K, N) -- ternary packs K/16 uint32 rows, int4/nf4 K/8, int8/mx store K raw
int8 rows, and mx scale tables have one row per 32-element block -- but the
codec never interprets them: each payload records its own shape/dtype and
the format tag tells the decode side which registry entry owns the bytes,
so new formats round-trip with no codec changes.  A checkpoint can also carry a compiled ``QuantPlan``: ``save``
writes ``quant_plan.json`` next to the arrays and records its sha256 under
the manifest's ``quant_plan`` section; ``_verify`` validates it like any
payload, so a truncated plan can never restore as "unquantized".

Because codec metadata is self-describing, a v2 checkpoint restores without
a template (``restore_tree``) -- this is what lets a serving process
cold-start from a packed artifact with no fp32 params and no model init
(see ``repro.quant.api.save_artifact`` / ``load_artifact``).

Sharded payloads (manifest-v2 shard layout)
-------------------------------------------
``save(..., shardings=...)`` writes any payload whose sharding splits it
into multiple shards as per-shard files instead of one blob.  The on-disk
contract:

  * files: ``<payload>.shard0.npy``, ``<payload>.shard1.npy``, ... -- one
    ``.npy`` per UNIQUE shard of the global array (replicated mesh axes are
    deduplicated: a slice held by several devices is written once).  On a
    multi-host cluster each host writes only its addressable shards into
    the same step directory; here (single host) all shards are addressable
    so one process writes the full set.
  * manifest entry (under ``arrays`` or a codec node's ``arrays``)::

        {"shape": [...], "dtype": "...",
         "shards": [{"file": "<payload>.shard0.npy",
                     "sha256": "...",
                     "index": [[start, stop], ...]},   # one pair per dim
                    ...]}

    replacing the unsharded ``{"file", "sha256", "shape", "dtype"}`` form;
    ``index`` is the shard's slice of the global array, so assembly needs
    no mesh (integrity checks and the template-``restore`` path concatenate
    on the host).  Every shard carries its own sha256 and is verified by
    ``_verify`` like any payload.
  * assembly contract: ``restore_tree(..., shardings=...)`` matches each
    target device's slice (``sharding.devices_indices_map``) against the
    saved shard indices and builds the global array with
    ``jax.make_array_from_single_device_arrays`` -- per-shard files load
    straight onto their owning devices and the global array is never
    materialized on one host.  A layout mismatch (elastic re-scale) falls
    back to host-side concatenation + ``device_put``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import shutil
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizer import QTensor

PLAN_FILE = "quant_plan.json"


def step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:09d}")


# ---------------------------------------------------------------------------
# Leaf codecs: pluggable serialization for non-plain-array leaves.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LeafCodec:
    """One registered leaf encoding.

    ``matches(leaf)`` decides whether this codec owns a leaf; ``encode``
    splits it into named array payloads (each stored as its own
    sha256-checked file) plus JSON-safe static metadata; ``decode`` is the
    exact inverse.  ``template`` (optional) rebuilds the leaf from
    ShapeDtypeStruct fields + metadata without touching payload bytes --
    what lets ``tree_shapes`` describe a checkpoint abstractly so sharding
    rules can run before any array is read.
    """

    name: str
    matches: Callable[[Any], bool]
    encode: Callable[[Any], Tuple[Dict[str, np.ndarray], Dict[str, Any]]]
    decode: Callable[[Dict[str, np.ndarray], Dict[str, Any]], Any]
    template: Optional[Callable[[Dict[str, Any], Dict[str, Any]], Any]] = None


_CODECS: Dict[str, LeafCodec] = {}


def register_codec(
    name: str,
    *,
    matches: Callable[[Any], bool],
    encode: Callable,
    decode: Callable,
    template: Optional[Callable] = None,
    overwrite: bool = False,
) -> LeafCodec:
    if name in _CODECS and not overwrite:
        raise ValueError(f"codec {name!r} already registered")
    codec = LeafCodec(name, matches, encode, decode, template)
    _CODECS[name] = codec
    return codec


def get_codec(name: str) -> LeafCodec:
    try:
        return _CODECS[name]
    except KeyError:
        raise KeyError(
            f"unknown leaf codec {name!r}; registered: {sorted(_CODECS)}"
        ) from None


def _codec_for(leaf: Any) -> Optional[LeafCodec]:
    for codec in _CODECS.values():
        if codec.matches(leaf):
            return codec
    return None


def _is_codec_leaf(leaf: Any) -> bool:
    return _codec_for(leaf) is not None


# Built-in: packed quantized weights.  (QTensor is the base-layer container
# from repro.core.quantizer; no higher quant layers are imported here.)
def _qt_encode(qt: QTensor) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    arrays = {
        "packed": np.asarray(qt.packed),
        "scale_m": np.asarray(qt.scale_m),
        "scale_e": np.asarray(qt.scale_e),
    }
    meta = {
        "bits": qt.bits,
        "group_size": qt.group_size,
        "shape": list(qt.shape),
        "fmt": qt.fmt,
    }
    return arrays, meta


def _qt_decode(arrays: Dict[str, np.ndarray], meta: Dict[str, Any]) -> QTensor:
    return QTensor(
        _as_jax(arrays["packed"]),
        _as_jax(arrays["scale_m"]),
        _as_jax(arrays["scale_e"]),
        bits=int(meta["bits"]),
        group_size=int(meta["group_size"]),
        shape=tuple(meta["shape"]),
        fmt=meta.get("fmt", ""),
    )


def _qt_template(fields: Dict[str, Any], meta: Dict[str, Any]) -> QTensor:
    """QTensor over ShapeDtypeStruct fields (no payload bytes read)."""
    return QTensor(
        fields["packed"], fields["scale_m"], fields["scale_e"],
        bits=int(meta["bits"]), group_size=int(meta["group_size"]),
        shape=tuple(meta["shape"]), fmt=meta.get("fmt", ""),
    )


def _as_jax(arr: Any):
    """np payloads -> device arrays; already-assembled jax.Arrays (the
    sharded make_array path) pass through untouched."""
    return arr if isinstance(arr, jax.Array) else jnp.asarray(arr)


register_codec(
    "qtensor",
    matches=lambda leaf: isinstance(leaf, QTensor),
    encode=_qt_encode,
    decode=_qt_decode,
    template=_qt_template,
)


# ---------------------------------------------------------------------------
# Tree <-> path-keyed flat view (codec nodes stay whole).
# ---------------------------------------------------------------------------
def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "name"):
            parts.append(str(e.name))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
    return "/".join(parts)


def _flat_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=_is_codec_leaf)
    return [(_path_str(path), leaf) for path, leaf in flat]


def _payload_name(name: str) -> str:
    return hashlib.sha1(name.encode()).hexdigest()[:16] + ".npy"


# ---------------------------------------------------------------------------
# Transient-IO retry.  Payload READS (np.load, sha256 hashing) retry OSError
# with exponential backoff -- a filesystem flake during a serving cold start
# should cost milliseconds, not the boot.  Integrity failures (sha256
# mismatch, malformed manifest) are NOT OSErrors and are never retried:
# corrupt data must fail closed (``_verify`` -> None), because retrying it
# would serve corrupt weights.  ``io_fault_hook`` is the chaos harness's
# injection point (``repro.serving.faults.FlakyIO``).
# ---------------------------------------------------------------------------
IO_RETRIES = 3  # retry attempts AFTER the first failure
IO_BACKOFF_S = 0.05  # first backoff; doubles per retry

_IO_FAULT_HOOK: List[Optional[Callable[[str], None]]] = [None]


def set_io_fault_hook(hook: Optional[Callable[[str], None]]) -> None:
    """Install a callable invoked with every payload path about to be read
    (``None`` uninstalls).  Raising ``OSError`` from it models a transient
    read failure; the retry loop must absorb it."""
    _IO_FAULT_HOOK[0] = hook


@contextlib.contextmanager
def io_fault_hook(hook: Callable[[str], None]):
    """Scoped ``set_io_fault_hook`` -- the hook never outlives the test."""
    set_io_fault_hook(hook)
    try:
        yield hook
    finally:
        set_io_fault_hook(None)


def _read_retry(read: Callable[[str], Any], fpath: str) -> Any:
    """``read(fpath)`` with OSError retry + exponential backoff."""
    delay = IO_BACKOFF_S
    for attempt in range(IO_RETRIES + 1):
        try:
            if _IO_FAULT_HOOK[0] is not None:
                _IO_FAULT_HOOK[0](fpath)
            return read(fpath)
        except OSError:
            if attempt == IO_RETRIES:
                raise
            time.sleep(delay)
            delay *= 2


def _np_load(fpath: str) -> np.ndarray:
    return _read_retry(np.load, fpath)


def _sha256_once(fpath: str) -> str:
    with open(fpath, "rb") as f:
        return hashlib.sha256(f.read()).hexdigest()


def _file_sha256(fpath: str) -> str:
    return _read_retry(_sha256_once, fpath)


def _norm_index(idx, shape) -> Tuple[Tuple[int, int], ...]:
    """A devices_indices_map entry -> ((start, stop), ...) per dim."""
    out = []
    for s, dim in zip(idx, shape):
        start = 0 if s.start is None else int(s.start)
        stop = dim if s.stop is None else int(s.stop)
        out.append((start, stop))
    return tuple(out)


def _shard_indices(sharding, shape) -> List[Tuple[Tuple[int, int], ...]]:
    """Unique shard slices of ``shape`` under ``sharding`` (replicated mesh
    axes deduplicated), in first-seen device order."""
    seen: List[Tuple[Tuple[int, int], ...]] = []
    for idx in sharding.devices_indices_map(tuple(shape)).values():
        key = _norm_index(idx, shape)
        if key not in seen:
            seen.append(key)
    return seen


def _write_payload(
    d: str, name: str, arr: np.ndarray, sharding: Any = None
) -> Dict[str, Any]:
    """Write one payload; with a ``sharding`` that splits it, write
    per-shard files (``<payload>.shard{k}.npy``, own sha256 each) instead of
    one blob -- the manifest-v2 shard layout (module docstring)."""
    fname = _payload_name(name)
    indices = (
        _shard_indices(sharding, arr.shape) if sharding is not None else []
    )
    if len(indices) > 1:
        shards = []
        for k, index in enumerate(indices):
            sname = f"{fname[:-len('.npy')]}.shard{k}.npy"
            spath = os.path.join(d, sname)
            np.save(spath, arr[tuple(slice(a, b) for a, b in index)])
            shards.append({
                "file": sname,
                "sha256": _file_sha256(spath),
                "index": [list(p) for p in index],
            })
        return {
            "shards": shards,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    fpath = os.path.join(d, fname)
    np.save(fpath, arr)
    return {
        "file": fname,
        "sha256": _file_sha256(fpath),
        "shape": list(arr.shape),
        "dtype": str(arr.dtype),
    }


def _plan_json(plan: Any) -> Optional[str]:
    if plan is None:
        return None
    return plan if isinstance(plan, str) else plan.to_json()


# ---------------------------------------------------------------------------
# Save.
# ---------------------------------------------------------------------------
def save(
    ckpt_dir: str,
    step: int,
    tree: Any,
    extra: Optional[Dict] = None,
    plan: Any = None,
    shardings: Any = None,
    quant_state: Optional[Dict] = None,
) -> str:
    """Atomically persist ``tree`` at ``step``. Returns the final directory.

    Plain array leaves go to the manifest's ``arrays`` section; leaves owned
    by a registered codec (QTensors) go to ``nodes`` as payload files plus
    static metadata.  ``plan`` (a ``repro.quant.QuantPlan`` or its JSON
    string) is written to ``quant_plan.json`` and checksummed under the
    manifest's ``quant_plan`` section.  ``quant_state`` (a JSON-serializable
    schedule record, e.g. ``repro.quant.QuantState.to_meta()``) rides in the
    manifest's ``quant_state`` section so a mid-schedule TTQ/INQ resume is
    bit-faithful -- the state *arrays* live inside ``tree`` like any other
    leaf.  ``shardings`` (a matching pytree of
    NamedSharding; codec leaves may carry per-field shardings, e.g. a
    QTensor of shardings from ``repro.parallel.qtensor_shardings``) switches
    split payloads to the per-shard layout (module docstring).
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    final = step_dir(ckpt_dir, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest: Dict[str, Any] = {
        "version": 2,
        "step": step,
        "arrays": {},
        "nodes": {},
        "quant_plan": None,
        "quant_state": quant_state,
        "extra": extra or {},
    }
    shard_by_name: Dict[str, Any] = (
        dict(_flat_with_paths(shardings)) if shardings is not None else {}
    )
    for name, leaf in _flat_with_paths(tree):
        codec = _codec_for(leaf)
        sh = shard_by_name.get(name)
        if codec is None:
            manifest["arrays"][name] = _write_payload(
                tmp, name, np.asarray(leaf), sh
            )
        else:
            payloads, meta = codec.encode(leaf)
            manifest["nodes"][name] = {
                "codec": codec.name,
                "meta": meta,
                "arrays": {
                    field: _write_payload(
                        tmp, f"{name}/{field}", arr, getattr(sh, field, None)
                    )
                    for field, arr in payloads.items()
                },
            }
    blob = _plan_json(plan)
    if blob is not None:
        with open(os.path.join(tmp, PLAN_FILE), "w") as f:
            f.write(blob)
        manifest["quant_plan"] = {
            "file": PLAN_FILE,
            "sha256": hashlib.sha256(blob.encode()).hexdigest(),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


# ---------------------------------------------------------------------------
# Verification (integrity gate for restore_latest's fallback).
# ---------------------------------------------------------------------------
def _shards_tile(meta: Dict[str, Any]) -> bool:
    """Do the shard indices exactly tile the full array?

    Shards written by ``_write_payload`` come from a mesh sharding, so they
    form a regular grid: per dimension, the unique (start, stop) intervals
    must partition [0, dim), and every cross-product cell must be present
    exactly once.  A step directory missing a host's shards (or a
    hand-edited manifest) must FAIL verification -- assembling it would
    leave uninitialized slices in the restored array."""
    shape = meta["shape"]
    boxes = {tuple(tuple(p) for p in s["index"]) for s in meta["shards"]}
    if len(boxes) != len(meta["shards"]):
        return False  # duplicate index -> double-write, reject
    per_dim = []
    for d, dim in enumerate(shape):
        ivals = sorted({box[d] for box in boxes})
        pos = 0
        for start, stop in ivals:
            if start != pos or stop <= start:
                return False
            pos = stop
        if pos != dim:
            return False
        per_dim.append(len(ivals))
    n_cells = 1
    for n in per_dim:
        n_cells *= n
    return len(boxes) == n_cells


def _check_payload(d: str, meta: Dict[str, Any]) -> bool:
    if "shards" in meta:  # sharded payload: tile the array AND verify each
        if not _shards_tile(meta):
            return False
        return all(
            _file_sha256(os.path.join(d, s["file"])) == s["sha256"]
            for s in meta["shards"]
        )
    return _file_sha256(os.path.join(d, meta["file"])) == meta["sha256"]


def _verify(d: str) -> Optional[Dict]:
    """Full-integrity check of one checkpoint directory -> manifest or None.

    Everything the manifest references is validated: array payloads, codec
    node payloads, and the ``quant_plan`` section (checksum AND parseable
    structure -- a truncated plan JSON must fail verification, not restore
    as an unquantized checkpoint)."""
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        for meta in manifest["arrays"].values():
            if not _check_payload(d, meta):
                return None
        for node in manifest.get("nodes", {}).values():
            if node["codec"] not in _CODECS:
                return None
            for meta in node["arrays"].values():
                if not _check_payload(d, meta):
                    return None
        qp = manifest.get("quant_plan")
        if qp is not None:
            with open(os.path.join(d, qp["file"])) as fh:
                blob = fh.read()
            if hashlib.sha256(blob.encode()).hexdigest() != qp["sha256"]:
                return None
            plan = json.loads(blob)
            if not isinstance(plan, dict) or "sites" not in plan:
                return None
        return manifest
    except (OSError, ValueError, KeyError, TypeError):
        # TypeError: structurally corrupt manifest (e.g. a null array entry)
        # must fall back like any other corruption, not crash restore_latest
        return None


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                pass
    return sorted(steps)


def latest_intact(ckpt_dir: str) -> Tuple[Optional[int], Optional[Dict]]:
    """(step, verified manifest) of the newest intact checkpoint.

    Returning the manifest lets callers thread it into ``restore`` /
    ``restore_tree`` / ``load_plan`` so a large artifact is read-and-hashed
    once per boot, not once per helper."""
    for step in reversed(list_steps(ckpt_dir)):
        manifest = _verify(step_dir(ckpt_dir, step))
        if manifest is not None:
            return step, manifest
    return None, None


def latest_intact_step(ckpt_dir: str) -> Optional[int]:
    """Newest step whose directory passes full verification."""
    return latest_intact(ckpt_dir)[0]


# ---------------------------------------------------------------------------
# Restore.
# ---------------------------------------------------------------------------
def _load_payload(d: str, meta: Dict[str, Any]) -> np.ndarray:
    """Host-side load of one payload; sharded payloads concatenate into a
    single host array (the mesh-free / template-``restore`` path)."""
    if "shards" not in meta:
        return _np_load(os.path.join(d, meta["file"]))
    out = np.empty(tuple(meta["shape"]), np.dtype(meta["dtype"]))
    for s in meta["shards"]:
        sl = tuple(slice(a, b) for a, b in s["index"])
        out[sl] = _np_load(os.path.join(d, s["file"]))
    return out


def _load_payload_on_mesh(d: str, meta: Dict[str, Any], sharding) -> jax.Array:
    """Assemble one payload directly onto its target sharding.

    When the saved shard indices match the target layout (the common
    save-and-restore-on-the-same-mesh-shape case), each ``.shard{k}`` file
    loads once and is device_put straight onto the devices owning that
    slice -- ``jax.make_array_from_single_device_arrays`` stitches the
    global view and the full array never exists on one host.  An elastic
    layout change falls back to host concatenation + ``device_put``."""
    shape = tuple(meta["shape"])
    if sharding is None:
        return jnp.asarray(_load_payload(d, meta))
    if "shards" in meta:
        saved = {
            tuple(tuple(p) for p in s["index"]): s["file"]
            for s in meta["shards"]
        }
        imap = sharding.devices_indices_map(shape)
        if all(_norm_index(idx, shape) in saved for idx in imap.values()):
            cache: Dict[str, np.ndarray] = {}
            pieces = []
            for dev, idx in imap.items():
                fname = saved[_norm_index(idx, shape)]
                if fname not in cache:
                    cache[fname] = _np_load(os.path.join(d, fname))
                pieces.append(jax.device_put(cache[fname], dev))
            return jax.make_array_from_single_device_arrays(
                shape, sharding, pieces
            )
    return jax.device_put(_load_payload(d, meta), sharding)


def _decode_node(d: str, node: Dict[str, Any], shard_leaf: Any = None) -> Any:
    codec = get_codec(node["codec"])
    if shard_leaf is None:
        arrays = {
            field: _load_payload(d, meta)
            for field, meta in node["arrays"].items()
        }
    else:
        arrays = {
            field: _load_payload_on_mesh(
                d, meta, getattr(shard_leaf, field, None)
            )
            for field, meta in node["arrays"].items()
        }
    return codec.decode(arrays, node["meta"])


def restore(
    ckpt_dir: str, step: int, template: Any, shardings: Any = None,
    manifest: Optional[Dict] = None,
) -> Any:
    """Fill ``template`` (pytree of arrays / ShapeDtypeStructs / QTensors)
    from disk.  ``shardings``: optional matching pytree of NamedSharding for
    elastic placement onto a (possibly different) mesh.  ``manifest``: an
    already-verified manifest (skips re-hashing every payload)."""
    d = step_dir(ckpt_dir, step)
    if manifest is None:
        manifest = _verify(d)
    if manifest is None:
        raise IOError(f"checkpoint {d} missing or corrupt")
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=_is_codec_leaf
    )
    flat_s = (
        jax.tree_util.tree_flatten(shardings, is_leaf=_is_codec_leaf)[0]
        if shardings is not None
        else [None] * len(flat_t)
    )
    nodes = manifest.get("nodes", {})
    leaves = []
    for (path, leaf), shard in zip(flat_t, flat_s):
        name = _path_str(path)
        if name in nodes:
            val = _decode_node(d, nodes[name])
            leaves.append(jax.device_put(val, shard) if shard is not None else val)
            continue
        meta = manifest["arrays"].get(name)
        if meta is None:
            raise KeyError(f"checkpoint missing array {name!r}")
        arr = _np_load(os.path.join(d, meta["file"]))
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"{name}: shape {arr.shape} != template {leaf.shape}")
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _insert_by_path(out: Dict[str, Any], name: str, val: Any) -> None:
    node = out
    parts = name.split("/")
    for part in parts[:-1]:
        node = node.setdefault(part, {})
    node[parts[-1]] = val


def restore_tree(
    d: str, manifest: Optional[Dict] = None, shardings: Any = None
) -> Any:
    """Template-free restore of one verified checkpoint directory.

    Rebuilds the nested-dict pytree purely from manifest paths: plain
    arrays load with their stored dtype, codec nodes decode through the
    registry (QTensors come back packed -- the fp32 weights are never
    materialized).  This is the cold-start path for serving from a packed
    artifact.  ``manifest``: an already-verified manifest (skips
    re-hashing).  ``shardings``: a matching pytree of NamedSharding (see
    ``tree_shapes`` for building one without reading payloads) -- sharded
    payloads then assemble per-shard straight onto their owning devices and
    the global tree never materializes on one host."""
    if manifest is None:
        manifest = _verify(d)
    if manifest is None:
        raise IOError(f"checkpoint {d} missing or corrupt")
    shard_by_name: Dict[str, Any] = (
        dict(_flat_with_paths(shardings)) if shardings is not None else {}
    )
    out: Dict[str, Any] = {}
    for name, meta in manifest["arrays"].items():
        sh = shard_by_name.get(name)
        val = (
            _load_payload_on_mesh(d, meta, sh)
            if sh is not None
            else jnp.asarray(_load_payload(d, meta))
        )
        _insert_by_path(out, name, val)
    for name, node in manifest.get("nodes", {}).items():
        _insert_by_path(out, name, _decode_node(d, node, shard_by_name.get(name)))
    return out


def tree_shapes(manifest: Dict[str, Any]) -> Any:
    """Abstract pytree of one checkpoint: ShapeDtypeStructs for plain
    arrays, codec templates (e.g. QTensors over ShapeDtypeStruct fields)
    for codec nodes -- built purely from the manifest, no payload reads.
    This is what sharding rules run against before a mesh-aware restore."""
    out: Dict[str, Any] = {}
    for name, meta in manifest["arrays"].items():
        _insert_by_path(out, name, jax.ShapeDtypeStruct(
            tuple(meta["shape"]), np.dtype(meta["dtype"])
        ))
    for name, node in manifest.get("nodes", {}).items():
        codec = get_codec(node["codec"])
        if codec.template is None:
            raise ValueError(
                f"codec {codec.name!r} has no template builder; cannot "
                "describe this checkpoint abstractly"
            )
        fields = {
            field: jax.ShapeDtypeStruct(tuple(m["shape"]), np.dtype(m["dtype"]))
            for field, m in node["arrays"].items()
        }
        _insert_by_path(out, name, codec.template(fields, node["meta"]))
    return out


def load_plan(d: str, manifest: Optional[Dict] = None):
    """The checkpoint's compiled ``QuantPlan`` (or None if it carries none).

    ``manifest``: an already-verified manifest (skips re-hashing)."""
    if manifest is None:
        manifest = _verify(d)
    if manifest is None:
        raise IOError(f"checkpoint {d} missing or corrupt")
    qp = manifest.get("quant_plan")
    if qp is None:
        return None
    from repro.quant.plan import QuantPlan  # lazy: keep the base layer light

    with open(os.path.join(d, qp["file"])) as f:
        return QuantPlan.from_json(f.read())


def load_quant_state(d: str, manifest: Optional[Dict] = None) -> Optional[Dict]:
    """The checkpoint's quantization-schedule record (``quant_state``
    manifest section; None if it carries none).  Returns the raw meta dict
    -- rebuild with ``repro.quant.QuantState.from_meta``."""
    if manifest is None:
        manifest = _verify(d)
    if manifest is None:
        raise IOError(f"checkpoint {d} missing or corrupt")
    return manifest.get("quant_state")


def load_manifest(d: str) -> Dict[str, Any]:
    """Verified manifest of one checkpoint directory (raises if corrupt)."""
    manifest = _verify(d)
    if manifest is None:
        raise IOError(f"checkpoint {d} missing or corrupt")
    return manifest


def restore_latest(
    ckpt_dir: str, template: Any, shardings: Any = None
) -> Tuple[Optional[int], Any]:
    """Newest intact checkpoint (corruption falls back to older ones)."""
    step, manifest = latest_intact(ckpt_dir)
    if step is None:
        return None, None
    return step, restore(ckpt_dir, step, template, shardings, manifest=manifest)


def dir_bytes(path: str) -> int:
    """Total on-disk size of a checkpoint/artifact directory."""
    return sum(
        os.path.getsize(os.path.join(root, f))
        for root, _, files in os.walk(path)
        for f in files
    )


def retain(ckpt_dir: str, keep: int = 3) -> None:
    steps = list_steps(ckpt_dir)
    for step in steps[:-keep]:
        shutil.rmtree(step_dir(ckpt_dir, step), ignore_errors=True)
