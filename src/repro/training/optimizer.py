"""AdamW from scratch, with optional 8-bit dynamic-fixed-point moments.

``state_bits=8`` stores the first/second moments as int8 mantissas with
per-row shared exponents -- the paper's own DFP machinery applied to
optimizer state (a ZeRO-style 4x memory cut for m and v; this is what lets
the 314B-param training cell fit 16 GB/chip on the dry-run mesh).

The second moment is quantized in the SQRT domain: int8 mantissas of
sqrt(v), not v.  With a direct-v encoding, an element whose v rounds to 0
while its m does not explodes the update (m / (sqrt(0)+eps)); in sqrt
domain both mantissas are proportional to |g|, so whenever sqrt(v) rounds
to zero the matching m does too and the update stays bounded.

QTensor (PTQ) leaves and integer leaves are not trainable and are skipped.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import dfp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_bits: int = 32  # 32 or 8 (DFP moments)


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip((s - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.minimum(warm, 1.0) * jnp.where(s < cfg.warmup_steps, 1.0, cos)


def _trainable(leaf) -> bool:
    return hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating)


def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-row 8-bit DFP (exponent shared over the last axis)."""
    axis = (x.ndim - 1,) if x.ndim else None
    return dfp.quantize_tensor(x.astype(jnp.float32), 8, axis)


def _dq8(q: jax.Array, e: jax.Array) -> jax.Array:
    return dfp.dequantize(q, e)


def _q8_sqrt(v: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Second moment: quantize sqrt(v) (see module docstring)."""
    return _q8(jnp.sqrt(jnp.maximum(v, 0.0)))


def _dq8_sqrt(q: jax.Array, e: jax.Array) -> jax.Array:
    u = _dq8(q, e)
    return u * u


def init_state(params: Any, cfg: OptConfig) -> Dict[str, Any]:
    def zero_moment(leaf):
        if not _trainable(leaf):
            return None
        z = jnp.zeros(leaf.shape, jnp.float32)
        if cfg.state_bits == 8:
            q, e = _q8(z)
            return {"q": q, "e": e}
        return z

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zero_moment, params),
        "v": jax.tree.map(zero_moment, params),  # sqrt-domain when 8-bit
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [l for l in jax.tree.leaves(tree) if _trainable(l)]
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(
    params: Any, grads: Any, state: Dict[str, Any], cfg: OptConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    is_entry = lambda n: isinstance(n, dict) and set(n) == {"q", "e"}

    def upd(p, g, m, v):
        if not _trainable(p) or g is None:
            return p, m, v
        g = g.astype(jnp.float32) * clip
        mf = _dq8(m["q"], m["e"]) if cfg.state_bits == 8 else m
        vf = _dq8_sqrt(v["q"], v["e"]) if cfg.state_bits == 8 else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(g)
        mh = mf / b1c
        vh = vf / b2c
        pf = p.astype(jnp.float32)
        new_p = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * pf)
        if cfg.state_bits == 8:
            mq, me = _q8(mf)
            vq, ve = _q8_sqrt(vf)
            return new_p.astype(p.dtype), {"q": mq, "e": me}, {"q": vq, "e": ve}
        return new_p.astype(p.dtype), mf, vf

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.flatten(state["m"], is_leaf=lambda n: n is None or is_entry(n))[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=lambda n: n is None or is_entry(n))[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"step": step, "m": new_m, "v": new_v}, metrics
