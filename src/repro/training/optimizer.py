"""AdamW from scratch, with optional 8-bit dynamic-fixed-point moments.

``state_bits=8`` stores the first/second moments as int8 mantissas with
per-row shared exponents -- the paper's own DFP machinery applied to
optimizer state (a ZeRO-style 4x memory cut for m and v; this is what lets
the 314B-param training cell fit 16 GB/chip on the dry-run mesh).

The second moment is quantized in the SQRT domain: int8 mantissas of
sqrt(v), not v.  With a direct-v encoding, an element whose v rounds to 0
while its m does not explodes the update (m / (sqrt(0)+eps)); in sqrt
domain both mantissas are proportional to |g|, so whenever sqrt(v) rounds
to zero the matching m does too and the update stays bounded.

QTensor (PTQ) leaves and integer leaves are not trainable and are skipped.

Quantization-state leaves (repro.quant.state) get special treatment by leaf
name: ``ttq_scales`` / ``inq_scales`` are trainable grids excluded from
weight decay (decay would shrink the learned grid toward zero) that keep
f32 moments even under ``state_bits=8`` (a per-site scale table is tiny --
DFP-8 moments would save nothing and cost precision on exactly the most
sensitive parameters); ``inq_mask`` is frozen bookkeeping (no moments, no
update); and a ``w`` whose site carries an ``inq_mask`` has the masked
coordinates pinned inside ``apply_updates`` -- weight decay and moment
debiasing cannot move a frozen coordinate even though its gradient is
already zeroed by the STE.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import dfp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_bits: int = 32  # 32 or 8 (DFP moments)


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip((s - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.minimum(warm, 1.0) * jnp.where(s < cfg.warmup_steps, 1.0, cos)


def _trainable(leaf) -> bool:
    return hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating)


# Quantization-state leaf names (see repro/quant/state.py).  Matched by leaf
# key so the optimizer needs no plan or registry access.
SCALE_KEYS = ("ttq_scales", "inq_scales")  # trainable grids: no weight
# decay, f32 moments even under state_bits=8
FROZEN_KEYS = ("inq_mask",)  # never updated
MASK_KEY = "inq_mask"  # pins its sibling "w"'s frozen coordinates


def _leaf_name(path) -> str:
    """Last dict key on a tree_util key path ('' for non-dict entries)."""
    if not path:
        return ""
    last = path[-1]
    return str(getattr(last, "key", ""))


def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-row 8-bit DFP (exponent shared over the last axis)."""
    axis = (x.ndim - 1,) if x.ndim else None
    return dfp.quantize_tensor(x.astype(jnp.float32), 8, axis)


def _dq8(q: jax.Array, e: jax.Array) -> jax.Array:
    return dfp.dequantize(q, e)


def _q8_sqrt(v: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Second moment: quantize sqrt(v) (see module docstring)."""
    return _q8(jnp.sqrt(jnp.maximum(v, 0.0)))


def _dq8_sqrt(q: jax.Array, e: jax.Array) -> jax.Array:
    u = _dq8(q, e)
    return u * u


def init_state(params: Any, cfg: OptConfig) -> Dict[str, Any]:
    def zero_moment(path, leaf):
        name = _leaf_name(path)
        if not _trainable(leaf) or name in FROZEN_KEYS:
            return None
        z = jnp.zeros(leaf.shape, jnp.float32)
        if cfg.state_bits == 8 and name not in SCALE_KEYS:
            q, e = _q8(z)
            return {"q": q, "e": e}
        return z

    zm = jax.tree_util.tree_map_with_path(zero_moment, params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": zm,
        "v": jax.tree_util.tree_map_with_path(zero_moment, params),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [l for l in jax.tree.leaves(tree) if _trainable(l)]
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(
    params: Any, grads: Any, state: Dict[str, Any], cfg: OptConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    is_entry = lambda n: isinstance(n, dict) and set(n) == {"q", "e"}

    def upd(name, p, g, m, v, mask):
        if not _trainable(p) or g is None or m is None:
            return p, m, v
        g = g.astype(jnp.float32) * clip
        q8 = is_entry(m)  # scale leaves keep f32 moments under state_bits=8
        mf = _dq8(m["q"], m["e"]) if q8 else m
        vf = _dq8_sqrt(v["q"], v["e"]) if q8 else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(g)
        mh = mf / b1c
        vh = vf / b2c
        pf = p.astype(jnp.float32)
        wd = 0.0 if name in SCALE_KEYS else cfg.weight_decay
        new_p = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + wd * pf)
        if mask is not None:  # INQ: frozen coordinates do not move, ever
            new_p = jnp.where(mask > 0, pf, new_p)
        if q8:
            mq, me = _q8(mf)
            vq, ve = _q8_sqrt(vf)
            return new_p.astype(p.dtype), {"q": mq, "e": me}, {"q": vq, "e": ve}
        return new_p.astype(p.dtype), mf, vf

    flat_pp, treedef = jax.tree_util.tree_flatten_with_path(params)
    paths = [kp for kp, _ in flat_pp]
    flat_p = [leaf for _, leaf in flat_pp]
    names = [_leaf_name(kp) for kp in paths]
    # site-level mask lookup: a "w" whose parent node carries an inq_mask
    masks = {
        kp[:-1]: leaf for kp, leaf, nm in zip(paths, flat_p, names)
        if nm == MASK_KEY
    }
    mask_for = [
        masks.get(kp[:-1]) if nm == "w" else None
        for kp, nm in zip(paths, names)
    ]
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.flatten(state["m"], is_leaf=lambda n: n is None or is_entry(n))[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=lambda n: n is None or is_entry(n))[0]
    out = [
        upd(nm, p, g, m, v, mk)
        for nm, p, g, m, v, mk in zip(names, flat_p, flat_g, flat_m, flat_v, mask_for)
    ]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"step": step, "m": new_m, "v": new_v}, metrics
