"""First-class KV-cache formats: registered block layouts for decode state.

The paper's thesis -- integer mantissas sharing power-of-two exponents --
applied to the KV cache, which at long context dominates decode HBM traffic.
Three formats ship (the registry is open, like ``repro.quant.formats``):

  * ``kv_bf16``  raw bf16 mantissas, no exponents (the fp baseline).
  * ``kv_int8``  int8 mantissas + one int8 DFP exponent per (token, kv-head)
                 -- subsumes the old ``kv_bits == 8`` special case.
                 ~1.94x fewer cache bytes than bf16 at hd=32 (2hd/(hd+1):
                 the per-token exponent column is the only overhead).
  * ``kv_mx``    int4 mantissas packed two-per-byte along head_dim + one
                 int8 exponent shared by a 32-token block along the
                 sequence axis (mx-style microscaling: all-shift dequant)
                 -- ~3.99x fewer cache bytes than bf16 at hd=32.

A cache for one attention layer is a dict of leaves with the sequence axis
at position 1: ``{"k", "v"}`` plus ``{"ke", "ve"}`` exponent planes for the
quantized formats.  Families stack these on a leading layer axis and scan.

Write semantics
---------------
``write(fmt, cache, k, v, cache_index)`` quantizes on write and supports the
two shapes the serving engines produce:

  * aligned slice write  -- scalar (possibly traced) ``cache_index``; the
    S incoming tokens land at [idx, idx+S) (prefill / chunked prefill).
  * per-slot masked write -- (B,) ``cache_index`` with S == 1 (continuous
    batching: every slot decodes at its own position).

For ``kv_mx`` a write may raise a block's shared exponent (running max);
previously-stored mantissas of that block are then re-scaled (arithmetic
shift toward the new exponent) so every resident token dequantizes with the
block's single exponent.  That is exactly the value each token would have
been given had it been quantized at the final exponent, so block contents
are write-order consistent.  Only blocks the write touches can change
exponent.

Read semantics
--------------
``attend_view(fmt, cache)`` returns ``(k, v, kscale, vscale)`` where k/v are
integer codes (mx nibbles unpacked) and kscale/vscale are exact
power-of-two per-token scales (B, T, Kh) -- the XLA oracle folds these into
the score/probability tensors so a dequantized cache never materializes.
The Pallas flash-decode kernel (``kernels/flash_decode.py``) instead loads
the *packed* leaves and dequantizes tile-by-tile in VMEM.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dfp

MX_KV_BLOCK = 32  # tokens sharing one exponent along the sequence axis
_MX_QMAX = 7  # int4 symmetric range [-7, 7]
# empty-block exponent sentinel: any real token's exponent wins the running
# max (0 would act as a floor -- tokens with |x| < qmax would round to 0)
_MX_E_EMPTY = -127


# ---------------------------------------------------------------------------
# shared write helpers
# ---------------------------------------------------------------------------
def _slice_write(buf: jax.Array, val: jax.Array, idx) -> jax.Array:
    """Aligned S-token write at (traced) scalar ``idx`` along axis 1."""
    return jax.lax.dynamic_update_slice_in_dim(buf, val.astype(buf.dtype), idx, 1)


def _mask_write(buf: jax.Array, val: jax.Array, pos: jax.Array) -> jax.Array:
    """Per-slot single-token write at per-batch positions ``pos`` (B,)."""
    iota = jnp.arange(buf.shape[1])
    m = iota[None, :, None, None] == pos[:, None, None, None]
    return jnp.where(m, val.astype(buf.dtype), buf)


def _dfp_tokens(x: jax.Array, bits: int) -> Tuple[jax.Array, jax.Array]:
    """(B,S,Kh,hd) -> (int8 mantissas, int32 per-(token, head) exponents)."""
    xf = x.astype(jnp.float32)
    max_abs = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    e = dfp.choose_exponent(max_abs, bits)
    return dfp.quantize(xf, e, bits), e


# ---------------------------------------------------------------------------
# int4 nibble packing (mx mantissas: two head_dim channels per byte)
# ---------------------------------------------------------------------------
def pack_i4(codes: jax.Array) -> jax.Array:
    """(..., hd) int codes in [-8, 7] -> (..., hd//2) uint8 nibble pairs."""
    c = codes.astype(jnp.int32) & 0xF
    return (c[..., 0::2] | (c[..., 1::2] << 4)).astype(jnp.uint8)


def unpack_i4(packed: jax.Array) -> jax.Array:
    """(..., hd//2) uint8 -> (..., hd) int8 codes in [-8, 7]."""
    b = packed.astype(jnp.int32)
    lo, hi = b & 0xF, (b >> 4) & 0xF
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    pair = jnp.stack([lo, hi], axis=-1).astype(jnp.int8)
    return pair.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


# ---------------------------------------------------------------------------
# format registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class KVFormat:
    """One registered cache layout.

    ``init`` allocates the leaves for ``lead + (max_len, kh, hd)`` caches
    (``lead`` carries the stacked-layer and batch axes, e.g. ``(L, B)``);
    ``write_aligned`` / ``write_masked`` return the updated kv leaves;
    ``attend_view`` exposes (k, v, kscale, vscale) for the XLA fold path;
    ``bytes_per_token`` is k+v cache bytes per token per layer (exponent
    planes included) -- the bench's traffic accounting.
    """

    name: str
    mant_bits: int  # stored mantissa bits per value (16 = unquantized bf16)
    seq_block: int  # tokens sharing one exponent (0 = none, 1 = per-token)
    init: Callable
    write_aligned: Callable
    write_masked: Callable
    attend_view: Callable
    bytes_per_token: Callable

    @property
    def quantized(self) -> bool:
        return self.seq_block > 0


_KV_FORMATS: Dict[str, KVFormat] = {}


def register_kv_format(fmt: KVFormat) -> KVFormat:
    if fmt.name in _KV_FORMATS:
        raise ValueError(f"kv format {fmt.name!r} already registered")
    _KV_FORMATS[fmt.name] = fmt
    return fmt


def get_kv_format(name: str) -> KVFormat:
    try:
        return _KV_FORMATS[name]
    except KeyError:
        raise KeyError(
            f"unknown kv cache format {name!r}; registered: "
            f"{kv_format_names()}"
        ) from None


def kv_format_names() -> Tuple[str, ...]:
    return tuple(sorted(_KV_FORMATS))


def resolve_kv_fmt(cfg) -> str:
    """Config knob -> format name, with ``kv_bits`` back-compat.

    ``cfg.kv_fmt`` wins when set; otherwise ``kv_bits == 8`` maps to
    ``kv_int8`` (the pre-registry spelling) and anything else to the bf16
    baseline.  Unknown names fail loudly here, at cache-allocation time,
    not deep inside a jitted decode step.
    """
    name = getattr(cfg, "kv_fmt", None)
    if name is None:
        name = "kv_int8" if getattr(cfg, "kv_bits", 16) == 8 else "kv_bf16"
    get_kv_format(name)  # loud KeyError on a typo
    return name


# ---------------------------------------------------------------------------
# kv_bf16
# ---------------------------------------------------------------------------
def _bf16_init(lead, max_len, kh, hd, dtype):
    shape = (*lead, max_len, kh, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _bf16_write_aligned(cache, k, v, idx):
    return {"k": _slice_write(cache["k"], k, idx),
            "v": _slice_write(cache["v"], v, idx)}


def _bf16_write_masked(cache, k, v, pos):
    return {"k": _mask_write(cache["k"], k, pos),
            "v": _mask_write(cache["v"], v, pos)}


def _bf16_view(cache):
    return cache["k"], cache["v"], None, None


# ---------------------------------------------------------------------------
# kv_int8: per-(token, head) DFP exponents
# ---------------------------------------------------------------------------
def _int8_init(lead, max_len, kh, hd, dtype):
    shape = (*lead, max_len, kh, hd)
    eshape = shape[:-1] + (1,)
    return {
        "k": jnp.zeros(shape, jnp.int8),
        "v": jnp.zeros(shape, jnp.int8),
        "ke": jnp.zeros(eshape, jnp.int8),
        "ve": jnp.zeros(eshape, jnp.int8),
    }


def _int8_write(cache, k, v, idx, write_fn):
    kq, ke = _dfp_tokens(k, 8)
    vq, ve = _dfp_tokens(v, 8)
    return {
        "k": write_fn(cache["k"], kq, idx),
        "v": write_fn(cache["v"], vq, idx),
        "ke": write_fn(cache["ke"], ke.astype(jnp.int8), idx),
        "ve": write_fn(cache["ve"], ve.astype(jnp.int8), idx),
    }


def _int8_view(cache):
    kscale = dfp.exp2i(cache["ke"][..., 0])  # (B, T, Kh), exact 2**e
    vscale = dfp.exp2i(cache["ve"][..., 0])
    return cache["k"], cache["v"], kscale, vscale


# ---------------------------------------------------------------------------
# kv_mx: int4 mantissas, one exponent per 32-token block per head
# ---------------------------------------------------------------------------
def _mx_init(lead, max_len, kh, hd, dtype):
    if max_len % MX_KV_BLOCK:
        raise ValueError(
            f"kv_mx needs max_len % {MX_KV_BLOCK} == 0, got {max_len}"
        )
    if hd % 2:
        raise ValueError(f"kv_mx packs head_dim nibble pairs; hd={hd} is odd")
    shape = (*lead, max_len, kh, hd // 2)
    eshape = (*lead, max_len // MX_KV_BLOCK, kh, 1)
    return {
        "k": jnp.zeros(shape, jnp.uint8),
        "v": jnp.zeros(shape, jnp.uint8),
        "ke": jnp.full(eshape, _MX_E_EMPTY, jnp.int8),
        "ve": jnp.full(eshape, _MX_E_EMPTY, jnp.int8),
    }


def _mx_token_exponent(x):
    """Per-token int4 exponent; all-zero tokens yield the empty sentinel so
    they never raise a block's shared exponent."""
    xf = x.astype(jnp.float32)
    max_abs = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    e = dfp.choose_exponent(max_abs, 4)
    return jnp.where(max_abs > 0, e, jnp.full_like(e, _MX_E_EMPTY))


def _mx_rescale(buf, e_old, e_new, smax):
    """Shift resident block mantissas to the (possibly raised) exponents."""
    shift = e_new - e_old  # (B, nb, Kh, 1) >= 0; 0 for untouched blocks
    blk_full = jnp.arange(smax) // MX_KV_BLOCK
    shift_pos = jnp.take(shift, blk_full, axis=1)  # (B, Smax, Kh, 1)
    codes = unpack_i4(buf).astype(jnp.float32)
    codes = codes * dfp.exp2i(-shift_pos)
    return jnp.clip(jnp.round(codes), -_MX_QMAX, _MX_QMAX)


def _mx_quantize_at(x, e_use):
    scaled = x.astype(jnp.float32) * dfp.exp2i(-e_use)
    return jnp.clip(jnp.round(scaled), -_MX_QMAX, _MX_QMAX)


def _mx_write_one_aligned(buf, ebuf, x, idx):
    b, smax = buf.shape[0], buf.shape[1]
    nb, s = ebuf.shape[1], x.shape[1]
    e_tok = _mx_token_exponent(x)  # (B, S, Kh, 1) int32
    gblk = ((idx + jnp.arange(s)) // MX_KV_BLOCK).astype(jnp.int32)  # (S,)
    # per-block running-max exponent: empty blocks come back iinfo.min from
    # segment_max and lose to the stored exponent
    e_in = jax.ops.segment_max(
        jnp.moveaxis(e_tok[..., 0], 1, 0), gblk, num_segments=nb
    )  # (nb, B, Kh)
    e_in = jnp.moveaxis(e_in, 0, 1)[..., None]
    e_old = ebuf.astype(jnp.int32)
    e_new = jnp.maximum(e_old, e_in)
    codes = _mx_rescale(buf, e_old, e_new, smax)
    e_use = jnp.take(e_new, gblk, axis=1)  # (B, S, Kh, 1)
    codes = jax.lax.dynamic_update_slice_in_dim(
        codes, _mx_quantize_at(x, e_use), idx, 1
    )
    return pack_i4(codes), e_new.astype(jnp.int8)


def _mx_write_one_masked(buf, ebuf, x, pos):
    b, smax = buf.shape[0], buf.shape[1]
    nb = ebuf.shape[1]
    e_tok = _mx_token_exponent(x)  # (B, 1, Kh, 1)
    blk = (pos // MX_KV_BLOCK).astype(jnp.int32)  # (B,)
    bmask = jnp.arange(nb)[None, :, None, None] == blk[:, None, None, None]
    e_old = ebuf.astype(jnp.int32)
    e_new = jnp.where(bmask, jnp.maximum(e_old, e_tok), e_old)
    codes = _mx_rescale(buf, e_old, e_new, smax)
    e_use = jnp.take_along_axis(e_new, blk[:, None, None, None], axis=1)
    smask = jnp.arange(smax)[None, :, None, None] == pos[:, None, None, None]
    codes = jnp.where(smask, _mx_quantize_at(x, e_use), codes)
    return pack_i4(codes), e_new.astype(jnp.int8)


def _mx_write_aligned(cache, k, v, idx):
    kb, ke = _mx_write_one_aligned(cache["k"], cache["ke"], k, idx)
    vb, ve = _mx_write_one_aligned(cache["v"], cache["ve"], v, idx)
    return {"k": kb, "v": vb, "ke": ke, "ve": ve}


def _mx_write_masked(cache, k, v, pos):
    kb, ke = _mx_write_one_masked(cache["k"], cache["ke"], k, pos)
    vb, ve = _mx_write_one_masked(cache["v"], cache["ve"], v, pos)
    return {"k": kb, "v": vb, "ke": ke, "ve": ve}


def _mx_view(cache):
    kscale = jnp.repeat(dfp.exp2i(cache["ke"][..., 0]), MX_KV_BLOCK, axis=1)
    vscale = jnp.repeat(dfp.exp2i(cache["ve"][..., 0]), MX_KV_BLOCK, axis=1)
    return unpack_i4(cache["k"]), unpack_i4(cache["v"]), kscale, vscale


register_kv_format(KVFormat(
    name="kv_bf16", mant_bits=16, seq_block=0,
    init=_bf16_init,
    write_aligned=_bf16_write_aligned, write_masked=_bf16_write_masked,
    attend_view=_bf16_view,
    bytes_per_token=lambda kh, hd: 2 * kh * hd * 2.0,
))

register_kv_format(KVFormat(
    name="kv_int8", mant_bits=8, seq_block=1,
    init=_int8_init,
    write_aligned=lambda c, k, v, i: _int8_write(c, k, v, i, _slice_write),
    write_masked=lambda c, k, v, p: _int8_write(c, k, v, p, _mask_write),
    attend_view=_int8_view,
    bytes_per_token=lambda kh, hd: 2 * (kh * hd + kh) * 1.0,
))

register_kv_format(KVFormat(
    name="kv_mx", mant_bits=4, seq_block=MX_KV_BLOCK,
    init=_mx_init,
    write_aligned=_mx_write_aligned, write_masked=_mx_write_masked,
    attend_view=_mx_view,
    bytes_per_token=lambda kh, hd: 2 * (kh * hd / 2 + kh / MX_KV_BLOCK),
))


# ---------------------------------------------------------------------------
# public entry points (what models/attention and the families call)
# ---------------------------------------------------------------------------
def init_cache(cfg, lead: Tuple[int, ...], max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """Allocate the kv leaves for one cache stack (``lead`` = (L, B) axes)."""
    fmt = get_kv_format(resolve_kv_fmt(cfg))
    return fmt.init(lead, max_len, cfg.n_kv_heads, cfg.hd(), dtype)


def write(fmt_name: str, cache: Dict[str, jax.Array], k: jax.Array,
          v: jax.Array, cache_index) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Quantize-on-write; returns (updated cache dict, valid lengths (B,))."""
    fmt = get_kv_format(fmt_name)
    b, s = k.shape[0], k.shape[1]
    if jnp.ndim(cache_index) == 0:
        new = fmt.write_aligned(cache, k, v, cache_index)
        valid = jnp.broadcast_to(cache_index + s, (b,))
    else:  # per-slot positions (continuous batching): S == 1
        new = fmt.write_masked(cache, k, v, cache_index)
        valid = cache_index + 1
    out = dict(cache)
    out.update(new)
    return out, valid


def attend_view(fmt_name: str, cache: Dict[str, jax.Array]):
    """(k codes, v codes, kscale, vscale) for the XLA fold-the-scales path."""
    return get_kv_format(fmt_name).attend_view(cache)


def cache_bytes(cache) -> int:
    """Total bytes of all kv leaves (the flash-decode read set per tick).

    Works on concrete arrays and ShapeDtypeStructs alike."""
    total = 0
    for leaf in jax.tree.leaves(cache):
        n = 1
        for d in leaf.shape:
            n *= int(d)
        total += n * jnp.dtype(leaf.dtype).itemsize
    return total
