"""Decoder-only LM covering the dense / MoE / VLM families.

Layers are stacked on a leading axis and driven by lax.scan (fast compiles at
80 layers, and the unit XLA overlaps FSDP all-gathers against).  Blocks are
optionally rematerialized.  gemma3-style 5:1 local:global attention is a
per-layer window array scanned alongside the params (window == S acts as
global).  KV caches are scan-carried (L, B, Smax, Kh, hd) arrays.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import kv_cache, layers, moe
from repro.models.layers import QuantCtx
from repro.parallel import sharding


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def window_schedule(cfg, seq_len: int) -> Optional[jax.Array]:
    """Per-layer attention window; None when the arch has no local layers."""
    if not cfg.sliding_window:
        return None
    ratio = cfg.local_global_ratio
    win = []
    for i in range(cfg.n_layers):
        is_global = ratio and ((i + 1) % (ratio + 1) == 0)
        win.append(seq_len + 1 if is_global else cfg.sliding_window)
    return jnp.asarray(win, jnp.int32)


def init_block(key, cfg, dtype) -> Dict[str, Any]:
    ka, km = jax.random.split(key)
    p = {
        "ln1": layers.init_rmsnorm(cfg.d_model, dtype),
        "attn": attn_lib.init_attention(ka, cfg, dtype),
        "ln2": layers.init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.n_experts:
        p["moe"] = moe.init_moe(km, cfg, dtype)
    else:
        p["mlp"] = layers.init_mlp(km, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_lm(key, cfg) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    ke, kb, kh = jax.random.split(key, 3)
    block_keys = jax.random.split(kb, cfg.n_layers)
    params = {
        "embed": layers.init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "blocks": _stack([init_block(k, cfg, dtype) for k in block_keys]),
        "final_norm": layers.init_rmsnorm(cfg.d_model, dtype),
        "lm_head": layers.init_dense_layer(kh, cfg.d_model, cfg.padded_vocab, False, dtype),
    }
    return params


def _block_apply(
    bp, x, positions, cfg, ctx: QuantCtx, window, cache=None, cache_index=None,
    attend_cache=False,
):
    # NOTE (Perf iteration B2, REFUTED): constraining the attention/MoE
    # sublayer outputs to seq-sharded here (Megatron-SP style) halves the
    # TP-pair all-reduce but forces a full KV re-gather in every layer's
    # attention -- net collective bytes DOUBLED (4.3 -> 9.2 GB/step on
    # grok x prefill_32k).  The per-block residual constrain in forward()
    # is the right granularity; sublayer outputs stay unconstrained.
    h = layers.rmsnorm(bp["ln1"], x, cfg.norm_eps)
    a, new_cache = attn_lib.attention(
        bp["attn"], h, positions, cfg, ctx, "blocks/attn",
        causal=True, window=window, cache=cache, cache_index=cache_index,
        attend_cache=attend_cache,
    )
    x = x + a
    h = layers.rmsnorm(bp["ln2"], x, cfg.norm_eps)
    if cfg.n_experts:
        x = x + moe.moe_layer(bp["moe"], h, "blocks/moe", cfg, ctx)
    else:
        x = x + layers.mlp(bp["mlp"], h, "blocks/mlp", ctx)
    return x, new_cache


def hidden(
    params,
    tokens: jax.Array,  # (B, S) int32
    cfg,
    ctx: QuantCtx,
    positions: Optional[jax.Array] = None,
    extra_embeds: Optional[jax.Array] = None,  # VLM: (B, n_vis, d) prepended
) -> jax.Array:
    x = layers.embed(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    if positions is None:
        positions = jnp.arange(s)
    win = window_schedule(cfg, s)

    def body(h, scanned):
        bp = scanned["p"]
        w = scanned.get("w")
        h = sharding.constrain(h, ("batch", "seq", None))
        h, _ = _block_apply(bp, h, positions, cfg, ctx, w)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    scanned = {"p": params["blocks"]}
    if win is not None:
        scanned["w"] = win
    x, _ = jax.lax.scan(body, x, scanned)
    return layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def forward(params, tokens, cfg, ctx: QuantCtx, positions=None, extra_embeds=None):
    x = hidden(params, tokens, cfg, ctx, positions, extra_embeds)
    return layers.dense(params["lm_head"], x, "lm_head", ctx)


def loss_fn(params, batch, cfg, ctx: QuantCtx) -> jax.Array:
    x = hidden(
        params, batch["tokens"], cfg, ctx,
        positions=batch.get("positions"),
        extra_embeds=batch.get("extra_embeds"),
    )
    labels = batch["labels"]
    if x.shape[1] != labels.shape[1]:  # VLM: loss on the text tail only
        x = x[:, -labels.shape[1] :]
    return layers.lm_head_loss(
        params["lm_head"], x, labels, cfg.vocab, "lm_head", ctx
    )


# ---------------------------------------------------------------------------
# KV-cache serving path
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Registered kv-format leaves stacked (L, B, Smax, ...); see
    ``models/kv_cache.py`` (``cfg.kv_fmt`` knob, ``kv_bits==8`` back-compat)."""
    return kv_cache.init_cache(cfg, (cfg.n_layers, batch), max_len, dtype)


# leaf names the kv formats may allocate, in scan-carry order
KV_LEAF_NAMES = ("k", "v", "ke", "ve")


def _cache_scan(params, x, positions, cfg, ctx, cache, cache_index, win,
                attend_cache=False):
    kv_keys = [n for n in KV_LEAF_NAMES if n in cache]

    def body(h, scanned):
        bp = scanned["p"]
        w = scanned.get("w")
        c = {n: scanned[n] for n in kv_keys}
        h, new = _block_apply(
            bp, h, positions, cfg, ctx, w, cache=c, cache_index=cache_index,
            attend_cache=attend_cache,
        )
        return h, {n: new[n] for n in kv_keys}

    scanned = {"p": params["blocks"]}
    scanned.update({k: v for k, v in cache.items()})
    if win is not None:
        scanned["w"] = win
    x, new_cache = jax.lax.scan(body, x, scanned)
    return x, new_cache


def prefill(params, tokens, cfg, ctx: QuantCtx, cache, extra_embeds=None):
    """Fill the cache with S tokens; returns (last-token logits, cache)."""
    x = layers.embed(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s)
    win = window_schedule(cfg, cache["k"].shape[2])
    x, cache = _cache_scan(params, x, positions, cfg, ctx, cache, jnp.int32(0), win)
    x = layers.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return layers.dense(params["lm_head"], x, "lm_head", ctx), cache


def prefill_chunk(params, tokens, start, cfg, ctx: QuantCtx, cache):
    """Consume one chunk of a prompt against a partially-filled cache.

    ``tokens`` (B, S) land at cache positions [start, start + S); attention
    runs over the WHOLE cache (``attend_cache``), so chunks after the first
    see every earlier chunk of the same prompt.  ``start`` is a traced
    scalar -- the graph compiles once per chunk LENGTH, never per offset.
    Returns (last-token logits, cache); only the final chunk's logits are
    meaningful to a caller sampling the first generated token.
    """
    x = layers.embed(params["embed"], tokens)
    s = x.shape[1]
    positions = start + jnp.arange(s)
    if cfg.mrope:  # text-only serving prompt: all three components temporal
        positions = jnp.broadcast_to(
            positions[None, None, :], (3, tokens.shape[0], s)
        )
    win = window_schedule(cfg, cache["k"].shape[2])
    x, cache = _cache_scan(
        params, x, positions, cfg, ctx, cache, start, win, attend_cache=True
    )
    x = layers.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return layers.dense(params["lm_head"], x, "lm_head", ctx), cache


def decode_step(params, token, pos, cfg, ctx: QuantCtx, cache):
    """One decode step. token (B, 1) int32; pos scalar OR per-slot (B,)."""
    x = layers.embed(params["embed"], token)
    if jnp.ndim(pos) == 1:
        positions = pos[:, None].astype(jnp.int32)
    else:
        positions = jnp.full((token.shape[0], 1), pos, jnp.int32)
    if cfg.mrope:
        positions = jnp.broadcast_to(positions, (3, *positions.shape))
    win = window_schedule(cfg, cache["k"].shape[2])
    x, cache = _cache_scan(params, x, positions, cfg, ctx, cache, pos, win)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return layers.dense(params["lm_head"], x, "lm_head", ctx), cache
