"""Zamba2-style hybrid: Mamba2 backbone with shared attention blocks.

Layer plan for n_layers Mamba2 blocks with period P and n_shared shared
transformer blocks: after every P-th Mamba block one of the shared blocks
(alternating) runs with its OWN KV history but SHARED weights -- the zamba2
parameter-sharing trick.  n_layers = n_super * P + tail.

Scan structure: outer scan over superblocks (P stacked Mamba2 layers + one
shared-attention application), then a tail scan.  Shared-block weights are
selected inside the scan with a jnp.where tree (no 13x weight copies).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import kv_cache, layers, ssm
from repro.models.layers import QuantCtx
from repro.parallel import sharding


def plan(cfg) -> Tuple[int, int, int]:
    p = cfg.shared_attn_period or 6
    n_super = cfg.n_layers // p
    tail = cfg.n_layers - n_super * p
    return n_super, p, tail


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _init_mamba_block(key, cfg, dtype):
    return {
        "norm": layers.init_rmsnorm(cfg.d_model, dtype),
        "mamba": ssm.init_mamba(key, cfg, dtype),
    }


def _init_shared_block(key, cfg, dtype):
    ka, km = jax.random.split(key)
    return {
        "ln1": layers.init_rmsnorm(cfg.d_model, dtype),
        "attn": attn_lib.init_attention(ka, cfg, dtype),
        "ln2": layers.init_rmsnorm(cfg.d_model, dtype),
        "mlp": layers.init_mlp(km, cfg.d_model, cfg.d_ff, dtype),
    }


def init_hybrid(key, cfg) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    n_super, p, tail = plan(cfg)
    ke, km, kt, ks, kh = jax.random.split(key, 5)
    mkeys = jax.random.split(km, max(n_super * p, 1))
    tkeys = jax.random.split(kt, max(tail, 1))
    skeys = jax.random.split(ks, cfg.n_shared_blocks)
    params = {
        "embed": layers.init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "mamba_stack": _stack(
            [_init_mamba_block(k, cfg, dtype) for k in mkeys[: n_super * p]]
        ),
        "shared": _stack([_init_shared_block(k, cfg, dtype) for k in skeys]),
        "final_norm": layers.init_rmsnorm(cfg.d_model, dtype),
        "lm_head": layers.init_dense_layer(kh, cfg.d_model, cfg.padded_vocab, False, dtype),
    }
    if tail:
        params["tail_stack"] = _stack([_init_mamba_block(k, cfg, dtype) for k in tkeys])
    return params


def _select_shared(shared, idx):
    """Alternate between the n_shared stacked blocks without copying."""
    n = jax.tree.leaves(shared)[0].shape[0]
    sel = idx % n
    return jax.tree.map(lambda leaf: leaf[sel], shared)


def _mamba_block(bp, x, cfg, ctx):
    h = layers.rmsnorm(bp["norm"], x, cfg.norm_eps)
    return x + ssm.mamba2_seq(bp["mamba"], h, cfg, ctx, "mamba")


def _shared_block(sp, x, positions, cfg, ctx, cache=None, cache_index=None):
    h = layers.rmsnorm(sp["ln1"], x, cfg.norm_eps)
    a, new_cache = attn_lib.attention(
        sp["attn"], h, positions, cfg, ctx, "shared/attn",
        causal=True, cache=cache, cache_index=cache_index,
    )
    x = x + a
    h = layers.rmsnorm(sp["ln2"], x, cfg.norm_eps)
    x = x + layers.mlp(sp["mlp"], h, "shared/mlp", ctx)
    return x, new_cache


def hidden(params, tokens, cfg, ctx: QuantCtx, positions=None) -> jax.Array:
    n_super, p, tail = plan(cfg)
    x = layers.embed(params["embed"], tokens)
    s = x.shape[1]
    if positions is None:
        positions = jnp.arange(s)

    def reshaped(stack, n, per):
        return jax.tree.map(lambda l: l.reshape(n, per, *l.shape[1:]), stack)

    def super_body(carry, scanned):
        x = sharding.constrain(carry, ("batch", "seq", None))
        mp, idx = scanned["m"], scanned["i"]

        def inner(h, bp):
            return _mamba_block(bp, h, cfg, ctx), None

        inner_fn = jax.checkpoint(inner) if cfg.remat else inner
        x, _ = jax.lax.scan(inner_fn, x, mp)
        sp = _select_shared(params["shared"], idx)
        x, _ = _shared_block(sp, x, positions, cfg, ctx)
        return x, None

    if n_super:
        scanned = {
            "m": reshaped(params["mamba_stack"], n_super, p),
            "i": jnp.arange(n_super),
        }
        x, _ = jax.lax.scan(super_body, x, scanned)

    if tail:
        def tail_body(h, bp):
            h = sharding.constrain(h, ("batch", "seq", None))
            return _mamba_block(bp, h, cfg, ctx), None

        tail_fn = jax.checkpoint(tail_body) if cfg.remat else tail_body
        x, _ = jax.lax.scan(tail_fn, x, params["tail_stack"])

    return layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def forward(params, tokens, cfg, ctx: QuantCtx, positions=None) -> jax.Array:
    x = hidden(params, tokens, cfg, ctx, positions)
    return layers.dense(params["lm_head"], x, "lm_head", ctx)


def loss_fn(params, batch, cfg, ctx: QuantCtx) -> jax.Array:
    x = hidden(params, batch["tokens"], cfg, ctx)
    return layers.lm_head_loss(
        params["lm_head"], x, batch["labels"], cfg.vocab, "lm_head", ctx
    )


# ---------------------------------------------------------------------------
# Decode path: per-layer SSM states + per-superblock KV caches.
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    n_super, p, tail = plan(cfg)
    sstate = ssm.init_ssm_state(cfg, batch)
    def stacked(n):
        return jax.tree.map(lambda l: jnp.zeros((n, *l.shape), l.dtype), sstate)
    cache = {"ssm": stacked(n_super * p)}
    # per-superblock KV through the same registered formats as transformer
    # (kv_bits / kv_fmt were silently ignored here before the registry)
    cache.update(kv_cache.init_cache(cfg, (n_super, batch), max_len, dtype))
    if tail:
        cache["ssm_tail"] = stacked(tail)
    return cache


def decode_step(params, token, pos, cfg, ctx: QuantCtx, cache):
    n_super, p, tail = plan(cfg)
    x = layers.embed(params["embed"], token)
    if jnp.ndim(pos) == 1:
        positions = pos[:, None].astype(jnp.int32)
    else:
        positions = jnp.full((token.shape[0], 1), pos, jnp.int32)

    def reshaped(stack, n, per):
        return jax.tree.map(lambda l: l.reshape(n, per, *l.shape[1:]), stack)

    kv_keys = [n for n in ("k", "v", "ke", "ve") if n in cache]

    def super_body(carry, scanned):
        x = carry
        mp, states, idx = scanned["m"], scanned["s"], scanned["i"]

        def inner(h, sc):
            bp, st = sc
            hin = layers.rmsnorm(bp["norm"], h, cfg.norm_eps)
            out, new_st = ssm.mamba2_step(bp["mamba"], hin, st, cfg, ctx, "mamba")
            return h + out, new_st

        x, new_states = jax.lax.scan(inner, x, (mp, states))
        sp = _select_shared(params["shared"], idx)
        c = {n: scanned[n] for n in kv_keys}
        x, new_kv = _shared_block(sp, x, positions, cfg, ctx, c, pos)
        return x, {"s": new_states, **{n: new_kv[n] for n in kv_keys}}

    if n_super:
        scanned = {
            "m": reshaped(params["mamba_stack"], n_super, p),
            "s": reshaped(cache["ssm"], n_super, p),
            "i": jnp.arange(n_super),
            **{n: cache[n] for n in kv_keys},
        }
        x, upd = jax.lax.scan(super_body, x, scanned)
        cache = dict(cache)
        cache["ssm"] = jax.tree.map(
            lambda l: l.reshape(n_super * p, *l.shape[2:]), upd["s"]
        )
        for n in kv_keys:
            cache[n] = upd[n]

    if tail:
        def tail_body(h, sc):
            bp, st = sc
            hin = layers.rmsnorm(bp["norm"], h, cfg.norm_eps)
            out, new_st = ssm.mamba2_step(bp["mamba"], hin, st, cfg, ctx, "mamba")
            return h + out, new_st

        x, new_tail = jax.lax.scan(tail_body, x, (params["tail_stack"], cache["ssm_tail"]))
        cache["ssm_tail"] = new_tail

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return layers.dense(params["lm_head"], x, "lm_head", ctx), cache
