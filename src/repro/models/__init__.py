"""Model zoo: pure-pytree implementations of the assigned families."""
from repro.models.layers import QuantCtx
from repro.models.model_zoo import (
    ModelApi,
    build_model,
    input_specs,
    load_servable,
    make_ctx,
    make_smoke_batch,
    quantize_and_plan,
    quantize_model_params,
    save_servable,
)
