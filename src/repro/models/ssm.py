"""Selective state-space blocks: Mamba1 (falcon-mamba) and Mamba2 (zamba2).

Sequence processing uses a chunked scan: lax.scan over chunks carrying only
the recurrent state, with the chunk body checkpointed -- O(S/chunk) state
checkpoints instead of O(S), which is what lets the 500k-token cell fit.

The paper's quantization applies to the in/out/x/dt projections (~87% of SSM
params); the scan itself is elementwise (no MAC budget to trade), so A, D,
conv and dt biases stay in higher precision per the policy
(DESIGN.md Sec. "Arch-applicability").
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import QuantCtx, dense


def _dt_rank(cfg) -> int:
    return max(1, -(-cfg.d_model // 16))


def _fit_chunk(s: int, want: int) -> int:
    """Largest divisor of s that is <= want (scan chunk length)."""
    c = min(s, want)
    while s % c:
        c -= 1
    return c


def d_inner(cfg) -> int:
    return cfg.ssm_expand * cfg.d_model


def init_mamba(key, cfg, dtype) -> Dict[str, Any]:
    di, ds, rank = d_inner(cfg), cfg.ssm_state, _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {
        "in_proj": layers.init_dense_layer(ks[0], cfg.d_model, 2 * di, False, dtype),
        "out_proj": layers.init_dense_layer(ks[1], di, cfg.d_model, False, dtype),
        "conv_w": jax.random.normal(ks[2], (cfg.ssm_conv, di), dtype) * 0.1,
        "conv_b": jnp.zeros((di,), dtype),
        "D": jnp.ones((di,), jnp.float32),
    }
    if cfg.ssm_version == 1:
        p["x_proj"] = layers.init_dense_layer(ks[3], di, rank + 2 * ds, False, dtype)
        p["dt_proj"] = layers.init_dense_layer(ks[4], rank, di, True, dtype)
        p["A_log"] = jnp.log(
            jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
        )
    else:  # mamba2: scalar A per head, B/C projected from the block input
        nh = cfg.ssm_heads or di // 64
        p["bc_proj"] = layers.init_dense_layer(ks[3], cfg.d_model, 2 * ds, False, dtype)
        p["dt_bias"] = jnp.zeros((nh,), jnp.float32)
        p["A_log"] = jnp.zeros((nh,), jnp.float32)
        p["norm"] = layers.init_rmsnorm(di, dtype)
    return p


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with kernel (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):  # small static K (4): unrolled taps
        out = out + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i].astype(
            jnp.float32
        )
    return (out + b.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba1 selective scan (chunked)
# ---------------------------------------------------------------------------
def _m1_chunk(h0, xs):
    """h: (B, di, ds); xs per-step tensors stacked over chunk axis."""

    def step(h, inp):
        dt, bmat, cmat, xv, a = inp  # dt (B,di), b/c (B,ds), xv (B,di), a (di,ds)
        da = jnp.exp(dt[..., None] * a)  # (B, di, ds)
        h = da * h + (dt * xv)[..., None] * bmat[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, cmat)
        return h, y

    return jax.lax.scan(step, h0, xs)


def mamba1_seq(p, x: jax.Array, cfg, ctx: QuantCtx, path: str, chunk: int = 64):
    """Full-sequence Mamba1. x (B,S,d) -> (B,S,d)."""
    b, s, d = x.shape
    di, ds, rank = d_inner(cfg), cfg.ssm_state, _dt_rank(cfg)
    xz = dense(p["in_proj"], x, f"{path}/in_proj", ctx)
    xv, z = jnp.split(xz, 2, axis=-1)
    xv = jax.nn.silu(_causal_conv(xv, p["conv_w"], p["conv_b"]))

    dbc = dense(p["x_proj"], xv, f"{path}/x_proj", ctx)
    dt_in, bmat, cmat = jnp.split(dbc, [rank, rank + ds], axis=-1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt_in, f"{path}/dt_proj", ctx))
    a = -jnp.exp(p["A_log"])  # (di, ds)

    dtf = dt.astype(jnp.float32)
    xvf = xv.astype(jnp.float32)
    bf = bmat.astype(jnp.float32)
    cf = cmat.astype(jnp.float32)

    chunk = _fit_chunk(s, chunk)
    n_chunks = s // chunk

    def outer(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * chunk, chunk, 1)
        xs = (
            jnp.moveaxis(sl(dtf), 1, 0),
            jnp.moveaxis(sl(bf), 1, 0),
            jnp.moveaxis(sl(cf), 1, 0),
            jnp.moveaxis(sl(xvf), 1, 0),
            jnp.broadcast_to(a, (chunk, *a.shape)),
        )
        h, ys = jax.checkpoint(_m1_chunk)(h, xs)
        return h, jnp.moveaxis(ys, 0, 1)  # (B, chunk, di)

    h0 = jnp.zeros((b, di, ds), jnp.float32)
    _, ys = jax.lax.scan(outer, h0, jnp.arange(n_chunks))
    # ys: (n_chunks, B, chunk, di) -> (B, S, di)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, di)
    y = (y + xvf * p["D"]) * jax.nn.silu(z.astype(jnp.float32))
    return dense(p["out_proj"], y.astype(x.dtype), f"{path}/out_proj", ctx)


def mamba1_step(p, x: jax.Array, state, cfg, ctx: QuantCtx, path: str):
    """Single-token decode. x (B,1,d); state = {'h': (B,di,ds), 'conv': (B,K-1,di)}."""
    b = x.shape[0]
    di, ds, rank = d_inner(cfg), cfg.ssm_state, _dt_rank(cfg)
    xz = dense(p["in_proj"], x[:, 0], f"{path}/in_proj", ctx)
    xv, z = jnp.split(xz, 2, axis=-1)

    conv_buf = jnp.concatenate([state["conv"], xv[:, None, :]], axis=1)  # (B,K,di)
    w = p["conv_w"]
    xc = jnp.einsum("bkd,kd->bd", conv_buf.astype(jnp.float32), w.astype(jnp.float32))
    xv = jax.nn.silu(xc + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    new_conv = conv_buf[:, 1:]

    dbc = dense(p["x_proj"], xv, f"{path}/x_proj", ctx)
    dt_in, bmat, cmat = jnp.split(dbc, [rank, rank + ds], axis=-1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt_in, f"{path}/dt_proj", ctx)).astype(
        jnp.float32
    )
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt[..., None] * a)
    h = da * state["h"] + (dt * xv.astype(jnp.float32))[..., None] * bmat.astype(
        jnp.float32
    )[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, cmat.astype(jnp.float32))
    y = (y + xv.astype(jnp.float32) * p["D"]) * jax.nn.silu(z.astype(jnp.float32))
    out = dense(p["out_proj"], y[:, None].astype(x.dtype), f"{path}/out_proj", ctx)
    return out, {"h": h, "conv": new_conv}


# ---------------------------------------------------------------------------
# Mamba2 (SSD: scalar decay per head)
# ---------------------------------------------------------------------------
def _m2_heads(cfg) -> Tuple[int, int]:
    nh = cfg.ssm_heads or d_inner(cfg) // 64
    return nh, d_inner(cfg) // nh


def _m2_chunk(h0, xs):
    def step(h, inp):
        # da (B,H); dtx (B,H,hd) = dt*x; b/c (B,ds).  The (hd x ds) outer
        # product h-update is formed per step -- NEVER materialized over S.
        da, dtx, b, c = inp
        h = da[..., None, None] * h + dtx[..., None] * b[:, None, None, :]
        y = jnp.einsum("bhds,bs->bhd", h, c)
        return h, y

    return jax.lax.scan(step, h0, xs)


def mamba2_seq(p, x: jax.Array, cfg, ctx: QuantCtx, path: str, chunk: int = 64):
    b, s, d = x.shape
    di, ds = d_inner(cfg), cfg.ssm_state
    nh, hd = _m2_heads(cfg)
    xz = dense(p["in_proj"], x, f"{path}/in_proj", ctx)
    xv, z = jnp.split(xz, 2, axis=-1)
    xv = jax.nn.silu(_causal_conv(xv, p["conv_w"], p["conv_b"]))
    bc = dense(p["bc_proj"], x, f"{path}/bc_proj", ctx)
    bmat, cmat = jnp.split(bc, 2, axis=-1)  # (B,S,ds) each

    a = -jnp.exp(p["A_log"])  # (H,)
    # dt derived from x magnitude per head (simplified SSD discretization)
    xh = xv.reshape(b, s, nh, hd).astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.mean(xh, axis=-1) + p["dt_bias"][None, None, :]
    )  # (B,S,H)
    da = jnp.exp(dt * a[None, None, :])  # (B,S,H)
    dtx = dt[..., None] * xh  # (B,S,H,hd)

    chunk = _fit_chunk(s, chunk)
    n_chunks = s // chunk

    def outer(h, idx):
        sl = lambda t, ax=1: jax.lax.dynamic_slice_in_dim(t, idx * chunk, chunk, ax)
        xs = (
            jnp.moveaxis(sl(da), 1, 0),
            jnp.moveaxis(sl(dtx), 1, 0),
            jnp.moveaxis(sl(bmat.astype(jnp.float32)), 1, 0),
            jnp.moveaxis(sl(cmat.astype(jnp.float32)), 1, 0),
        )
        h, ys = jax.checkpoint(_m2_chunk)(h, xs)
        return h, ys

    h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)
    _, ys = jax.lax.scan(outer, h0, jnp.arange(n_chunks))
    # ys: (n_chunks, chunk, B, H, hd) -> (B, S, di)
    y = jnp.moveaxis(ys.reshape(n_chunks * chunk, b, nh, hd), 0, 1).reshape(b, s, di)
    y = y + xv.astype(jnp.float32) * p["D"]
    y = layers.rmsnorm(p["norm"], y.astype(x.dtype), cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return dense(p["out_proj"], y, f"{path}/out_proj", ctx)


def mamba2_step(p, x: jax.Array, state, cfg, ctx: QuantCtx, path: str):
    b = x.shape[0]
    di, ds = d_inner(cfg), cfg.ssm_state
    nh, hd = _m2_heads(cfg)
    xz = dense(p["in_proj"], x[:, 0], f"{path}/in_proj", ctx)
    xv, z = jnp.split(xz, 2, axis=-1)
    conv_buf = jnp.concatenate([state["conv"], xv[:, None, :]], axis=1)
    xc = jnp.einsum(
        "bkd,kd->bd", conv_buf.astype(jnp.float32), p["conv_w"].astype(jnp.float32)
    )
    xv = jax.nn.silu(xc + p["conv_b"].astype(jnp.float32))
    bc = dense(p["bc_proj"], x[:, 0], f"{path}/bc_proj", ctx)
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    a = -jnp.exp(p["A_log"])
    xh = xv.reshape(b, nh, hd)
    dt = jax.nn.softplus(jnp.mean(xh, axis=-1) + p["dt_bias"][None, :])  # (B,H)
    da = jnp.exp(dt * a[None, :])[..., None, None]
    dbx = (dt[..., None] * xh)[..., None] * bmat.astype(jnp.float32)[:, None, None, :]
    h = da * state["h"] + dbx
    y = jnp.einsum("bhds,bs->bhd", h, cmat.astype(jnp.float32)).reshape(b, di)
    y = y + xv * p["D"]
    y = layers.rmsnorm(p["norm"], y.astype(x.dtype), cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = dense(p["out_proj"], y[:, None], f"{path}/out_proj", ctx)
    return out, {"h": h, "conv": conv_buf[:, 1:]}


def init_ssm_state(cfg, batch: int) -> Dict[str, jax.Array]:
    di, ds = d_inner(cfg), cfg.ssm_state
    conv = jnp.zeros((batch, cfg.ssm_conv - 1, di), jnp.float32)
    if cfg.ssm_version == 1:
        return {"h": jnp.zeros((batch, di, ds), jnp.float32), "conv": conv}
    nh, hd = _m2_heads(cfg)
    return {"h": jnp.zeros((batch, nh, hd, ds), jnp.float32), "conv": conv}
