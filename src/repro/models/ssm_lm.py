"""Attention-free Mamba1 LM (falcon-mamba-7b family)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers, ssm
from repro.models.layers import QuantCtx
from repro.parallel import sharding


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _init_block(key, cfg, dtype):
    return {
        "norm": layers.init_rmsnorm(cfg.d_model, dtype),
        "mamba": ssm.init_mamba(key, cfg, dtype),
    }


def init_ssm_lm(key, cfg) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    ke, kb, kh = jax.random.split(key, 3)
    bkeys = jax.random.split(kb, cfg.n_layers)
    return {
        "embed": layers.init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "blocks": _stack([_init_block(k, cfg, dtype) for k in bkeys]),
        "final_norm": layers.init_rmsnorm(cfg.d_model, dtype),
        "lm_head": layers.init_dense_layer(kh, cfg.d_model, cfg.padded_vocab, False, dtype),
    }


def hidden(params, tokens, cfg, ctx: QuantCtx) -> jax.Array:
    x = layers.embed(params["embed"], tokens)

    def body(h, bp):
        h = sharding.constrain(h, ("batch", "seq", None))
        hin = layers.rmsnorm(bp["norm"], h, cfg.norm_eps)
        return h + ssm.mamba1_seq(bp["mamba"], hin, cfg, ctx, "mamba"), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"])
    return layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def forward(params, tokens, cfg, ctx: QuantCtx, positions=None) -> jax.Array:
    x = hidden(params, tokens, cfg, ctx)
    return layers.dense(params["lm_head"], x, "lm_head", ctx)


def loss_fn(params, batch, cfg, ctx: QuantCtx) -> jax.Array:
    x = hidden(params, batch["tokens"], cfg, ctx)
    return layers.lm_head_loss(
        params["lm_head"], x, batch["labels"], cfg.vocab, "lm_head", ctx
    )


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    del max_len, dtype  # SSM state is O(1) in context length
    st = ssm.init_ssm_state(cfg, batch)
    return {"ssm": jax.tree.map(lambda l: jnp.zeros((cfg.n_layers, *l.shape), l.dtype), st)}


def decode_step(params, token, pos, cfg, ctx: QuantCtx, cache):
    del pos  # recurrent state carries position implicitly
    x = layers.embed(params["embed"], token)

    def body(h, sc):
        bp, st = sc
        hin = layers.rmsnorm(bp["norm"], h, cfg.norm_eps)
        out, new_st = ssm.mamba1_step(bp["mamba"], hin, st, cfg, ctx, "mamba")
        return h + out, new_st

    x, new_states = jax.lax.scan(body, x, (params["blocks"], cache["ssm"]))
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return layers.dense(params["lm_head"], x, "lm_head", ctx), {"ssm": new_states}
