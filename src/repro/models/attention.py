"""Grouped-query attention with the flavours the assigned archs need:
qk-norm (qwen3), qkv-bias (qwen1.5), M-RoPE (qwen2-vl), sliding-window local
layers (gemma3 5:1), cross-attention (whisper), KV-cache decode.

Training/prefill uses an online-softmax chunked formulation (flash-attention
scheme at the XLA level): KV is scanned in blocks with running max/sum so the
S x S score matrix is never materialized -- this is what keeps the roofline
memory term linear in S.

The decode KV cache is a registered block format (``models/kv_cache.py``:
kv_bf16 / kv_int8 / kv_mx) quantized on write.  Two read paths exist:

  * the XLA fold-the-scales path (``_attend_dense``): per-token power-of-two
    scales fold into the score/probability tensors, so the dequantized
    cache never materializes.  This is the oracle and the portable default.
  * the Pallas flash kernel (``kernels/flash_prefill.py::flash_attend``):
    loads the *packed* leaves and dequantizes tile-by-tile in VMEM -- one
    HBM pass over the packed bytes.  ``cfg.flash_decode`` routes S == 1
    steps; ``cfg.flash_prefill`` routes S > 1 cache-attends (chunked
    prefill) and the in-chunk self-attention tail.  Both are serving-time
    knobs (the kernel has no VJP) and fall back to the oracle whenever a
    multi-device activation mesh is installed -- a pallas_call cannot read
    a kv-head- or sequence-sharded (KV_SEQ_SHARD) cache correctly, so the
    bypass is structural, not best-effort.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import kv_cache, layers
from repro.models.layers import QuantCtx, dense

NEG_INF = -1e30


def init_attention(key, cfg, dtype, cross: bool = False) -> dict:
    hd = cfg.hd()
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": layers.init_dense_layer(kq, cfg.d_model, cfg.n_heads * hd, cfg.qkv_bias, dtype),
        "wk": layers.init_dense_layer(kk, cfg.d_model, cfg.n_kv_heads * hd, cfg.qkv_bias, dtype),
        "wv": layers.init_dense_layer(kv, cfg.d_model, cfg.n_kv_heads * hd, cfg.qkv_bias, dtype),
        "wo": layers.init_dense_layer(ko, cfg.n_heads * hd, cfg.d_model, False, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rmsnorm(hd, dtype)
        p["k_norm"] = layers.init_rmsnorm(hd, dtype)
    return p


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], n, x.shape[-1] // n)


def _mask_bias(
    q_pos: jax.Array,  # (S,) or (B, S)
    k_pos: jax.Array,  # (T,)
    causal: bool,
    window: Optional[int],
    valid_len: Optional[jax.Array] = None,  # (B,) cache fill level
) -> jax.Array:
    """Additive mask (..., S, T)."""
    qp = q_pos[..., :, None].astype(jnp.int32)
    kp = k_pos[None, :].astype(jnp.int32)
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= qp - kp < window
    if valid_len is not None:
        ok &= kp < valid_len[:, None, None]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _attend_dense(q, k, v, bias, kscale=None, vscale=None):
    """q (B,S,Kh,G,hd), k/v (B,T,Kh,hd), bias broadcastable to (B,Kh,G,S,T).

    Grouped-KV layout: used on the decode path where the score tensor is
    (..., 1, T) and repeating KV would blow up cache traffic.

    kscale/vscale: optional per-token cache scales (B,T,Kh) -- exact powers
    of two from the kv format's exponent planes.  They are folded into the
    score/probability tensors so the dequantized cache is never
    materialized (``kv_cache.attend_view`` supplies integer codes).
    """
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bskgh,btkh->bkgst", q.astype(jnp.float32), k.astype(jnp.float32))
    if kscale is not None:  # fold key scales into the scores
        s = s * kscale.transpose(0, 2, 1)[:, :, None, None, :]
    s = s * scale + bias
    p = jax.nn.softmax(s, axis=-1)
    if vscale is not None:  # fold value scales into the probabilities
        p = p * vscale.transpose(0, 2, 1)[:, :, None, None, :]
    out = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return out


def _attend_dense_mha(q, k, v, bias):
    """Full-head layout: q/k/v (B,S|T,H,hd); bias (..., S, T).  KV heads are
    pre-repeated so the head axis shards over 'model' (Kh alone often does
    not divide the TP width, e.g. 8 kv heads on 16-way TP)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale + bias
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))


def _attend_chunked(q, k, v, q_pos, causal, window, chunk: int):
    """Online-softmax over KV chunks (flash-attention scheme at XLA level).

    q (B,S,H,hd); k/v (B,T,H,hd) (KV pre-repeated to full heads).  Only the
    (m, l, acc) carries survive a chunk; scores/probs are recomputed in the
    backward pass (jax.checkpoint).  T need not divide the chunk size: the
    trailing T % chunk tokens run as one final partial chunk instead of
    silently falling back to the O(S*T)-materializing dense path.
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    scale = hd**-0.5
    qf = q.astype(jnp.float32) * scale
    n_full, rem = divmod(t, chunk)

    def step(carry, ks, vs, k_pos):
        m, l, acc = carry
        bias = _mask_bias(q_pos, k_pos, causal, window)  # (S, c) or (B,S,c)
        bias = bias[None] if bias.ndim == 2 else bias[:, None]
        sc = jnp.einsum("bshd,bthd->bhst", qf, ks.astype(jnp.float32))
        sc = sc + bias  # (B,H,S,c)
        m_new = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        upd = jnp.einsum("bhst,bthd->bshd", p, vs.astype(jnp.float32))
        acc_new = acc * corr.transpose(0, 2, 1)[..., None] + upd
        return m_new, l_new, acc_new

    def body(carry, idx):
        ks = jax.lax.dynamic_slice_in_dim(k, idx * chunk, chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, 1)
        k_pos = idx * chunk + jnp.arange(chunk)
        return step(carry, ks, vs, k_pos), None

    carry = (
        jnp.full((b, h, s), NEG_INF, jnp.float32),
        jnp.zeros((b, h, s), jnp.float32),
        jnp.zeros((b, s, h, hd), jnp.float32),
    )
    if n_full:
        carry, _ = jax.lax.scan(
            jax.checkpoint(body), carry, jnp.arange(n_full)
        )
    if rem:  # final partial chunk (static shape: compiled once per length)
        carry = step(
            carry, k[:, n_full * chunk:], v[:, n_full * chunk:],
            n_full * chunk + jnp.arange(rem),
        )
    m, l, acc = carry
    denom = jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return acc / denom


def _flash_routable() -> bool:
    """The flash kernels assume every packed cache leaf is whole per device.

    Under a multi-device activation mesh the cache is kv-head-sharded --
    or sequence-sharded when ``KV_SEQ_SHARD`` kicks in (GQA head counts
    that do not divide the TP width) -- and a pallas_call is not
    partitionable over either axis, so routing falls back to the XLA
    oracle, which shards correctly.  Single-device (or no) mesh: route."""
    from repro.parallel import sharding as _sh

    mesh = _sh._ACT_MESH[0]
    return mesh is None or mesh.size == 1


def _win_arg(window) -> jax.Array:
    return jnp.asarray(
        2**30 if window is None else window, jnp.int32
    ).reshape(1, 1)


def _flash_cache_path(q, cache, fmt, q_pos, valid, window, cfg):
    """Route an S >= 1 cache-attend through the packed-cache Pallas kernel.

    S == 1 is the flash-decode step; S > 1 is a prefill chunk, whose rows
    the kernel assumes CONTIGUOUS from q_pos's first entry -- exactly what
    ``transformer.prefill_chunk`` traces (start + arange(S))."""
    from repro.kernels.flash_prefill import flash_attend

    b, s = q.shape[0], q.shape[1]
    hd = cfg.hd()
    kh = cfg.n_kv_heads
    g = cfg.n_heads // kh
    qf = q.reshape(b, s, kh, g, hd).astype(jnp.float32)
    if q_pos.ndim == 2:  # (B, S) per-row positions
        qs = q_pos[:, 0]
    else:  # (S,) traced positions shared by every row
        qs = jnp.broadcast_to(q_pos.reshape(-1)[0], (b,))
    out = flash_attend(
        qf, cache["k"], cache["v"], cache.get("ke"), cache.get("ve"),
        qs.astype(jnp.int32).reshape(b, 1),
        valid.astype(jnp.int32).reshape(b, 1),
        _win_arg(window), fmt=fmt,
    )
    return out.reshape(b, s, cfg.n_heads * hd)


def _flash_self_path(q, k, v, window, cfg):
    """In-chunk self-attention tail through the flash kernel.

    The chunk's own just-projected bf16 K/V stand in for a packed cache
    (fmt="kv_bf16"): positions are chunk-relative (causality and window
    distance are offset-invariant within one chunk), fill level is the
    whole chunk."""
    from repro.kernels.flash_prefill import flash_attend

    b, s = q.shape[0], q.shape[1]
    hd = cfg.hd()
    kh = cfg.n_kv_heads
    g = cfg.n_heads // kh
    qf = q.reshape(b, s, kh, g, hd).astype(jnp.float32)
    out = flash_attend(
        qf, k, v, None, None,
        jnp.zeros((b, 1), jnp.int32),
        jnp.full((b, 1), k.shape[1], jnp.int32),
        _win_arg(window), fmt="kv_bf16",
    )
    return out.reshape(b, s, cfg.n_heads * hd)


def attention(
    p: dict,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (S,) | (B,S) | (3,B,S) for mrope
    cfg,
    ctx: QuantCtx,
    path: str,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_src: Optional[jax.Array] = None,  # cross-attention source (B, T, d)
    cache: Optional[Dict[str, jax.Array]] = None,  # kv leaves (B, Smax, ...)
    cache_index: Optional[jax.Array] = None,  # scalar write position
    chunk: int = 1024,
    rope: bool = True,
    attend_cache: bool = False,  # S>1 chunk attends over the whole cache
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Returns (output (B,S,d), updated cache dict or None).

    ``cache`` is a kv-format leaf dict ({"k","v"} plus {"ke","ve"} exponent
    planes for quantized formats) as allocated by ``kv_cache.init_cache``;
    the format itself resolves from ``cfg`` (``kv_fmt`` / ``kv_bits``).

    ``attend_cache`` forces the cache-attend (decode) path for S > 1: after
    the chunk's K/V are written at ``cache_index``, scores run against the
    FULL cache, so earlier chunks of the same prompt are visible.  This is
    what chunked prefill needs -- the plain prefill path only attends over
    the chunk's own K/V and would drop history for any chunk after the
    first.  S == 1 decode behaves exactly as before.
    """
    hd = cfg.hd()
    g = cfg.n_heads // cfg.n_kv_heads
    src = x if kv_src is None else kv_src

    q = _split_heads(dense(p["wq"], x, f"{path}/wq", ctx), cfg.n_heads)
    k = _split_heads(dense(p["wk"], src, f"{path}/wk", ctx), cfg.n_kv_heads)
    v = _split_heads(dense(p["wv"], src, f"{path}/wv", ctx), cfg.n_kv_heads)
    if cfg.qk_norm:
        q = layers.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = layers.rmsnorm(p["k_norm"], k, cfg.norm_eps)

    use_rope = rope and kv_src is None  # no rope on cross-attention
    if use_rope:
        if cfg.mrope:
            q = layers.apply_mrope(q, positions, cfg.rope_theta)
            k = layers.apply_mrope(k, positions, cfg.rope_theta)
            q_pos = positions[0]  # temporal component orders causality
        else:
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
            q_pos = positions
    else:
        q_pos = positions

    new_cache = None
    decode = cache is not None and (x.shape[1] == 1 or attend_cache)
    if cache is not None:
        fmt = kv_cache.resolve_kv_fmt(cfg)
        new_cache, valid = kv_cache.write(fmt, cache, k, v, cache_index)

    if decode:
        # flash routing: S == 1 under cfg.flash_decode, S > 1 cache-attends
        # (chunked prefill) under cfg.flash_prefill -- independent knobs.
        # Both require a whole-per-device cache (_flash_routable); S > 1
        # additionally requires a causal layer (the kernel's masking
        # contract), which every self-attention prefill chunk is.
        flash = (
            getattr(cfg, "flash_decode", False)
            if x.shape[1] == 1
            else getattr(cfg, "flash_prefill", False) and causal
        )
        if flash and _flash_routable():
            out = _flash_cache_path(
                q, new_cache, fmt, q_pos, valid, window, cfg
            )
        else:
            # XLA fold-the-scales oracle: grouped-KV layout over the whole
            # cache, (..., S, T) scores, per-token scales folded in
            ck, cv, kscale, vscale = kv_cache.attend_view(fmt, new_cache)
            t = ck.shape[1]
            k_pos = jnp.arange(t)
            bias = _mask_bias(q_pos, k_pos, causal, window, valid)
            if bias.ndim == 2:
                bias = bias[None, None, None]  # (1,1,1,S,T)
            else:
                bias = bias[:, None, None]  # (B,1,1,S,T)
            qh = q.reshape(*q.shape[:2], cfg.n_kv_heads, g, hd)
            out = _attend_dense(qh, ck, cv, bias, kscale=kscale, vscale=vscale)
            out = out.reshape(*x.shape[:2], cfg.n_heads * hd)
        out = out.astype(x.dtype)
        return dense(p["wo"], out, f"{path}/wo", ctx), new_cache

    # in-chunk self-attention tail: a full-prompt prefill (cache written,
    # chunk attends only its own K/V) can run the flash kernel on the
    # just-projected bf16 K/V instead of the chunked/dense XLA paths.
    # `cache is not None` keeps training out (the kernel has no VJP).
    if (
        cache is not None
        and x.shape[1] > 1
        and causal
        and kv_src is None
        and getattr(cfg, "flash_prefill", False)
        and _flash_routable()
    ):
        out = _flash_self_path(q, k, v, window, cfg).astype(x.dtype)
        return dense(p["wo"], out, f"{path}/wo", ctx), new_cache

    # training / prefill: repeat KV to full heads so the head axis shards
    # over 'model' even when n_kv_heads does not divide the TP width.
    from repro.parallel import sharding as _sh

    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = _sh.constrain(q, ("batch", None, "heads", None))
    k = _sh.constrain(k, ("batch", None, "heads", None))
    v = _sh.constrain(v, ("batch", None, "heads", None))
    t = k.shape[1]
    if t > chunk:
        out = _attend_chunked(q, k, v, q_pos, causal, window, chunk)
    else:
        k_pos = jnp.arange(t)
        if causal or window is not None:
            bias = _mask_bias(q_pos, k_pos, causal, window)
            bias = bias[None] if bias.ndim == 2 else bias[:, None]
        else:
            bias = jnp.zeros((), jnp.float32)
        out = _attend_dense_mha(q, k, v, bias)

    out = out.reshape(*x.shape[:2], cfg.n_heads * hd).astype(x.dtype)
    return dense(p["wo"], out, f"{path}/wo", ctx), new_cache
