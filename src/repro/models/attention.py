"""Grouped-query attention with the flavours the assigned archs need:
qk-norm (qwen3), qkv-bias (qwen1.5), M-RoPE (qwen2-vl), sliding-window local
layers (gemma3 5:1), cross-attention (whisper), KV-cache decode.

Training/prefill uses an online-softmax chunked formulation (flash-attention
scheme at the XLA level): KV is scanned in blocks with running max/sum so the
S x S score matrix is never materialized -- this is what keeps the roofline
memory term linear in S.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dfp
from repro.models import layers
from repro.models.layers import QuantCtx, dense

NEG_INF = -1e30


def _kv_quantize(x: jax.Array):
    """(B,S,Kh,hd) -> (int8 mantissas, int8 exponents (B,S,Kh,1))."""
    max_abs = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    e = dfp.choose_exponent(max_abs, 8)
    return dfp.quantize(x.astype(jnp.float32), e, 8), e.astype(jnp.int8)


def init_attention(key, cfg, dtype, cross: bool = False) -> dict:
    hd = cfg.hd()
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": layers.init_dense_layer(kq, cfg.d_model, cfg.n_heads * hd, cfg.qkv_bias, dtype),
        "wk": layers.init_dense_layer(kk, cfg.d_model, cfg.n_kv_heads * hd, cfg.qkv_bias, dtype),
        "wv": layers.init_dense_layer(kv, cfg.d_model, cfg.n_kv_heads * hd, cfg.qkv_bias, dtype),
        "wo": layers.init_dense_layer(ko, cfg.n_heads * hd, cfg.d_model, False, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rmsnorm(hd, dtype)
        p["k_norm"] = layers.init_rmsnorm(hd, dtype)
    return p


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], n, x.shape[-1] // n)


def _mask_bias(
    q_pos: jax.Array,  # (S,) or (B, S)
    k_pos: jax.Array,  # (T,)
    causal: bool,
    window: Optional[int],
    valid_len: Optional[jax.Array] = None,  # (B,) cache fill level
) -> jax.Array:
    """Additive mask (..., S, T)."""
    qp = q_pos[..., :, None].astype(jnp.int32)
    kp = k_pos[None, :].astype(jnp.int32)
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= qp - kp < window
    if valid_len is not None:
        ok &= kp < valid_len[:, None, None]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _attend_dense(q, k, v, bias, ke=None, ve=None):
    """q (B,S,Kh,G,hd), k/v (B,T,Kh,hd), bias broadcastable to (B,Kh,G,S,T).

    Grouped-KV layout: used on the decode path where the score tensor is
    (..., 1, T) and repeating KV would blow up cache traffic.

    ke/ve: optional int8-KV-cache DFP exponents (B,T,Kh,1).  Scales are
    folded into the score/probability tensors so the dequantized cache is
    never materialized -- the cache streams from HBM at 1 byte/elem.
    """
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bskgh,btkh->bkgst", q.astype(jnp.float32), k.astype(jnp.float32))
    if ke is not None:  # fold per-(token, head) key scales into the scores
        kscale = dfp.exp2i(ke[..., 0])  # (B,T,Kh), exact power of two
        s = s * kscale.transpose(0, 2, 1)[:, :, None, None, :]
    s = s * scale + bias
    p = jax.nn.softmax(s, axis=-1)
    if ve is not None:  # fold value scales into the probabilities
        vscale = dfp.exp2i(ve[..., 0])
        p = p * vscale.transpose(0, 2, 1)[:, :, None, None, :]
    out = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return out


def _attend_dense_mha(q, k, v, bias):
    """Full-head layout: q/k/v (B,S|T,H,hd); bias (..., S, T).  KV heads are
    pre-repeated so the head axis shards over 'model' (Kh alone often does
    not divide the TP width, e.g. 8 kv heads on 16-way TP)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale + bias
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))


def _attend_chunked(q, k, v, q_pos, causal, window, chunk: int):
    """Online-softmax over KV chunks (flash-attention scheme at XLA level).

    q (B,S,H,hd); k/v (B,T,H,hd) (KV pre-repeated to full heads).  Only the
    (m, l, acc) carries survive a chunk; scores/probs are recomputed in the
    backward pass (jax.checkpoint)."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    scale = hd**-0.5
    qf = q.astype(jnp.float32) * scale
    n_chunks = t // chunk

    def body(carry, idx):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, idx * chunk, chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, 1)
        k_pos = idx * chunk + jnp.arange(chunk)
        bias = _mask_bias(q_pos, k_pos, causal, window)  # (S, chunk) or (B,S,chunk)
        bias = bias[None] if bias.ndim == 2 else bias[:, None]
        sc = jnp.einsum("bshd,bthd->bhst", qf, ks.astype(jnp.float32))
        sc = sc + bias  # (B,H,S,chunk)
        m_new = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        upd = jnp.einsum("bhst,bthd->bshd", p, vs.astype(jnp.float32))
        acc_new = acc * corr.transpose(0, 2, 1)[..., None] + upd
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, s, h, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0), jnp.arange(n_chunks)
    )
    denom = jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return acc / denom


def attention(
    p: dict,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (S,) | (B,S) | (3,B,S) for mrope
    cfg,
    ctx: QuantCtx,
    path: str,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_src: Optional[jax.Array] = None,  # cross-attention source (B, T, d)
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (k, v) (B, Smax, Kh, hd)
    cache_index: Optional[jax.Array] = None,  # scalar write position
    chunk: int = 1024,
    rope: bool = True,
    attend_cache: bool = False,  # S>1 chunk attends over the whole cache
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Returns (output (B,S,d), updated cache or None).

    ``attend_cache`` forces the cache-attend (decode) path for S > 1: after
    the chunk's K/V are written at ``cache_index``, scores run against the
    FULL cache, so earlier chunks of the same prompt are visible.  This is
    what chunked prefill needs -- the plain prefill path only attends over
    the chunk's own K/V and would drop history for any chunk after the
    first.  S == 1 decode behaves exactly as before.
    """
    hd = cfg.hd()
    g = cfg.n_heads // cfg.n_kv_heads
    src = x if kv_src is None else kv_src

    q = _split_heads(dense(p["wq"], x, f"{path}/wq", ctx), cfg.n_heads)
    k = _split_heads(dense(p["wk"], src, f"{path}/wk", ctx), cfg.n_kv_heads)
    v = _split_heads(dense(p["wv"], src, f"{path}/wv", ctx), cfg.n_kv_heads)
    if cfg.qk_norm:
        q = layers.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = layers.rmsnorm(p["k_norm"], k, cfg.norm_eps)

    use_rope = rope and kv_src is None  # no rope on cross-attention
    if use_rope:
        if cfg.mrope:
            q = layers.apply_mrope(q, positions, cfg.rope_theta)
            k = layers.apply_mrope(k, positions, cfg.rope_theta)
            q_pos = positions[0]  # temporal component orders causality
        else:
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
            q_pos = positions
    else:
        q_pos = positions

    new_cache = None
    decode = cache is not None and (x.shape[1] == 1 or attend_cache)
    if cache is not None:
        quantized_kv = len(cache) == 4
        if quantized_kv:  # int8 DFP cache: quantize on write
            ck, cv, cke, cve = cache
            kw, kew = _kv_quantize(k)
            vw, vew = _kv_quantize(v)
            writes = [(ck, kw), (cv, vw), (cke, kew), (cve, vew)]
        else:
            ck, cv = cache
            writes = [(ck, k.astype(ck.dtype)), (cv, v.astype(cv.dtype))]
        written = []
        if jnp.ndim(cache_index) == 0:  # aligned batch: cheap slice write
            for buf, val in writes:
                written.append(
                    jax.lax.dynamic_update_slice_in_dim(
                        buf, val.astype(buf.dtype), cache_index, 1
                    )
                )
            valid = jnp.broadcast_to(cache_index + x.shape[1], (x.shape[0],))
        else:  # per-slot positions (continuous batching): masked write, S==1
            iota = jnp.arange(ck.shape[1])
            m = (iota[None, :, None, None] == cache_index[:, None, None, None])
            for buf, val in writes:
                written.append(jnp.where(m, val.astype(buf.dtype), buf))
            valid = cache_index + 1
        new_cache = tuple(written)

    if decode:
        # grouped-KV layout over the whole cache: (..., 1, T) scores
        if len(new_cache) == 4:
            k, v, cke, cve = new_cache
        else:
            (k, v), cke, cve = new_cache, None, None
        t = k.shape[1]
        k_pos = jnp.arange(t)
        bias = _mask_bias(q_pos, k_pos, causal, window, valid)
        if bias.ndim == 2:
            bias = bias[None, None, None]  # (1,1,1,S,T)
        else:
            bias = bias[:, None, None]  # (B,1,1,S,T)
        qh = q.reshape(*q.shape[:2], cfg.n_kv_heads, g, hd)
        out = _attend_dense(qh, k, v, bias, ke=cke, ve=cve)
        out = out.reshape(*x.shape[:2], cfg.n_heads * hd).astype(x.dtype)
        return dense(p["wo"], out, f"{path}/wo", ctx), new_cache

    # training / prefill: repeat KV to full heads so the head axis shards
    # over 'model' even when n_kv_heads does not divide the TP width.
    from repro.parallel import sharding as _sh

    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = _sh.constrain(q, ("batch", None, "heads", None))
    k = _sh.constrain(k, ("batch", None, "heads", None))
    v = _sh.constrain(v, ("batch", None, "heads", None))
    t = k.shape[1]
    if t > chunk and t % chunk == 0:
        out = _attend_chunked(q, k, v, q_pos, causal, window, chunk)
    else:
        k_pos = jnp.arange(t)
        if causal or window is not None:
            bias = _mask_bias(q_pos, k_pos, causal, window)
            bias = bias[None] if bias.ndim == 2 else bias[:, None]
        else:
            bias = jnp.zeros((), jnp.float32)
        out = _attend_dense_mha(q, k, v, bias)

    out = out.reshape(*x.shape[:2], cfg.n_heads * hd).astype(x.dtype)
    return dense(p["wo"], out, f"{path}/wo", ctx), new_cache
