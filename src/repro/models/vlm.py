"""Qwen2-VL-style VLM: the LM backbone with M-RoPE; vision frontend is a
STUB per the assignment -- inputs carry precomputed patch embeddings that are
prepended to the text sequence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.layers import QuantCtx


def build_mrope_positions(batch: int, n_vis: int, n_text: int, grid: int = 0):
    """(3, B, S) position ids: vision tokens get (t=0, h, w) grid coords,
    text tokens advance the temporal component."""
    if grid <= 0:
        grid = max(1, int(n_vis**0.5))
    s = n_vis + n_text
    t = jnp.concatenate([jnp.zeros((n_vis,), jnp.int32), 1 + jnp.arange(n_text)])
    idx = jnp.arange(n_vis)
    h = jnp.concatenate([idx // grid, 1 + jnp.arange(n_text)])
    w = jnp.concatenate([idx % grid, 1 + jnp.arange(n_text)])
    pos = jnp.stack([t, h, w]).astype(jnp.int32)  # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, s))


def forward(params, batch, cfg, ctx: QuantCtx):
    return transformer.forward(
        params,
        batch["tokens"],
        cfg,
        ctx,
        positions=batch["positions"],
        extra_embeds=batch["vision_embeds"],
    )


def loss_fn(params, batch, cfg, ctx: QuantCtx):
    return transformer.loss_fn(
        params,
        {
            "tokens": batch["tokens"],
            "labels": batch["labels"],
            "positions": batch["positions"],
            "extra_embeds": batch["vision_embeds"],
        },
        cfg,
        ctx,
    )


def prefill(params, batch, cfg, ctx: QuantCtx, cache):
    x = transformer.layers.embed(params["embed"], batch["tokens"])
    v = batch["vision_embeds"].astype(x.dtype)
    x = jnp.concatenate([v, x], axis=1)
    positions = batch["positions"]
    win = transformer.window_schedule(cfg, cache["k"].shape[2])
    x, cache = transformer._cache_scan(
        params, x, positions, cfg, ctx, cache, jnp.int32(0), win
    )
    x = transformer.layers.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return transformer.layers.dense(params["lm_head"], x, "lm_head", ctx), cache
