"""Whisper-style encoder-decoder (audio family).

The conv frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, n_frames, d_model).  Encoder = non-causal
self-attention blocks with LayerNorm + GELU MLP (whisper flavour); decoder =
causal self-attention + cross-attention over encoder output, KV-cache decode.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import kv_cache, layers
from repro.models.layers import QuantCtx, dense
from repro.parallel import sharding


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _init_gelu_mlp(key, d, ff, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "up": layers.init_dense_layer(k1, d, ff, True, dtype),
        "down": layers.init_dense_layer(k2, ff, d, True, dtype),
    }


def _gelu_mlp(p, x, path, ctx):
    return dense(p["down"], jax.nn.gelu(dense(p["up"], x, f"{path}/up", ctx)), f"{path}/down", ctx)


def _init_enc_block(key, cfg, dtype):
    ka, km = jax.random.split(key)
    return {
        "ln1": layers.init_layernorm(cfg.d_model, dtype),
        "attn": attn_lib.init_attention(ka, cfg, dtype),
        "ln2": layers.init_layernorm(cfg.d_model, dtype),
        "mlp": _init_gelu_mlp(km, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_block(key, cfg, dtype):
    ka, kc, km = jax.random.split(key, 3)
    return {
        "ln1": layers.init_layernorm(cfg.d_model, dtype),
        "self_attn": attn_lib.init_attention(ka, cfg, dtype),
        "ln2": layers.init_layernorm(cfg.d_model, dtype),
        "cross_attn": attn_lib.init_attention(kc, cfg, dtype, cross=True),
        "ln3": layers.init_layernorm(cfg.d_model, dtype),
        "mlp": _init_gelu_mlp(km, cfg.d_model, cfg.d_ff, dtype),
    }


def init_encdec(key, cfg) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    ke, kd, kt, kp, kq, kh = jax.random.split(key, 6)
    ekeys = jax.random.split(ke, cfg.n_enc_layers)
    dkeys = jax.random.split(kd, cfg.n_layers)
    return {
        "enc_pos": jax.random.normal(kp, (cfg.n_audio_frames, cfg.d_model), dtype) * 0.01,
        "enc_blocks": _stack([_init_enc_block(k, cfg, dtype) for k in ekeys]),
        "enc_norm": layers.init_layernorm(cfg.d_model, dtype),
        "embed": layers.init_embedding(kt, cfg.padded_vocab, cfg.d_model, dtype),
        "dec_pos": jax.random.normal(kq, (448, cfg.d_model), dtype) * 0.01,
        "dec_blocks": _stack([_init_dec_block(k, cfg, dtype) for k in dkeys]),
        "dec_norm": layers.init_layernorm(cfg.d_model, dtype),
        "lm_head": layers.init_dense_layer(kh, cfg.d_model, cfg.padded_vocab, False, dtype),
    }


def encode(params, frames: jax.Array, cfg, ctx: QuantCtx) -> jax.Array:
    """frames: (B, n_frames, d_model) precomputed embeddings (stub frontend)."""
    x = frames + params["enc_pos"][None, : frames.shape[1]]
    positions = jnp.arange(x.shape[1])

    def body(h, bp):
        h = sharding.constrain(h, ("batch", "seq", None))
        a, _ = attn_lib.attention(
            bp["attn"], layers.layernorm(bp["ln1"], h), positions, cfg, ctx,
            "enc/attn", causal=False, rope=False,
        )
        h = h + a
        h = h + _gelu_mlp(bp["mlp"], layers.layernorm(bp["ln2"], h), "enc/mlp", ctx)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return layers.layernorm(params["enc_norm"], x)


def _dec_block(bp, x, enc_out, positions, cfg, ctx, cache=None, cache_index=None):
    a, new_cache = attn_lib.attention(
        bp["self_attn"], layers.layernorm(bp["ln1"], x), positions, cfg, ctx,
        "dec/self_attn", causal=True, rope=False, cache=cache, cache_index=cache_index,
    )
    x = x + a
    c, _ = attn_lib.attention(
        bp["cross_attn"], layers.layernorm(bp["ln2"], x), positions, cfg, ctx,
        "dec/cross_attn", causal=False, rope=False, kv_src=enc_out,
    )
    x = x + c
    x = x + _gelu_mlp(bp["mlp"], layers.layernorm(bp["ln3"], x), "dec/mlp", ctx)
    return x, new_cache


def _pos_embed(table: jax.Array, start, length: int) -> jax.Array:
    if jnp.ndim(start) == 1:  # per-slot start -> (B, L, d)
        idx = (start[:, None] + jnp.arange(length)) % table.shape[0]
    else:
        idx = (start + jnp.arange(length)) % table.shape[0]
    return jnp.take(table, idx, axis=0)


def hidden(params, batch, cfg, ctx: QuantCtx) -> jax.Array:
    """Training path: batch = {frames, tokens}; returns decoder hidden states."""
    enc_out = encode(params, batch["frames"], cfg, ctx)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = layers.embed(params["embed"], tokens) + _pos_embed(params["dec_pos"], 0, s)[None]
    positions = jnp.arange(s)

    def body(h, bp):
        h = sharding.constrain(h, ("batch", "seq", None))
        h, _ = _dec_block(bp, h, enc_out, positions, cfg, ctx)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_blocks"])
    return layers.layernorm(params["dec_norm"], x)


def forward(params, batch, cfg, ctx: QuantCtx) -> jax.Array:
    x = hidden(params, batch, cfg, ctx)
    return dense(params["lm_head"], x, "lm_head", ctx)


def loss_fn(params, batch, cfg, ctx: QuantCtx) -> jax.Array:
    x = hidden(params, batch, cfg, ctx)
    return layers.lm_head_loss(
        params["lm_head"], x, batch["labels"], cfg.vocab, "lm_head", ctx
    )


KV_LEAF_NAMES = ("k", "v", "ke", "ve")


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    # decoder self-attn KV through the registered formats; cross-attn reads
    # enc_out densely (re-projected per step, no cache)
    cache = kv_cache.init_cache(cfg, (cfg.n_layers, batch), max_len, dtype)
    cache["enc_out"] = jnp.zeros((batch, cfg.n_audio_frames, cfg.d_model), dtype)
    return cache


def _dec_scan(params, x, enc_out, positions, cfg, ctx, cache, cache_index):
    kv_keys = [n for n in KV_LEAF_NAMES if n in cache]

    def body(h, sc):
        c = {n: sc[n] for n in kv_keys}
        h, new = _dec_block(
            sc["p"], h, enc_out, positions, cfg, ctx, c, cache_index
        )
        return h, {n: new[n] for n in kv_keys}

    scanned = {"p": params["dec_blocks"], **{n: cache[n] for n in kv_keys}}
    x, upd = jax.lax.scan(body, x, scanned)
    return x, upd


def prefill(params, batch, cfg, ctx: QuantCtx, cache):
    enc_out = encode(params, batch["frames"], cfg, ctx).astype(cache["enc_out"].dtype)
    cache = dict(cache, enc_out=enc_out)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = layers.embed(params["embed"], tokens) + _pos_embed(params["dec_pos"], 0, s)[None]
    positions = jnp.arange(s)
    x, upd = _dec_scan(params, x, enc_out, positions, cfg, ctx, cache, jnp.int32(0))
    cache.update(upd)
    x = layers.layernorm(params["dec_norm"], x[:, -1:])
    return dense(params["lm_head"], x, "lm_head", ctx), cache


def decode_step(params, token, pos, cfg, ctx: QuantCtx, cache):
    pe = _pos_embed(params["dec_pos"], pos, 1)
    if pe.ndim == 2:  # scalar pos -> add batch dim
        pe = pe[None]
    x = layers.embed(params["embed"], token) + pe
    if jnp.ndim(pos) == 1:
        positions = pos[:, None].astype(jnp.int32)
    else:
        positions = jnp.full((token.shape[0], 1), pos, jnp.int32)
    x, upd = _dec_scan(
        params, x, cache["enc_out"], positions, cfg, ctx, cache, pos
    )
    new_cache = dict(cache)
    new_cache.update(upd)
    x = layers.layernorm(params["dec_norm"], x)
    return dense(params["lm_head"], x, "lm_head", ctx), new_cache
