"""Mixture-of-Experts layer: top-k routing with static-capacity sort-based
dispatch (all shapes static => pjit/dry-run friendly).

Dispatch: token replicas are sorted by expert id; each token's rank within
its expert group is computed with searchsorted; ranks beyond the expert
capacity are dropped (standard capacity-factor semantics).  Under the
production mesh the expert axis of the (E, C, d) buffer is sharded over
'model' (expert parallelism) and the scatter/gather lowers to all-to-alls.
PTQ serving under the "pallas_ep" backend goes further: the whole expert
FFN runs as one shard_map over the expert axis (``_expert_ffn``) with the
dispatch/combine all-to-alls inside the body and the fused ``qdense``
decoding only local expert slices.

The router is pinned to 8-bit by the precision policy (paper's rule that
accuracy-critical control paths keep higher precision); expert FFN weights
are ternary/4-bit clustered like any other projection.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import ste
from repro.quant.api import observe_site
from repro.quant.backends import (
    ep_divisible,
    expert_ffn_ep,
    qmatmul,
    resolve_backend,
)
from repro.quant.qtensor import QTensor
from repro.models import layers
from repro.models.layers import QuantCtx, dense
from repro.parallel import sharding

# Perf iteration B1 toggle (EXPERIMENTS.md): flat-token chunking is the
# pre-B1 baseline; sequence-aligned chunking is the default.
FLAT_CHUNKING: list = [False]


def init_moe(key, cfg, dtype) -> Dict[str, Any]:
    kr, ku, kg, kd, km = jax.random.split(key, 5)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    std_in, std_out = d**-0.5, ff**-0.5
    p = {
        "router": layers.init_dense_layer(kr, d, e, False, dtype),
        "experts": {
            "gate": {"w": jax.random.normal(kg, (e, d, ff), dtype) * std_in},
            "up": {"w": jax.random.normal(ku, (e, d, ff), dtype) * std_in},
            "down": {"w": jax.random.normal(kd, (e, ff, d), dtype) * std_out},
        },
    }
    if cfg.moe_dense_residual:
        p["residual_mlp"] = layers.init_mlp(km, d, cfg.d_ff, dtype)
    return p


def _quantize_expert_weights(experts, ctx: QuantCtx, path: str):
    """QAT: fake-quantize the stacked expert weights once per layer call.

    NOTE (Perf iteration A2, REFUTED then reverted to lazy form): hoisting
    the Algorithm-1 fake-quant out of the dispatch-chunk scan was predicted
    to remove the re-sort cost, but XLA's loop-invariant code motion had
    already hoisted it -- the explicit hoist only pinned the quantized
    copies as live values (+5% bytes, +6.6 GiB temps on arctic x train_4k).
    The lazy per-matmul form below lets XLA place the computation."""
    if ctx.mode != "qat" or (ctx.plan is None and ctx.policy is None):
        return experts
    out = {}
    for name, leaf in experts.items():
        prec = ctx.resolve(f"{path}/experts/{name}")
        w = leaf["w"]
        out[name] = {"w": w, "_prec": prec}  # quantized lazily in the matmul
    return out


def _expert_matmul(w, x, path: str, ctx: QuantCtx, prec=None, buf_axes=None) -> jax.Array:
    """x (E, C, d_in) @ w (E, d_in, d_out); weights already fake-quantized
    (QAT) or QTensor (PTQ)."""
    if ctx.observer is not None:
        # calibration pass: record the dispatched (E, C, d) buffer's range so
        # expert MLP sites get profiled static DFP exponents like dense()
        # sites do (one shared exponent per site across experts and chunks;
        # the capacity buffer's zero padding never raises max_abs)
        observe_site(ctx.observer, path, x)
    if isinstance(w, QTensor):
        # NOTE (Perf iteration B7, REFUTED then reverted): inlining the PTQ
        # matmul with per-intermediate sharding constraints was predicted to
        # stop the partitioner replicating the f32 act-quant tensors inside
        # the chunk loop; instead it un-hoisted the weight dequantization
        # (8.5x flops, +12 GiB temps on grok x prefill_32k).  The vmapped
        # qmatmul below lets XLA hoist.  Under the "pallas_ep" backend with a
        # mesh installed, expert sites bypass this function entirely through
        # the shard_map EP path (_expert_ffn below), which decodes only the
        # local expert slices -- no replicated f32 act-quant gathers.
        site_prec = ctx.resolve(path)
        return jax.vmap(
            lambda qt, xe: qmatmul(
                xe, qt, backend=ctx.backend,
                act_bits=site_prec.act_bits if site_prec else 8,
                act_exponent=ctx.act_exponent(path),
            )
        )(w, x)
    if ctx.mode == "qat" and prec is not None and prec.quantized:
        wq = jax.vmap(
            lambda we: ste.weights_ste(
                we.astype(jnp.float32), prec.w_bits, prec.group_size,
                prec.filter_size, prec.refit_scale, fmt=prec.fmt,
            )
        )(w).astype(x.dtype)
        xq = ste.act_ste(x.astype(jnp.float32), prec.act_bits).astype(x.dtype)
        return jnp.einsum("ecd,edf->ecf", xq, wq)
    return jnp.einsum("ecd,edf->ecf", x, w)


def _ep_cap_axes(mesh, c: int):
    """Data-parallel mesh axes the capacity axis can additionally shard over
    (only taken when C stays divisible; else capacity shards over EP alone
    and the buffer replicates across the data axes at the shard_map edge)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    total = mesh.shape.get("model", 1)
    for a in axes:
        total *= mesh.shape[a]
    return axes if (axes and c % total == 0) else ()


def _use_ep(experts, e: int, c: int, ctx: QuantCtx) -> bool:
    """Route this chunk's expert FFN through the shard_map EP path?  Only
    for PTQ (QTensor weights) under the "pallas_ep" backend with a mesh
    installed whose expert/capacity axes divide the (E, C) buffer."""
    mesh = sharding._ACT_MESH[0]
    return (
        isinstance(experts["gate"]["w"], QTensor)
        and resolve_backend(ctx.backend) == "pallas_ep"
        and mesh is not None
        and ep_divisible(e, c, mesh, "model", _ep_cap_axes(mesh, c))
    )


def _expert_ffn(experts, xb: jax.Array, path: str, ctx: QuantCtx, buf_axes):
    """gate/up/down over the dispatched (E, C, d) buffer.

    PTQ under the "pallas_ep" backend with an installed mesh runs the whole
    FFN as ONE shard_map over the expert ('model') axis: dispatch/combine
    all-to-alls inside the body, fused qdense on the local expert slices
    (gate silu in the kernel epilogue).  Every other mode composes the three
    ``_expert_matmul`` sites exactly as before, so the EP path has a
    bit-identical single-device oracle."""
    mesh = sharding._ACT_MESH[0]
    if _use_ep(experts, xb.shape[0], xb.shape[1], ctx):
        # (no observer handling: calibration always runs on float params, so
        # the QTensor guard above keeps the observing pass on the oracle path)
        def site_kw(name):
            site = f"{path}/experts/{name}"
            prec = ctx.resolve(site)
            return {
                "act_bits": prec.act_bits if prec else 8,
                "act_exponent": ctx.act_exponent(site),
                "fused": prec.fused if prec else True,
            }

        return expert_ffn_ep(
            {name: experts[name]["w"] for name in ("gate", "up", "down")},
            xb,
            mesh=mesh,
            ep_axis="model",
            cap_axes=_ep_cap_axes(mesh, xb.shape[1]),
            backend=ctx.backend,
            site_kwargs={n: site_kw(n) for n in ("gate", "up", "down")},
        )
    em = lambda name, val: _expert_matmul(
        experts[name]["w"], val, f"{path}/experts/{name}", ctx,
        prec=experts[name].get("_prec"), buf_axes=buf_axes,
    )
    h = jax.nn.silu(em("gate", xb))
    h = h * em("up", xb)
    return em("down", h)


def capacity(n_tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(n_tokens * top_k * factor / n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def _dispatch_chunk(p, experts, xt: jax.Array, path: str, cfg, ctx: QuantCtx, buf_axes):
    """Route one chunk of tokens (tc, d) through the (pre-quantized) experts."""
    tc, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(tc, k, e, cfg.capacity_factor)

    logits = dense(p["router"], xt, f"{path}/router", ctx).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (tc, E)
    top_vals, top_ids = jax.lax.top_k(probs, k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    flat_ids = top_ids.reshape(-1)  # (tc*k,)
    flat_gate = top_vals.reshape(-1)
    flat_src = jnp.arange(tc * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[order]
    sorted_src = flat_src[order]
    rank = jnp.arange(tc * k, dtype=jnp.int32) - jnp.searchsorted(
        sorted_ids, sorted_ids, side="left"
    ).astype(jnp.int32)
    keep = rank < c
    # out-of-bounds scatter indices are dropped by XLA => capacity overflow
    dest = jnp.where(keep, sorted_ids * c + rank, e * c)

    buf = jnp.zeros((e * c, d), xt.dtype).at[dest].set(
        xt[sorted_src], mode="drop"
    )
    use_ep = _use_ep(experts, e, c, ctx)
    xb = buf.reshape(e, c, d)
    if not use_ep:  # EP: shard_map's capacity-sharded in_spec IS the layout
        xb = sharding.constrain(xb, buf_axes)

    yb = _expert_ffn(experts, xb, path, ctx, buf_axes)
    # combine in the model dtype: the gather/scatter-add below crosses the
    # expert->token sharding boundary, so its collectives move these bytes
    # (f32 here doubled the MoE collective term -- Perf iteration B4)
    yb = yb.astype(xt.dtype)
    if not use_ep:  # EP: the combine all-to-all already ran inside shard_map
        yb = sharding.constrain(yb, buf_axes)

    vals = yb.reshape(e * c, d).at[dest].get(
        mode="fill", fill_value=0
    ) * flat_gate[order][:, None].astype(xt.dtype)
    out = jnp.zeros((tc, d), xt.dtype).at[sorted_src].add(vals)
    return sharding.constrain(out, ("batch", None))


def moe_layer(p, x: jax.Array, path: str, cfg, ctx: QuantCtx) -> jax.Array:
    """Chunked MoE: the token stream is processed in bounded-size chunks via
    lax.scan so dispatch buffers stay O(chunk) instead of O(global batch) --
    capacity is enforced per chunk (finer-grained drops, standard under
    microbatching).  EP shards experts over 'model' when divisible; archs
    with fewer experts than the TP width (grok: 8e on 16-way) fall back to
    capacity-over-data + FFN-over-model sharding."""
    b, s, d = x.shape
    t = b * s
    e = cfg.n_experts
    buf_axes = ("expert", None, None)
    mesh = sharding._ACT_MESH[0]
    if mesh is not None and "model" in mesh.shape and e % mesh.shape["model"]:
        buf_axes = (None, "batch", None)
    experts = _quantize_expert_weights(p["experts"], ctx, path)

    # Chunk along the SEQUENCE axis: (B, sc, d) chunks keep the batch axis
    # sharded, so slicing/stacking never reshards the token stream.  (A flat
    # (T,)-axis chunking interleaves the sharded token axis and XLA inserts
    # a full all-gather of the stacked outputs -- 24 GiB/step on the
    # grok x prefill_32k cell; see EXPERIMENTS.md Perf iteration B1.)
    target = getattr(cfg, "moe_chunk_tokens", 8192)
    n_chunks = max(1, t // max(target, 1))
    if FLAT_CHUNKING[0]:  # pre-B1 baseline: flat (T,)-axis chunking
        while t % n_chunks:
            n_chunks -= 1
        xt = sharding.constrain(x.reshape(t, d), ("batch", None))
        if n_chunks == 1:
            out = _dispatch_chunk(p, experts, xt, path, cfg, ctx, buf_axes)
        else:
            def fbody(carry, xc):
                yc = _dispatch_chunk(p, experts, xc, path, cfg, ctx, buf_axes)
                return carry, yc
            _, out = jax.lax.scan(
                jax.checkpoint(fbody), 0.0, xt.reshape(n_chunks, t // n_chunks, d)
            )
        out = sharding.constrain(
            out.reshape(b, s, d), ("batch", None, None)
        ).astype(x.dtype)
        if "residual_mlp" in p:
            out = out + layers.mlp(p["residual_mlp"], x, f"{path}/residual_mlp", ctx)
        return out
    while s % n_chunks:
        n_chunks -= 1
    sc = s // n_chunks

    if n_chunks == 1:
        xt = sharding.constrain(x.reshape(t, d), ("batch", None))
        out = _dispatch_chunk(p, experts, xt, path, cfg, ctx, buf_axes).reshape(b, s, d)
    else:
        def body(carry, xc):  # xc: (B, sc, d)
            xc = sharding.constrain(xc.reshape(b * sc, d), ("batch", None))
            yc = _dispatch_chunk(p, experts, xc, path, cfg, ctx, buf_axes)
            return carry, yc.reshape(b, sc, d)

        xcs = jnp.moveaxis(x.reshape(b, n_chunks, sc, d), 1, 0)
        _, out = jax.lax.scan(jax.checkpoint(body), 0.0, xcs)
        out = jnp.moveaxis(out, 0, 1).reshape(b, s, d)

    out = sharding.constrain(out, ("batch", None, None)).astype(x.dtype)
    if "residual_mlp" in p:  # arctic: dense MLP in parallel with the experts
        out = out + layers.mlp(p["residual_mlp"], x, f"{path}/residual_mlp", ctx)
    return out


def aux_load_balance_loss(logits: jax.Array, top_ids: jax.Array, n_experts: int):
    """Switch-style auxiliary loss (exposed for the trainer)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    me = probs.mean(0)
    ce = jnp.bincount(top_ids.reshape(-1), length=n_experts) / top_ids.size
    return n_experts * jnp.sum(me * ce)
