"""Unified model API: family dispatch, input specs, PTQ conversion.

``build_model(cfg)`` returns a ``ModelApi`` whose members all have fixed
signatures so the trainer / server / dry-run treat every family uniformly:

  init(key) -> params
  train_loss(params, batch) -> scalar
  forward(params, batch) -> logits
  init_cache(batch, max_len) -> cache            (decode state)
  prefill(params, batch, cache) -> (logits, cache)
  decode(params, token, pos, cache) -> (logits, cache)
  input_specs(shape_cfg) -> (batch/spec pytree, kind)

Staged-serving members (the prefill / insert / generate engine split):

  prefill_chunk(params, tokens, start, cache) -> (logits, cache)
      consume one (B, S) chunk of prompt tokens at cache positions
      [start, start+S), attending over the whole cache so earlier chunks
      stay visible; None for families whose decode state cannot replay a
      chunk in one graph (ssm/hybrid/encdec -- the staged engine falls
      back to budgeted per-token decode prefill there).
  insert(cache, prefix, slot) -> cache
      write a B=1 prefix cache (a finished prefill) into slot ``slot`` of
      a B=n_slots decode cache -- every leaf's batch row is overwritten,
      so stale state from the slot's previous occupant cannot leak.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig, config_from_dict, config_to_dict
from repro.core.policy import PrecisionPolicy
from repro.models import encdec, hybrid, ssm_lm, transformer, vlm
from repro.quant import api as quant_api
from repro.quant.plan import QuantCtx, QuantPlan


@dataclasses.dataclass
class ModelApi:
    cfg: ArchConfig
    ctx: QuantCtx
    init: Callable
    train_loss: Callable
    forward: Callable
    init_cache: Callable
    prefill: Optional[Callable]
    decode: Callable
    # staged serving: chunked prompt consumption + per-slot cache insertion
    prefill_chunk: Optional[Callable] = None
    insert: Optional[Callable] = None

    def with_ctx(self, ctx: QuantCtx) -> "ModelApi":
        """Rebind every member to a new quantization context."""
        return build_model(self.cfg, ctx)

    def with_plan(self, plan: QuantPlan) -> "ModelApi":
        """View of this model driven by a compiled QuantPlan."""
        return self.with_ctx(QuantCtx.for_plan(plan))

    def compiled(self, params) -> "ModelApi":
        """Compile this api's policy against ``params`` (kills per-call regex
        resolution in dense(); a no-op view for fp contexts)."""
        if self.ctx.policy is None:
            return self
        plan = self.ctx.policy.compile(
            params, mode=self.ctx.mode, backend=self.ctx.backend
        )
        return self.with_plan(plan)


def make_ctx(cfg: ArchConfig) -> QuantCtx:
    """Deprecated alias: use ``repro.quant.QuantCtx.from_config(cfg.quant)``."""
    return QuantCtx.from_config(cfg.quant)


def _insert_leaf(buf, pre, slot: Any, axis: int):
    return jax.lax.dynamic_update_slice_in_dim(
        buf, pre.astype(buf.dtype), slot, axis=axis
    )


def insert_prefix(cache, prefix, slot, batch_axis_overrides: Optional[Dict[str, int]] = None):
    """Write a B=1 ``prefix`` cache into batch row ``slot`` of ``cache``.

    Every model family stacks its decode state as (layers, B, ...), so the
    batch axis is 1 for every leaf; ``batch_axis_overrides`` names top-level
    leaves that deviate (encdec's (B, T, d) ``enc_out`` is axis 0).  ``slot``
    may be traced -- one compile covers every slot.
    """
    over = batch_axis_overrides or {}
    if not over:
        return jax.tree.map(lambda b, p: _insert_leaf(b, p, slot, 1), cache, prefix)
    out = {}
    for name, leaf in cache.items():
        ax = over.get(name, 1)
        out[name] = jax.tree.map(
            lambda b, p, a=ax: _insert_leaf(b, p, slot, a), leaf, prefix[name]
        )
    return out


def build_model(cfg: ArchConfig, ctx: Optional[QuantCtx] = None) -> ModelApi:
    ctx = ctx or QuantCtx.from_config(cfg.quant)
    fam = cfg.family
    if fam in ("dense", "moe"):
        return ModelApi(
            cfg, ctx,
            init=lambda key: transformer.init_lm(key, cfg),
            train_loss=lambda p, b: transformer.loss_fn(p, b, cfg, ctx),
            forward=lambda p, b: transformer.forward(p, b["tokens"], cfg, ctx),
            init_cache=lambda b, m: transformer.init_cache(cfg, b, m),
            prefill=lambda p, b, c: transformer.prefill(p, b["tokens"], cfg, ctx, c),
            decode=lambda p, t, pos, c: transformer.decode_step(p, t, pos, cfg, ctx, c),
            prefill_chunk=lambda p, t, start, c: transformer.prefill_chunk(
                p, t, start, cfg, ctx, c
            ),
            insert=insert_prefix,
        )
    if fam == "vlm":
        return ModelApi(
            cfg, ctx,
            init=lambda key: transformer.init_lm(key, cfg),
            train_loss=lambda p, b: vlm.loss_fn(p, b, cfg, ctx),
            forward=lambda p, b: vlm.forward(p, b, cfg, ctx),
            init_cache=lambda b, m: transformer.init_cache(cfg, b, m),
            prefill=lambda p, b, c: vlm.prefill(p, b, cfg, ctx, c),
            decode=lambda p, t, pos, c: transformer.decode_step(p, t, pos, cfg, ctx, c),
            prefill_chunk=lambda p, t, start, c: transformer.prefill_chunk(
                p, t, start, cfg, ctx, c
            ),
            insert=insert_prefix,
        )
    if fam == "hybrid":
        return ModelApi(
            cfg, ctx,
            init=lambda key: hybrid.init_hybrid(key, cfg),
            train_loss=lambda p, b: hybrid.loss_fn(p, b, cfg, ctx),
            forward=lambda p, b: hybrid.forward(p, b["tokens"], cfg, ctx),
            init_cache=lambda b, m: hybrid.init_cache(cfg, b, m),
            prefill=None,  # hybrid prefill == forward + state replay (engine-level)
            decode=lambda p, t, pos, c: hybrid.decode_step(p, t, pos, cfg, ctx, c),
            insert=insert_prefix,  # ssm states + per-superblock KV: all (L, B, ...)
        )
    if fam == "ssm":
        return ModelApi(
            cfg, ctx,
            init=lambda key: ssm_lm.init_ssm_lm(key, cfg),
            train_loss=lambda p, b: ssm_lm.loss_fn(p, b, cfg, ctx),
            forward=lambda p, b: ssm_lm.forward(p, b["tokens"], cfg, ctx),
            init_cache=lambda b, m: ssm_lm.init_cache(cfg, b, m),
            prefill=None,
            decode=lambda p, t, pos, c: ssm_lm.decode_step(p, t, pos, cfg, ctx, c),
            insert=insert_prefix,
        )
    if fam == "encdec":
        return ModelApi(
            cfg, ctx,
            init=lambda key: encdec.init_encdec(key, cfg),
            train_loss=lambda p, b: encdec.loss_fn(p, b, cfg, ctx),
            forward=lambda p, b: encdec.forward(p, b, cfg, ctx),
            init_cache=lambda b, m: encdec.init_cache(cfg, b, m),
            prefill=lambda p, b, c: encdec.prefill(p, b, cfg, ctx, c),
            decode=lambda p, t, pos, c: encdec.decode_step(p, t, pos, cfg, ctx, c),
            insert=lambda c, pre, s: insert_prefix(
                c, pre, s, batch_axis_overrides={"enc_out": 0}
            ),
        )
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Input specs: one cell = (arch x shape); used by smoke tests (concrete) and
# the dry-run (ShapeDtypeStruct, no allocation).
# ---------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[Dict[str, Any], str]:
    """Returns ({name: ShapeDtypeStruct}, kind). Token count semantics:
    train/prefill feed (B, S); decode feeds one token with an S-long cache."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        if cfg.family == "encdec":
            return (
                {
                    "frames": jax.ShapeDtypeStruct((b, cfg.n_audio_frames, cfg.d_model), f),
                    "tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "labels": jax.ShapeDtypeStruct((b, s), i32),
                },
                "train",
            )
        if cfg.family == "vlm":
            nv = cfg.n_frontend_tokens
            return (
                {
                    "tokens": jax.ShapeDtypeStruct((b, s - nv), i32),
                    "labels": jax.ShapeDtypeStruct((b, s - nv), i32),
                    "vision_embeds": jax.ShapeDtypeStruct((b, nv, cfg.d_model), f),
                    "positions": jax.ShapeDtypeStruct((3, b, s), i32),
                },
                "train",
            )
        return (
            {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            },
            "train",
        )
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return (
                {
                    "frames": jax.ShapeDtypeStruct((b, cfg.n_audio_frames, cfg.d_model), f),
                    "tokens": jax.ShapeDtypeStruct((b, s), i32),
                },
                "prefill",
            )
        if cfg.family == "vlm":
            nv = cfg.n_frontend_tokens
            return (
                {
                    "tokens": jax.ShapeDtypeStruct((b, s - nv), i32),
                    "vision_embeds": jax.ShapeDtypeStruct((b, nv, cfg.d_model), f),
                    "positions": jax.ShapeDtypeStruct((3, b, s), i32),
                },
                "prefill",
            )
        return ({"tokens": jax.ShapeDtypeStruct((b, s), i32)}, "prefill")
    # decode: one new token against an S-long cache
    return ({"token": jax.ShapeDtypeStruct((b, 1), i32)}, "decode")


def make_smoke_batch(key, cfg: ArchConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Concrete small training batch for CPU smoke tests."""
    kt, kv = jax.random.split(key)
    f = jnp.dtype(cfg.dtype)
    out: Dict[str, Any] = {
        "tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(kv, (batch, seq), 0, cfg.vocab, jnp.int32),
    }
    if cfg.family == "encdec":
        out["frames"] = (
            jax.random.normal(kv, (batch, cfg.n_audio_frames, cfg.d_model)) * 0.1
        ).astype(f)
    if cfg.family == "vlm":
        nv = cfg.n_frontend_tokens
        out["vision_embeds"] = (
            jax.random.normal(kv, (batch, nv, cfg.d_model)) * 0.1
        ).astype(f)
        out["positions"] = vlm.build_mrope_positions(batch, nv, seq)
        out["labels"] = out["labels"]
    return out


# ---------------------------------------------------------------------------
# PTQ: convert trained params to QTensor weights per a compiled QuantPlan.
# ---------------------------------------------------------------------------
def quantize_model_params(params, policy: PrecisionPolicy):
    """Deprecated alias for ``repro.quant.quantize_model`` (plan discarded).

    Prefer ``quantize_and_plan`` (or ``repro.quant.quantize_model`` directly)
    so the compiled, serializable plan travels with the quantized params.
    """
    qparams, _ = quant_api.quantize_model(params, policy)
    return qparams


def quantize_and_plan(
    api: ModelApi, params, calib_batches=None
) -> Tuple[Any, QuantPlan, ModelApi]:
    """One-call PTQ for a zoo model: returns (qparams, plan, plan-bound api).

    With ``calib_batches`` (iterable of forward-compatible batches), a
    full-precision observing pass profiles per-site activation ranges and
    the plan carries static DFP exponents (paper's profiled mode); without,
    PTQ inference uses dynamic per-row exponents everywhere.
    """
    qc = api.cfg.quant
    qparams, plan = quant_api.quantize_model(
        params,
        api.ctx.policy,
        mode="ptq",
        backend=qc.backend,
        calib_batches=calib_batches,
        forward=lambda p, b, ctx: api.with_ctx(ctx).forward(p, b),
        act_bits=qc.act_bits,
    )
    return qparams, plan, api.with_plan(plan)


# ---------------------------------------------------------------------------
# Quantized artifacts: quantize once, cold-start serving many times.
# ---------------------------------------------------------------------------
def save_servable(
    artifact_dir: str, api: ModelApi, qparams, plan: QuantPlan, mesh=None
) -> str:
    """Persist (qparams, plan) as a self-contained serving artifact.

    The serialized ArchConfig travels in the manifest, so ``load_servable``
    needs nothing but the directory.  With ``mesh`` the payloads write
    per-host sharded (``payload.shard{k}``; see the checkpoint module
    docstring for the layout)."""
    return quant_api.save_artifact(
        artifact_dir, qparams, plan,
        extra={"arch_config": config_to_dict(api.cfg)},
        mesh=mesh,
    )


def load_servable(
    artifact_dir: str, mesh=None
) -> Tuple[ModelApi, Any, "quant_api.Artifact"]:
    """Cold-start a zoo model from a packed artifact: (api, qparams, artifact).

    No fp32 weights are materialized and no calibration runs -- the QTensor
    tree loads packed, the plan (calibrated activation exponents included)
    comes from the manifest, and the model is rebuilt from the artifact's
    own serialized ArchConfig and bound to the plan.  With ``mesh`` every
    payload assembles straight onto its owning devices (per-host shard
    files via ``jax.make_array_from_single_device_arrays``); the global
    packed tree never exists on one host."""
    art = quant_api.load_artifact(artifact_dir, mesh=mesh)
    cfg_dict = art.extra.get("arch_config")
    if cfg_dict is None:
        raise ValueError(
            f"artifact at {artifact_dir!r} carries no 'arch_config' metadata; "
            "save it with repro.models.save_servable (or pass extra="
            "{'arch_config': config_to_dict(cfg)} to save_artifact)"
        )
    cfg = config_from_dict(cfg_dict)
    api = build_model(cfg)
    if art.plan is not None:
        api = api.with_plan(art.plan)
    return api, art.params, art
