"""Unified model API: family dispatch, input specs, PTQ conversion.

``build_model(cfg)`` returns a ``ModelApi`` whose members all have fixed
signatures so the trainer / server / dry-run treat every family uniformly:

  init(key) -> params
  train_loss(params, batch) -> scalar
  forward(params, batch) -> logits
  init_cache(batch, max_len) -> cache            (decode state)
  prefill(params, batch, cache) -> (logits, cache)
  decode(params, token, pos, cache) -> (logits, cache)
  input_specs(shape_cfg) -> (batch/spec pytree, kind)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import calibration
from repro.core.policy import PrecisionPolicy
from repro.core.quantizer import quantize_weights
from repro.models import encdec, hybrid, ssm_lm, transformer, vlm
from repro.models.layers import QuantCtx


@dataclasses.dataclass
class ModelApi:
    cfg: ArchConfig
    ctx: QuantCtx
    init: Callable
    train_loss: Callable
    forward: Callable
    init_cache: Callable
    prefill: Optional[Callable]
    decode: Callable


def make_ctx(cfg: ArchConfig) -> QuantCtx:
    q = cfg.quant
    if q.mode == "fp":
        return QuantCtx.fp()
    if q.w_bits == 2:
        pol = PrecisionPolicy.ternary(q.group_size, q.filter_size, q.refit_scale)
    elif q.w_bits == 4:
        pol = PrecisionPolicy.int4(q.group_size)
    else:
        pol = PrecisionPolicy.int8(q.group_size)
    return QuantCtx(q.mode, pol, q.backend)


def build_model(cfg: ArchConfig, ctx: Optional[QuantCtx] = None) -> ModelApi:
    ctx = ctx or make_ctx(cfg)
    fam = cfg.family
    if fam in ("dense", "moe"):
        return ModelApi(
            cfg, ctx,
            init=lambda key: transformer.init_lm(key, cfg),
            train_loss=lambda p, b: transformer.loss_fn(p, b, cfg, ctx),
            forward=lambda p, b: transformer.forward(p, b["tokens"], cfg, ctx),
            init_cache=lambda b, m: transformer.init_cache(cfg, b, m),
            prefill=lambda p, b, c: transformer.prefill(p, b["tokens"], cfg, ctx, c),
            decode=lambda p, t, pos, c: transformer.decode_step(p, t, pos, cfg, ctx, c),
        )
    if fam == "vlm":
        return ModelApi(
            cfg, ctx,
            init=lambda key: transformer.init_lm(key, cfg),
            train_loss=lambda p, b: vlm.loss_fn(p, b, cfg, ctx),
            forward=lambda p, b: vlm.forward(p, b, cfg, ctx),
            init_cache=lambda b, m: transformer.init_cache(cfg, b, m),
            prefill=lambda p, b, c: vlm.prefill(p, b, cfg, ctx, c),
            decode=lambda p, t, pos, c: transformer.decode_step(p, t, pos, cfg, ctx, c),
        )
    if fam == "hybrid":
        return ModelApi(
            cfg, ctx,
            init=lambda key: hybrid.init_hybrid(key, cfg),
            train_loss=lambda p, b: hybrid.loss_fn(p, b, cfg, ctx),
            forward=lambda p, b: hybrid.forward(p, b["tokens"], cfg, ctx),
            init_cache=lambda b, m: hybrid.init_cache(cfg, b, m),
            prefill=None,  # hybrid prefill == forward + state replay (engine-level)
            decode=lambda p, t, pos, c: hybrid.decode_step(p, t, pos, cfg, ctx, c),
        )
    if fam == "ssm":
        return ModelApi(
            cfg, ctx,
            init=lambda key: ssm_lm.init_ssm_lm(key, cfg),
            train_loss=lambda p, b: ssm_lm.loss_fn(p, b, cfg, ctx),
            forward=lambda p, b: ssm_lm.forward(p, b["tokens"], cfg, ctx),
            init_cache=lambda b, m: ssm_lm.init_cache(cfg, b, m),
            prefill=None,
            decode=lambda p, t, pos, c: ssm_lm.decode_step(p, t, pos, cfg, ctx, c),
        )
    if fam == "encdec":
        return ModelApi(
            cfg, ctx,
            init=lambda key: encdec.init_encdec(key, cfg),
            train_loss=lambda p, b: encdec.loss_fn(p, b, cfg, ctx),
            forward=lambda p, b: encdec.forward(p, b, cfg, ctx),
            init_cache=lambda b, m: encdec.init_cache(cfg, b, m),
            prefill=lambda p, b, c: encdec.prefill(p, b, cfg, ctx, c),
            decode=lambda p, t, pos, c: encdec.decode_step(p, t, pos, cfg, ctx, c),
        )
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Input specs: one cell = (arch x shape); used by smoke tests (concrete) and
# the dry-run (ShapeDtypeStruct, no allocation).
# ---------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[Dict[str, Any], str]:
    """Returns ({name: ShapeDtypeStruct}, kind). Token count semantics:
    train/prefill feed (B, S); decode feeds one token with an S-long cache."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        if cfg.family == "encdec":
            return (
                {
                    "frames": jax.ShapeDtypeStruct((b, cfg.n_audio_frames, cfg.d_model), f),
                    "tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "labels": jax.ShapeDtypeStruct((b, s), i32),
                },
                "train",
            )
        if cfg.family == "vlm":
            nv = cfg.n_frontend_tokens
            return (
                {
                    "tokens": jax.ShapeDtypeStruct((b, s - nv), i32),
                    "labels": jax.ShapeDtypeStruct((b, s - nv), i32),
                    "vision_embeds": jax.ShapeDtypeStruct((b, nv, cfg.d_model), f),
                    "positions": jax.ShapeDtypeStruct((3, b, s), i32),
                },
                "train",
            )
        return (
            {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            },
            "train",
        )
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return (
                {
                    "frames": jax.ShapeDtypeStruct((b, cfg.n_audio_frames, cfg.d_model), f),
                    "tokens": jax.ShapeDtypeStruct((b, s), i32),
                },
                "prefill",
            )
        if cfg.family == "vlm":
            nv = cfg.n_frontend_tokens
            return (
                {
                    "tokens": jax.ShapeDtypeStruct((b, s - nv), i32),
                    "vision_embeds": jax.ShapeDtypeStruct((b, nv, cfg.d_model), f),
                    "positions": jax.ShapeDtypeStruct((3, b, s), i32),
                },
                "prefill",
            )
        return ({"tokens": jax.ShapeDtypeStruct((b, s), i32)}, "prefill")
    # decode: one new token against an S-long cache
    return ({"token": jax.ShapeDtypeStruct((b, 1), i32)}, "decode")


def make_smoke_batch(key, cfg: ArchConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Concrete small training batch for CPU smoke tests."""
    kt, kv = jax.random.split(key)
    f = jnp.dtype(cfg.dtype)
    out: Dict[str, Any] = {
        "tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(kv, (batch, seq), 0, cfg.vocab, jnp.int32),
    }
    if cfg.family == "encdec":
        out["frames"] = (
            jax.random.normal(kv, (batch, cfg.n_audio_frames, cfg.d_model)) * 0.1
        ).astype(f)
    if cfg.family == "vlm":
        nv = cfg.n_frontend_tokens
        out["vision_embeds"] = (
            jax.random.normal(kv, (batch, nv, cfg.d_model)) * 0.1
        ).astype(f)
        out["positions"] = vlm.build_mrope_positions(batch, nv, seq)
        out["labels"] = out["labels"]
    return out


# ---------------------------------------------------------------------------
# PTQ: convert trained params to QTensor weights per the precision policy.
# ---------------------------------------------------------------------------
def quantize_model_params(params, policy: PrecisionPolicy):
    """Walk the param tree; replace projection 'w' leaves with QTensors.

    Stacked leading axes (layers and/or experts) are vmapped over.  The
    embedding table (a gather, not a GEMM) is snapped to the 8-bit DFP grid
    in place (values quantized, storage dtype unchanged).
    """

    def quant_w(w, prec):
        def q2(m):
            return quantize_weights(
                m, prec.w_bits, prec.group_size, prec.filter_size, prec.refit_scale
            )

        fn = q2
        for _ in range(w.ndim - 2):
            fn = jax.vmap(fn)
        return fn(w.astype(jnp.float32))

    def walk(node, path):
        if isinstance(node, dict):
            out = {}
            for key, val in node.items():
                sub = f"{path}/{key}" if path else key
                if key == "w" and hasattr(val, "ndim") and val.ndim >= 2:
                    prec = policy.resolve(path)
                    if prec.quantized and prec.w_bits < 16:
                        kdim = val.shape[-2]
                        if kdim % prec.group_size == 0 and kdim % 16 == 0:
                            out[key] = quant_w(val, prec)
                            continue
                    out[key] = val
                elif key == "table" and hasattr(val, "ndim"):
                    out[key] = calibration.fake_quantize_act(
                        val.astype(jnp.float32), 8, per_row=True
                    ).astype(val.dtype)
                else:
                    out[key] = walk(val, sub)
            return out
        return node

    return walk(params, "")
