"""Building-block layers (pure pytree params, no framework dependency).

Every projection goes through ``dense()`` which consults the quantization
context (``repro.quant.QuantCtx``, a thin view over a compiled ``QuantPlan``
or a raw ``PrecisionPolicy``): full precision, QAT fake-quant (STE, Sec. 4
of the paper), or PTQ with real QTensor weights through the registry-driven
``qdense`` -- one whole-site call that carries the bias and an optional
activation into the kernel epilogue, so on fused backends (pallas) a
projection is a single pallas_call with no intermediate HBM round-trips.
With a compiled plan, per-site precision is a dict lookup (no per-call
regex), PTQ activations use the plan's calibrated static exponents where
profiled (per-site ``fused``/``static_act`` knobs), and a ctx carrying an
``observer`` records activation ranges for calibration.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import ste
from repro.quant.api import observe_site
from repro.quant.backends import apply_act, qdense
from repro.quant.plan import QuantCtx  # noqa: F401  (canonical re-export)
from repro.quant.qtensor import QTensor

Params = Dict[str, Any]


def _init_dense(key, d_in: int, d_out: int, bias: bool, dtype) -> Params:
    std = d_in**-0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * std}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(
    p: Params, x: jax.Array, path: str, ctx: QuantCtx,
    act: Optional[str] = None,
) -> jax.Array:
    """Quantization-aware projection x @ W (+ b) (+ activation ``act``).

    ``act`` ("silu"/"gelu"/"relu") rides into the PTQ kernel epilogue on
    fused backends; on the fp/QAT paths it is applied after the bias, so all
    modes compute the same function.
    """
    w = p["w"]
    if ctx.observer is not None:  # calibration pass: record this site's range
        observe_site(ctx.observer, path, x)
    if isinstance(w, QTensor):  # PTQ path: full integer pipeline, one call
        prec = ctx.resolve(path)
        y = qdense(
            x, w,
            bias=p.get("b"), act=act, backend=ctx.backend,
            act_bits=prec.act_bits if prec else 8,
            act_exponent=ctx.act_exponent(path),
            fused=prec.fused if prec else True,
        )
        return y.astype(x.dtype)
    if ctx.mode == "qat" and (ctx.plan is not None or ctx.policy is not None):
        prec = ctx.resolve(path)
        if prec is not None and prec.quantized:
            wf = w.astype(jnp.float32)
            if "inq_mask" in p:  # learned-grid INQ: the whole tensor
                # fake-quantizes onto the TRAINED cluster grid (codes
                # re-derived from w/s exactly as deployment derives them);
                # events freeze w updates, the grid keeps training
                wq = ste.inq_ste(
                    wf, p["inq_mask"], p["inq_scales"], prec.w_bits,
                    prec.group_size, prec.filter_size, prec.refit_scale,
                    fmt=prec.fmt,
                ).astype(x.dtype)
            elif prec.fmt == "ttq" and "ttq_scales" in p:
                wq = ste.ttq_ste(
                    wf, p["ttq_scales"], prec.group_size
                ).astype(x.dtype)
            else:
                wq = ste.weights_ste(
                    wf,
                    prec.w_bits,
                    prec.group_size,
                    prec.filter_size,
                    prec.refit_scale,
                    fmt=prec.fmt,
                ).astype(x.dtype)
            xq = ste.act_ste(x.astype(jnp.float32), prec.act_bits).astype(x.dtype)
            y = xq @ wq
        else:
            y = x @ w
    else:
        y = x @ w
    if "b" in p:
        y = y + p["b"]
    return apply_act(y, act)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    sin, cos = jnp.sin(angles)[..., None, :], jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections=(1, 1, 2)
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: positions (3, ..., S) = (t, h, w) ids, the
    hd/2 frequency lanes are split across the three components in the ratio
    ``sections`` (defaults to paper's 1:1:2 t:h:w split)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    n = hd // 2
    total = sum(sections)
    bounds = [n * sum(sections[: i + 1]) // total for i in range(3)]
    lane = jnp.arange(n)
    comp = jnp.where(lane < bounds[0], 0, jnp.where(lane < bounds[1], 1, 2))
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32)[..., None] * jnp.ones_like(freqs),
        jnp.broadcast_to(comp, positions.shape[1:] + (n,))[None],
        axis=0,
    )[0]  # (..., S, hd/2): per-lane position from its component
    angles = pos * freqs
    sin, cos = jnp.sin(angles)[..., None, :], jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU) and embedding
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "up": _init_dense(k1, d_model, d_ff, False, dtype),
        "gate": _init_dense(k2, d_model, d_ff, False, dtype),
        "down": _init_dense(k3, d_ff, d_model, False, dtype),
    }


def mlp(p: Params, x: jax.Array, path: str, ctx: QuantCtx) -> jax.Array:
    # silu rides into the gate projection's kernel epilogue on fused backends
    h = dense(p["gate"], x, f"{path}/gate", ctx, act="silu")
    h = h * dense(p["up"], x, f"{path}/up", ctx)
    return dense(p["down"], h, f"{path}/down", ctx)


def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * (d**-0.5)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def lm_loss(logits: jax.Array, labels: jax.Array, vocab: int) -> jax.Array:
    """Cross entropy with vocab padding masked out of the partition function."""
    from repro.parallel import sharding as _sh

    logits = _sh.constrain(logits, ("batch", None, "feat"))
    logits = logits.astype(jnp.float32)
    pad = logits.shape[-1] - vocab
    if pad > 0:
        mask = jnp.concatenate(
            [jnp.zeros((vocab,), jnp.float32), jnp.full((pad,), -1e30, jnp.float32)]
        )
        logits = logits + mask
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def lm_head_loss(
    head: Params,
    x: jax.Array,  # (B, S, d) final hidden states
    labels: jax.Array,  # (B, S)
    vocab: int,
    path: str,
    ctx: "QuantCtx",
    chunk_tokens: int = 8192,
) -> jax.Array:
    """Fused lm_head + cross entropy, chunked over tokens.

    The full (B, S, V) f32 logits tensor is never materialized: each chunk's
    logits are computed, reduced to (lse, gold) and recomputed in the
    backward pass (jax.checkpoint).  Peak logits memory drops from
    O(B*S*V) to O(chunk*V) -- the dominant activation for large-vocab archs.
    """
    from repro.parallel import sharding as _sh

    b, s, d = x.shape
    t = b * s
    xt = _sh.constrain(x.reshape(t, d), ("batch", None))
    lt = labels.reshape(t)
    n_chunks = max(1, t // max(chunk_tokens, 1))
    while t % n_chunks:
        n_chunks -= 1
    tc = t // n_chunks
    padded = head["w"].shape[-1]
    pad = padded - vocab
    mask = None
    if pad > 0:
        mask = jnp.concatenate(
            [jnp.zeros((vocab,), jnp.float32), jnp.full((pad,), -1e30, jnp.float32)]
        )

    def body(acc, inp):
        xc, lc = inp
        xc = _sh.constrain(xc, ("batch", None))
        logits = dense(head, xc, path, ctx)
        logits = _sh.constrain(logits, ("batch", "feat")).astype(jnp.float32)
        if mask is not None:
            logits = logits + mask
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[:, None], axis=-1)[:, 0]
        return acc + jnp.sum(lse - gold), None

    if n_chunks == 1:
        loss, _ = body(jnp.zeros((), jnp.float32), (xt, lt))
    else:
        loss, _ = jax.lax.scan(
            jax.checkpoint(body),
            jnp.zeros((), jnp.float32),
            (xt.reshape(n_chunks, tc, d), lt.reshape(n_chunks, tc)),
        )
    return loss / t


def init_dense_layer(key, d_in, d_out, bias, dtype) -> Params:
    return _init_dense(key, d_in, d_out, bias, dtype)
