"""Compiled precision plans: the static per-layer quantization table.

``PrecisionPolicy`` (core/policy.py) is a *rule set* -- a default precision
plus ordered regex overrides.  A ``QuantPlan`` is that rule set *compiled*
against a concrete parameter tree: every projection site's path is resolved
exactly once into a ``LayerPrecision`` table, so the hot path (``dense()``)
does a dict lookup instead of a per-call ``re.search`` ladder.  The plan is

  * registered as a pytree (all-static leaves: it rides along inside jitted
    closures and checkpoint trees without retracing hazards),
  * JSON-serializable (``to_json``/``from_json``) so PTQ checkpoints carry
    their plan,
  * calibration-aware: ``act_exponents`` maps site path -> shared 8-bit DFP
    activation exponent profiled by the observer pass (the paper's static
    "profiled DFP" mode); sites without an entry fall back to dynamic
    per-row exponents, selectable per layer via ``LayerPrecision.static_act``.

``QuantCtx`` is the thin per-forward view models consult: mode + backend +
(plan | policy) + an optional calibration observer.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, MutableMapping, Optional, Tuple

import jax

from repro.core.policy import LayerPrecision, PrecisionPolicy

ActExponents = Tuple[Tuple[str, int], ...]


def _prec_to_dict(p: LayerPrecision) -> Dict[str, Any]:
    return dataclasses.asdict(p)


def _prec_from_dict(d: Dict[str, Any]) -> LayerPrecision:
    return LayerPrecision(**d)


@dataclasses.dataclass(frozen=True)
class QuantPlan:
    """Compiled, serializable quantization plan for one parameter tree."""

    site_paths: Tuple[str, ...] = ()
    site_precisions: Tuple[LayerPrecision, ...] = ()
    policy: Optional[PrecisionPolicy] = None  # fallback for un-compiled paths
    mode: str = "ptq"  # 'qat' | 'ptq'
    backend: str = "auto"
    act_exponents: ActExponents = ()  # (site path, int32 exponent) pairs

    def __post_init__(self):
        object.__setattr__(
            self, "_table", dict(zip(self.site_paths, self.site_precisions))
        )
        object.__setattr__(self, "_exps", dict(self.act_exponents))

    # -- resolution (the compiled fast path) -------------------------------
    def resolve(self, path: str) -> Optional[LayerPrecision]:
        prec = self._table.get(path)
        if prec is None and self.policy is not None:
            prec = self.policy.resolve(path)  # regex fallback, off-plan paths
        return prec

    def act_exponent(self, path: str) -> Optional[int]:
        """Calibrated static activation exponent for a site, if profiled and
        the site's precision opts in (``static_act``)."""
        e = self._exps.get(path)
        if e is None:
            return None
        prec = self.resolve(path)
        if prec is not None and not prec.static_act:
            return None
        return e

    def sites(self) -> Tuple[Tuple[str, LayerPrecision], ...]:
        return tuple(zip(self.site_paths, self.site_precisions))

    @property
    def calibrated(self) -> bool:
        return bool(self.act_exponents)

    def with_act_exponents(self, exps: Dict[str, int]) -> "QuantPlan":
        pairs = tuple(sorted((str(k), int(v)) for k, v in exps.items()))
        return dataclasses.replace(self, act_exponents=pairs)

    # -- serialization -----------------------------------------------------
    def to_json(self) -> str:
        pol = None
        if self.policy is not None:
            pol = {
                "default": _prec_to_dict(self.policy.default),
                "overrides": [
                    [pat, _prec_to_dict(p)] for pat, p in self.policy.overrides
                ],
            }
        return json.dumps(
            {
                "version": 1,
                "mode": self.mode,
                "backend": self.backend,
                "sites": [
                    [path, _prec_to_dict(prec)]
                    for path, prec in zip(self.site_paths, self.site_precisions)
                ],
                "policy": pol,
                "act_exponents": [[p, e] for p, e in self.act_exponents],
            }
        )

    @classmethod
    def from_json(cls, blob: str) -> "QuantPlan":
        d = json.loads(blob)
        pol = None
        if d.get("policy") is not None:
            pol = PrecisionPolicy(
                default=_prec_from_dict(d["policy"]["default"]),
                overrides=tuple(
                    (pat, _prec_from_dict(p)) for pat, p in d["policy"]["overrides"]
                ),
            )
        return cls(
            site_paths=tuple(path for path, _ in d["sites"]),
            site_precisions=tuple(_prec_from_dict(p) for _, p in d["sites"]),
            policy=pol,
            mode=d["mode"],
            backend=d["backend"],
            act_exponents=tuple((p, int(e)) for p, e in d["act_exponents"]),
        )


# All fields are static metadata: the plan has no array leaves, so it can sit
# inside jit closures, checkpoint trees and vmapped calls for free.
jax.tree_util.register_dataclass(
    QuantPlan,
    data_fields=[],
    meta_fields=[
        "site_paths", "site_precisions", "policy", "mode", "backend",
        "act_exponents",
    ],
)


def is_projection_site(key: str, val) -> bool:
    """One predicate for 'this leaf is a quantizable projection weight',
    shared by plan compilation and param conversion so the compiled table
    and the conversion walk can never disagree about what a site is."""
    return key == "w" and hasattr(val, "ndim") and val.ndim >= 2


def site_subpath(path: str, key: str) -> str:
    """The one path-construction rule ('a/b/c', matching dense() strings)."""
    return f"{path}/{key}" if path else key


def iter_weight_sites(params) -> Tuple[Tuple[str, Any], ...]:
    """All projection sites in a param tree: (path, w-leaf) for every dict
    node holding a 2-D+ ``w``.  Paths match the strings models pass to
    ``dense()`` (stacked layer/expert axes add no path component)."""
    sites = []

    def walk(node, path):
        if not isinstance(node, dict):
            return
        for key, val in node.items():
            if is_projection_site(key, val):
                sites.append((path, val))
            elif isinstance(val, dict):
                walk(val, site_subpath(path, key))

    walk(params, "")
    return tuple(sites)


def compile_policy(
    policy: PrecisionPolicy,
    params,
    *,
    mode: str = "ptq",
    backend: str = "auto",
) -> QuantPlan:
    """Walk ``params`` once, resolving every projection site's precision.

    Works on concrete arrays or ShapeDtypeStructs (only ``ndim`` is read),
    so plans compile under ``jax.eval_shape`` for the dry-run.
    """
    paths, precs = [], []
    for path, _ in iter_weight_sites(params):
        paths.append(path)
        precs.append(policy.resolve(path))
    return QuantPlan(
        site_paths=tuple(paths),
        site_precisions=tuple(precs),
        policy=policy,
        mode=mode,
        backend=backend,
    )


# ---------------------------------------------------------------------------
# QuantCtx: the per-forward view models consult.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class QuantCtx:
    """Thin view over a QuantPlan (or, pre-compile, a PrecisionPolicy).

    mode      : 'fp' | 'qat' | 'ptq'
    policy    : regex rule set (used until a plan is compiled, and as the
                fallback for paths outside the compiled table)
    backend   : qmatmul backend for PTQ
    plan      : compiled precision plan (dict-lookup resolution + calibrated
                activation exponents)
    observer  : mutable {site: {"max_abs", "msq", "count"}} host store; when
                set, ``dense()`` records activation ranges (calibration pass)
    """

    mode: str = "fp"  # 'fp' | 'qat' | 'ptq'
    policy: Optional[PrecisionPolicy] = None
    backend: str = "auto"  # ptq matmul backend
    plan: Optional[QuantPlan] = None
    observer: Optional[MutableMapping] = dataclasses.field(
        default=None, compare=False
    )

    @staticmethod
    def fp() -> "QuantCtx":
        return QuantCtx("fp", None)

    @classmethod
    def from_config(cls, q) -> "QuantCtx":
        """Build the pre-compile ctx from a configs.base.QuantConfig."""
        if q.mode == "fp":
            return cls.fp()
        if getattr(q, "fmt", None):  # named registered format (nf4, mx, ...)
            pol = PrecisionPolicy.for_format(
                q.fmt, q.group_size, q.filter_size, q.refit_scale
            )
        elif q.w_bits == 2:
            pol = PrecisionPolicy.ternary(q.group_size, q.filter_size, q.refit_scale)
        elif q.w_bits == 4:
            pol = PrecisionPolicy.int4(q.group_size)
        else:
            pol = PrecisionPolicy.int8(q.group_size)
        return cls(q.mode, pol, q.backend)

    @classmethod
    def for_plan(cls, plan: QuantPlan) -> "QuantCtx":
        return cls(plan.mode, plan.policy, plan.backend, plan=plan)

    def with_observer(self, observer: MutableMapping) -> "QuantCtx":
        return dataclasses.replace(self, observer=observer)

    def resolve(self, path: str) -> Optional[LayerPrecision]:
        if self.plan is not None:
            return self.plan.resolve(path)
        if self.policy is not None:
            return self.policy.resolve(path)
        return None

    def act_exponent(self, path: str) -> Optional[int]:
        if self.plan is None:
            return None
        return self.plan.act_exponent(path)
