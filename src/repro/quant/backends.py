"""Backend registry for the quantized matmul: pluggable execution strategies.

``qmatmul(x, qt)`` is the single entry point for PTQ inference.  It runs a
shared activation-quantization prologue (per-row dynamic DFP exponents, or a
calibrated static per-site exponent when the ``QuantPlan`` carries one) and
then dispatches to a registered backend strategy:

  * ``pallas``   : the real integer pipeline (TPU target; runs in interpret
                   mode on CPU so tests validate the exact kernel
                   semantics).  The kernel itself comes from the *format*
                   registry, so new weight encodings plug in here too.
  * ``xla``      : dequantize-weights -> bf16 dot.  Mathematically identical
                   up to f32 rounding; this is what the distributed (pjit)
                   graph lowers for the dry-run, where collectives/sharding
                   are the object of study.
  * ``xla_int8`` : integer pipeline without Pallas -- per-group batched int8
                   dots with int32 accumulation (2x int8 MXU path, 1 B/elem
                   weight stream).
  * ``ref``      : the pure-jnp oracle (bit-exact integer semantics).
  * ``auto``     : resolves to pallas on TPU, xla otherwise.

Every strategy receives the already-quantized activations ``(xq, xe)`` plus
the QTensor, so registering a new backend is one function -- there is no
string-compare ladder to extend (that lived in ``kernels/ops.py`` before
this registry).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dfp
from repro.core.quantizer import QTensor
from repro.kernels.quantize import quantize_rows
from repro.kernels.ref import qmatmul_ref, quantize_rows_ref

# fn(xq int8 (M, K), xe int32 ((M,1) or scalar), qt, *, block_m, block_n,
#    block_k) -> f32 (M, N), exponents applied.
BackendFn = Callable[..., jax.Array]

_BACKENDS: Dict[str, BackendFn] = {}


def register_backend(name: str, fn: BackendFn, *, overwrite: bool = False) -> None:
    if name in _BACKENDS and not overwrite:
        raise ValueError(f"backend {name!r} already registered")
    _BACKENDS[name] = fn


def get_backend(name: str) -> BackendFn:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {sorted(_BACKENDS)}"
        ) from None


def backend_names() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(name: str) -> str:
    """'auto' -> pallas on TPU, xla elsewhere; concrete names pass through."""
    if name == "auto":
        return "pallas" if _on_tpu() else "xla"
    return name


# ---------------------------------------------------------------------------
# Shared activation-quantization prologue.
# ---------------------------------------------------------------------------
def quantize_activations(
    x: jax.Array, bits: int = 8, use_pallas: Optional[bool] = None
):
    """Per-row dynamic DFP quantization -> (int8 mantissas, int32 exponents).

    Three explicit paths:
      * pallas on TPU        (use_pallas defaults to True on TPU),
      * pallas interpret mode (use_pallas=True off-TPU; exact but slow --
        used by tests to validate the kernel semantics),
      * the jnp reference    (use_pallas=False; default off-TPU).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return quantize_rows_ref(x, bits)
    return quantize_rows(x, bits=bits, interpret=not _on_tpu())


def _quantize_acts(xm: jax.Array, act_bits: int, act_exponent) -> Tuple[jax.Array, jax.Array]:
    """Dynamic per-row exponents, or the plan's calibrated static exponent."""
    if act_exponent is None:
        return quantize_rows_ref(xm, act_bits)
    e = jnp.asarray(act_exponent, jnp.int32)
    return dfp.quantize(xm, e, act_bits), e


# ---------------------------------------------------------------------------
# Built-in backend strategies.
# ---------------------------------------------------------------------------
def _xla_backend(xq, xe, qt: QTensor, **_):
    # float-side equivalent: fake-quantized activations x dequant weights
    # (f32 dot output; a bf16-output variant was tried as Perf iteration
    # B3 and had NO effect on collective bytes -- the TP reductions in
    # the MoE cells come from the combine scatter-add, see moe.py B4)
    from repro.quant.formats import dequantize_weights

    xf = dfp.dequantize(xq, xe).astype(jnp.bfloat16)
    w = dequantize_weights(qt).astype(jnp.bfloat16)
    return jax.lax.dot_general(
        xf, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _xla_int8_backend(xq, xe, qt: QTensor, **_):
    # integer pipeline without Pallas: per-group batched int8 dots with
    # int32 accumulation; weights materialize as int8 codes (1 B/elem)
    # instead of a scaled bf16 copy (2 B/elem) -- halves the decode-phase
    # weight stream and uses the 2x int8 MXU path on TPU.
    from repro.quant.formats import decode_codes

    g = qt.group_size
    m = xq.shape[0]
    kg = qt.k // g
    xg = jnp.moveaxis(xq.reshape(m, kg, g), 1, 0)  # (Kg, M, G) int8
    wg = decode_codes(qt).reshape(kg, g, qt.n)  # (Kg, G, N) int8
    part = jax.lax.dot_general(
        xg, wg, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )  # (Kg, M, N) int32
    scaled = part.astype(jnp.float32) * qt.scale_m.astype(jnp.float32)[:, None, :]
    out = scaled.sum(axis=0)
    exp = qt.scale_e.astype(jnp.float32) + xe.astype(jnp.float32)
    return out * jnp.exp2(exp)


def _ref_backend(xq, xe, qt: QTensor, **_):
    return qmatmul_ref(xq, xe, qt)


def _pallas_backend(xq, xe, qt: QTensor, *, block_m=128, block_n=128, block_k=512):
    from repro.quant.formats import format_of

    kernel = format_of(qt).kernel
    if kernel is None:
        raise ValueError(
            f"format for bits={qt.bits} has no Pallas kernel registered"
        )
    interpret = not _on_tpu()
    m = xq.shape[0]
    # pad rows to a tile multiple (serving batches are ragged)
    bm = min(block_m, max(8, m))
    pad = (-m) % bm
    if pad:
        xq = jnp.pad(xq, ((0, pad), (0, 0)))
    out = kernel(
        xq, qt.packed, qt.scale_m,
        group=qt.group_size, block_m=bm, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )
    out = out[:m] if pad else out
    exp = qt.scale_e.astype(jnp.float32) + xe.astype(jnp.float32)
    return out * jnp.exp2(exp)


register_backend("xla", _xla_backend)
register_backend("xla_int8", _xla_int8_backend)
register_backend("ref", _ref_backend)
register_backend("pallas", _pallas_backend)


# ---------------------------------------------------------------------------
# The public quantized matmul.
# ---------------------------------------------------------------------------
def qmatmul(
    x: jax.Array,
    qt: QTensor,
    *,
    backend: str = "auto",
    act_bits: int = 8,
    act_exponent=None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
) -> jax.Array:
    """x [..., K] (float) x QTensor (K, N) -> [..., N] f32.

    Full integer pipeline: 8-bit DFP activations (per-row dynamic exponents,
    or the calibrated static ``act_exponent`` from a QuantPlan), sub-8-bit
    weights, int32 cluster accumulation, one scale multiply per cluster.
    """
    lead = x.shape[:-1]
    xm = x.reshape(-1, x.shape[-1])
    fn = get_backend(resolve_backend(backend))
    xq, xe = _quantize_acts(xm, act_bits, act_exponent)
    out = fn(xq, xe, qt, block_m=block_m, block_n=block_n, block_k=block_k)
    return out.reshape(*lead, qt.n)


@functools.partial(jax.jit, static_argnames=("backend", "act_bits"))
def qmatmul_jit(x, qt, backend="auto", act_bits=8):
    return qmatmul(x, qt, backend=backend, act_bits=act_bits)
