"""Backend registry for the quantized matmul: pluggable execution strategies.

``qmatmul(x, qt)`` is the single entry point for PTQ inference.  It runs a
shared activation-quantization prologue (per-row dynamic DFP exponents, or a
calibrated static per-site exponent when the ``QuantPlan`` carries one) and
then dispatches to a registered backend strategy:

  * ``pallas``   : the real integer pipeline (TPU target; runs in interpret
                   mode on CPU so tests validate the exact kernel
                   semantics).  The kernel itself comes from the *format*
                   registry, so new weight encodings plug in here too.
  * ``xla``      : dequantize-weights -> bf16 dot.  Mathematically identical
                   up to f32 rounding; this is what the distributed (pjit)
                   graph lowers for the dry-run, where collectives/sharding
                   are the object of study.
  * ``xla_int8`` : integer pipeline without Pallas -- per-group batched int8
                   dots with int32 accumulation (2x int8 MXU path, 1 B/elem
                   weight stream).
  * ``ref``      : the pure-jnp oracle (bit-exact integer semantics).
  * ``pallas_ep``: pallas for plain dense sites; MoE expert sites
                   additionally route through ``expert_ffn_ep`` -- the whole
                   expert FFN wrapped in ``shard_map`` over the expert
                   ('model') mesh axis, with the dispatch/combine
                   all-to-alls inside the body, so each device decodes and
                   activation-quantizes only its local expert slices.
  * ``auto``     : resolves to pallas on TPU, xla otherwise.

Every strategy receives the already-quantized activations ``(xq, xe)`` plus
the QTensor, so registering a new backend is one function -- there is no
string-compare ladder to extend (that lived in ``kernels/ops.py`` before
this registry).

``qdense(x, qt, bias=..., act=...)`` is the whole-site entry point serving
uses: on backends with a registered *fused* strategy
(``register_fused_backend``; built-in: ``pallas``) the quantize prologue,
matmul, exponent scaling, bias and activation run as ONE pallas_call with no
intermediate HBM materialization -- the unfused three-pass composition
(quantize -> matmul -> scale/bias/act) remains the fallback and the ``ref``
backend stays the bit-exact oracle for both.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dfp
from repro.core.quantizer import QTensor
from repro.kernels._common import activation_fn, m_bucket, pick_block
from repro.kernels.quantize import quantize_rows
from repro.kernels.ref import qmatmul_ref, quantize_rows_ref

# fn(xq int8 (M, K), xe int32 ((M,1) or scalar), qt, *, block_m, block_n,
#    block_k) -> f32 (M, N), exponents applied.
BackendFn = Callable[..., jax.Array]

_BACKENDS: Dict[str, BackendFn] = {}


def register_backend(name: str, fn: BackendFn, *, overwrite: bool = False) -> None:
    if name in _BACKENDS and not overwrite:
        raise ValueError(f"backend {name!r} already registered")
    _BACKENDS[name] = fn


def get_backend(name: str) -> BackendFn:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {sorted(_BACKENDS)}"
        ) from None


def backend_names() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(name: str) -> str:
    """'auto' -> pallas on TPU, xla elsewhere; concrete names pass through."""
    if name == "auto":
        return "pallas" if _on_tpu() else "xla"
    return name


# ---------------------------------------------------------------------------
# Shared activation-quantization prologue (the ONE entry point: static or
# dynamic exponents, pallas or jnp -- formerly split across two near-duplicate
# functions, one of which never reached the Pallas kernel even on TPU).
# ---------------------------------------------------------------------------
def quantize_activations(
    x: jax.Array,
    bits: int = 8,
    use_pallas: Optional[bool] = None,
    *,
    exponent=None,
) -> Tuple[jax.Array, jax.Array]:
    """DFP-quantize activations -> (int8 mantissas, int32 exponent(s)).

    With ``exponent`` (a calibrated static per-site DFP exponent from a
    QuantPlan) the mantissas are computed directly against it -- no range
    scan.  Otherwise per-row dynamic exponents, through one of three
    explicit paths:
      * pallas on TPU        (use_pallas defaults to True on TPU),
      * pallas interpret mode (use_pallas=True off-TPU; exact but slow --
        used by tests to validate the kernel semantics),
      * the jnp reference    (use_pallas=False; default off-TPU).
    """
    if exponent is not None:
        e = jnp.asarray(exponent, jnp.int32)
        return dfp.quantize(x, e, bits), e
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return quantize_rows_ref(x, bits)
    return quantize_rows(x, bits=bits, interpret=not _on_tpu())


# ---------------------------------------------------------------------------
# Built-in backend strategies.
# ---------------------------------------------------------------------------
def _xla_backend(xq, xe, qt: QTensor, **_):
    # float-side equivalent: fake-quantized activations x dequant weights
    # (f32 dot output; a bf16-output variant was tried as Perf iteration
    # B3 and had NO effect on collective bytes -- the TP reductions in
    # the MoE cells come from the combine scatter-add, see moe.py B4)
    from repro.quant.formats import dequantize_weights

    xf = dfp.dequantize(xq, xe).astype(jnp.bfloat16)
    w = dequantize_weights(qt).astype(jnp.bfloat16)
    return jax.lax.dot_general(
        xf, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _xla_int8_backend(xq, xe, qt: QTensor, **_):
    # integer pipeline without Pallas: per-group batched int8 dots with
    # int32 accumulation; weights materialize as int8 codes (1 B/elem)
    # instead of a scaled bf16 copy (2 B/elem) -- halves the decode-phase
    # weight stream and uses the 2x int8 MXU path on TPU.
    from repro.quant.formats import decode_codes

    g = qt.group_size
    m = xq.shape[0]
    kg = qt.k // g
    xg = jnp.moveaxis(xq.reshape(m, kg, g), 1, 0)  # (Kg, M, G) int8
    wg = decode_codes(qt).reshape(kg, g, qt.n)  # (Kg, G, N) int8
    part = jax.lax.dot_general(
        xg, wg, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )  # (Kg, M, N) int32
    scaled = part.astype(jnp.float32) * qt.scale_m.astype(jnp.float32)[:, None, :]
    out = scaled.sum(axis=0)
    return out * dfp.exp2i(qt.scale_e + xe)


def _ref_backend(xq, xe, qt: QTensor, **_):
    return qmatmul_ref(xq, xe, qt)


def _pad_rows_to_bucket(x: jax.Array) -> Tuple[jax.Array, int]:
    """Pad ragged M up to a power-of-two bucket (>= 8).

    Every distinct (M, block) pair is a fresh kernel trace/compile; bucketing
    collapses the ragged serving batch sizes onto a handful of
    specializations (zero rows quantize to zero mantissas, so padded rows
    are inert)."""
    m = x.shape[0]
    pad = m_bucket(m) - m
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, m


def _pallas_backend(xq, xe, qt: QTensor, *, block_m=128, block_n=128, block_k=512):
    from repro.quant.formats import format_of

    kernel = format_of(qt).kernel
    if kernel is None:
        raise ValueError(
            f"format for bits={qt.bits} has no Pallas kernel registered"
        )
    xq, m = _pad_rows_to_bucket(xq)
    out = kernel(
        xq, qt.packed, qt.scale_m,
        group=qt.group_size, block_m=pick_block(xq.shape[0], block_m),
        block_n=block_n, block_k=block_k, interpret=not _on_tpu(),
    )
    out = out[:m]
    return out * dfp.exp2i(qt.scale_e + xe)


register_backend("xla", _xla_backend)
register_backend("xla_int8", _xla_int8_backend)
register_backend("ref", _ref_backend)
register_backend("pallas", _pallas_backend)
# Expert-parallel strategy: plain dense sites run the ordinary pallas path
# (the EP-ness only matters at MoE expert sites, which route through
# expert_ffn_ep below when a mesh is installed); registering it here makes
# "pallas_ep" a first-class backend name a QuantPlan can carry.
register_backend("pallas_ep", _pallas_backend)


# ---------------------------------------------------------------------------
# Fused whole-site strategies: take RAW activations and do prologue + matmul
# + epilogue in one kernel.  Backends without a fused entry fall back to the
# unfused composition inside qdense().
# ---------------------------------------------------------------------------
# fn(x f32/bf16 (M, K), qt, *, bias, act, act_bits, act_exponent, block_m,
#    block_n, block_k) -> f32 (M, N) finished output.
FusedFn = Callable[..., jax.Array]

_FUSED_BACKENDS: Dict[str, FusedFn] = {}


def register_fused_backend(name: str, fn: FusedFn, *, overwrite: bool = False) -> None:
    if name in _FUSED_BACKENDS and not overwrite:
        raise ValueError(f"fused backend {name!r} already registered")
    _FUSED_BACKENDS[name] = fn


def has_fused_backend(name: str) -> bool:
    return name in _FUSED_BACKENDS


def _pallas_fused(
    x, qt: QTensor, *, bias=None, act=None, act_bits=8, act_exponent=None,
    block_m=128, block_n=128, block_k=512,
):
    from repro.quant.formats import format_of

    kernel = format_of(qt).fused_kernel
    if kernel is None:
        raise ValueError(
            f"format {format_of(qt).name!r} has no fused Pallas kernel registered"
        )
    x, m = _pad_rows_to_bucket(x)
    out = kernel(
        x, qt.packed, qt.scale_m, qt.scale_e,
        group=qt.group_size, bias=bias, act=act, act_bits=act_bits,
        act_exponent=None if act_exponent is None else int(act_exponent),
        block_m=pick_block(x.shape[0], block_m), block_n=block_n,
        block_k=block_k, interpret=not _on_tpu(),
    )
    return out[:m]


register_fused_backend("pallas", _pallas_fused)
register_fused_backend("pallas_ep", _pallas_fused)


# ---------------------------------------------------------------------------
# The public quantized matmul.
# ---------------------------------------------------------------------------
def qmatmul(
    x: jax.Array,
    qt: QTensor,
    *,
    backend: str = "auto",
    act_bits: int = 8,
    act_exponent=None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
) -> jax.Array:
    """x [..., K] (float) x QTensor (K, N) -> [..., N] f32.

    Full integer pipeline: 8-bit DFP activations (per-row dynamic exponents,
    or the calibrated static ``act_exponent`` from a QuantPlan), sub-8-bit
    weights, int32 cluster accumulation, one scale multiply per cluster.
    """
    lead = x.shape[:-1]
    xm = x.reshape(-1, x.shape[-1])
    fn = get_backend(resolve_backend(backend))
    xq, xe = quantize_activations(xm, act_bits, exponent=act_exponent)
    out = fn(xq, xe, qt, block_m=block_m, block_n=block_n, block_k=block_k)
    return out.reshape(*lead, qt.n)


@functools.partial(jax.jit, static_argnames=("backend", "act_bits"))
def qmatmul_jit(x, qt, backend="auto", act_bits=8):
    return qmatmul(x, qt, backend=backend, act_bits=act_bits)


# ---------------------------------------------------------------------------
# The public quantized dense site (prologue + matmul + epilogue).
# ---------------------------------------------------------------------------
def apply_act(y: jax.Array, act: Optional[str]) -> jax.Array:
    return activation_fn(act)(y)  # same table as the fused kernel epilogue


def _fused_available(name: str, qt: QTensor) -> bool:
    """A fused strategy is usable only if the QTensor's format brought a
    fused kernel (register_format(..., fused_kernel=...)); formats without
    one -- including pre-existing third-party formats -- fall back to the
    unfused composition instead of raising."""
    if name not in _FUSED_BACKENDS:
        return False
    from repro.quant.formats import format_of

    return format_of(qt).fused_kernel is not None


# ---------------------------------------------------------------------------
# Expert-parallel fused FFN: shard_map over the expert ('model') axis.
# ---------------------------------------------------------------------------
def _qdense_stack(x, qt: QTensor, **kw):
    """qdense vmapped over a stacked (E_local, ...) expert axis: each local
    expert's site is one fused pallas_call over its local buffer slice."""
    return jax.vmap(lambda xe, qe: qdense(xe, qe, **kw), in_axes=(0, 0))(x, qt)


def ep_divisible(e: int, c: int, mesh, ep_axis: str = "model",
                 cap_axes: Tuple[str, ...] = ()) -> bool:
    """Can (E, C, d) expert buffers run the shard_map EP path on ``mesh``?

    Needs the expert count divisible by the EP axis and the capacity axis
    divisible by every axis it is sharded over (the all-to-alls split E by
    ep on dispatch and C by ep on combine)."""
    if mesh is None or ep_axis not in mesh.shape:
        return False
    ep = mesh.shape[ep_axis]
    cap = ep
    for a in cap_axes:
        cap *= mesh.shape[a]
    return ep > 1 and e % ep == 0 and c % cap == 0


def expert_ffn_ep(
    experts: Any,  # {"gate": QTensor (E, d, ff), "up": ..., "down": (E, ff, d)}
    x: jax.Array,  # (E, C, d) dispatched capacity buffer
    *,
    mesh,
    ep_axis: str = "model",
    cap_axes: Tuple[str, ...] = (),
    backend: str = "pallas_ep",
    site_kwargs: Optional[Dict[str, Dict[str, Any]]] = None,
) -> jax.Array:
    """The whole MoE expert FFN under expert parallelism, as ONE shard_map.

    The token side of the buffer arrives capacity-sharded (C over
    ``cap_axes + (ep_axis,)``, exactly how the dispatch scatter leaves it);
    inside the body an explicit ``all_to_all`` over the expert axis trades
    capacity shards for expert shards, the three projections run the fused
    ``qdense`` path on the LOCAL expert slices only (gate's silu rides in
    its kernel epilogue; h never leaves the shard), and a second
    ``all_to_all`` combines back to capacity sharding.  Each device decodes
    and activation-quantizes only its own experts' slices -- the partitioner
    can no longer replicate the f32 act-quant tensors across the mesh (the
    failure mode of the vmapped qmatmul path, moe.py Perf iteration B7).

    ``site_kwargs``: optional per-site qdense kwargs keyed
    "gate"/"up"/"down" (act_bits / act_exponent / fused from the compiled
    plan) -- per-site so the EP path quantizes each projection exactly like
    the single-device oracle composition does.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    sites = site_kwargs or {}
    kw = lambda name: dict(backend=backend, **sites.get(name, {}))

    def body(gq, uq, dq, xs):
        # xs: (E, C_local, d) -- this device's capacity shard of every expert.
        # Dispatch all-to-all: trade the expert axis for the capacity axis so
        # each device holds (E/ep, C_over_cap_axes, d) -- its experts, every
        # token routed to them.
        xl = jax.lax.all_to_all(xs, ep_axis, split_axis=0, concat_axis=1,
                                tiled=True)
        h = _qdense_stack(xl, gq, act="silu", **kw("gate"))
        # h stays f32 into the down projection, exactly like the unfused
        # oracle composition -- casting to the model dtype here would break
        # bit parity with the single-device path on bf16 models
        h = h * _qdense_stack(xl, uq, **kw("up"))
        y = _qdense_stack(h, dq, **kw("down"))
        # Combine all-to-all: back to capacity sharding for the gather.
        # Cast to the model dtype FIRST -- astype is elementwise, so moving
        # it across the pure data movement is bit-identical, and the combine
        # collective then moves half the bytes on bf16 models (the non-EP
        # combine learned the same lesson as Perf iteration B4, moe.py).
        y = jax.lax.all_to_all(y.astype(xs.dtype), ep_axis, split_axis=1,
                               concat_axis=0, tiled=True)
        return y

    cap = tuple(cap_axes) + (ep_axis,)
    xspec = P(None, cap, None)
    wspec = P(ep_axis)  # leading expert axis of every QTensor field
    fn = shard_map(
        body, mesh,
        in_specs=(wspec, wspec, wspec, xspec),
        out_specs=xspec,
        check_rep=False,
    )
    return fn(experts["gate"], experts["up"], experts["down"], x)


def qdense(
    x: jax.Array,
    qt: QTensor,
    *,
    bias: Optional[jax.Array] = None,
    act: Optional[str] = None,
    backend: str = "auto",
    act_bits: int = 8,
    act_exponent=None,
    fused: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
) -> jax.Array:
    """One quantized dense site: x [..., K] -> f32 [..., N] with the scale
    exponents, ``bias`` and ``act`` ("silu"/"gelu"/"relu") already applied.

    On a backend with a registered fused strategy (and ``fused=True``, the
    per-site plan knob) the whole site is ONE kernel launch: activations are
    quantized in-VMEM (per-row dynamic exponents on the first k-step, or the
    plan's calibrated static ``act_exponent`` baked in as a scalar) and the
    ``exp2(scale_e + xe)`` / bias / activation epilogue runs inside the
    resident output tile.  Other backends compose the identical math from
    the unfused pieces, so ``backend="ref"`` remains the bit-exact oracle
    for the fused path.
    """
    lead = x.shape[:-1]
    xm = x.reshape(-1, x.shape[-1])
    name = resolve_backend(backend)
    if fused and _fused_available(name, qt):
        out = _FUSED_BACKENDS[name](
            xm, qt, bias=bias, act=act, act_bits=act_bits,
            act_exponent=act_exponent, block_m=block_m, block_n=block_n,
            block_k=block_k,
        )
    else:
        xq, xe = quantize_activations(xm, act_bits, exponent=act_exponent)
        out = get_backend(name)(
            xq, xe, qt, block_m=block_m, block_n=block_n, block_k=block_k
        )
        if bias is not None:
            out = out + bias.astype(jnp.float32)
        out = apply_act(out, act)
    return out.reshape(*lead, qt.n)
