"""Trainable quantization state: TTQ learned scales + INQ freeze masks.

The QAT stack was stateless -- ``core/ste.py`` re-fit scales from the master
weights on every forward and the backward was identity-only, so a learned
scale (TTQ, arxiv 1612.01064) or a progressive freeze mask (INQ, arxiv
1702.03044) had nowhere to live, train, checkpoint, or reach the deployed
plan.  This module gives that state a home.

State leaves live *inside* the param tree at the projection-site dict nodes,
next to the ``w`` they govern:

  ``ttq_scales`` : (..., 2, G, N) f32 -- trained Wp/Wn cluster magnitudes
                   (trainable; the optimizer excludes them from weight decay
                   and keeps f32 moments even under DFP-8 moment state)
  ``inq_mask``   : (..., K, N) f32, 1.0 = frozen -- INQ accumulative
                   partition mask (non-trainable)
  ``inq_scales`` : (..., G, N) f32 -- the learned cluster grid the whole
                   tensor fake-quantizes onto (trainable, same optimizer
                   treatment as ``ttq_scales``; INQ events snap newly
                   frozen coordinates onto it, they never re-fit it)

Living in the tree means ``lax.scan`` over stacked blocks slices them per
layer automatically, the checkpoint codec persists them with no special
casing, and sharding rules see ordinary float leaves.  The *schedule* --
method, partition fractions, position -- is the small static ``QuantState``
record persisted in the checkpoint manifest so a mid-schedule resume is
bit-faithful.

``quantize_and_plan``-time consumption: ``api.quantize_params`` passes the
learned ``ttq_scales`` / ``inq_scales`` to ``quantize_weights(scales=...)``
so the served artifact runs on exactly the grid training converged to --
scales are never re-fit.  (``core.quantizer.quantize_scales`` round-trips
its own dequantization bit-exactly, which is what makes storing the f32
dequantized table sufficient.)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.quant.api import _quantizable
from repro.quant.formats import dequantize_weights, quantize_weights
from repro.quant.plan import QuantPlan, is_projection_site, site_subpath

# Every key this module may add to a site node.  Anything walking the tree
# for "real" params (artifact export, sharding) strips or skips these.
STATE_KEYS = ("ttq_scales", "inq_mask", "inq_scales")

DEFAULT_INQ_FRACTIONS = (0.5, 0.75, 0.875, 1.0)


@dataclasses.dataclass(frozen=True)
class QuantState:
    """Static schedule record for a stateful-quantization run.

    method      : 'ttq' | 'inq'
    fractions   : INQ accumulative partition fractions (portion of weights
                  frozen after each event); unused for ttq
    pos         : number of INQ events already applied (resume cursor)
    total_steps : planned training length the event steps are derived from
    """

    method: str
    fractions: Tuple[float, ...] = DEFAULT_INQ_FRACTIONS
    pos: int = 0
    total_steps: int = 0

    def to_meta(self) -> Dict[str, Any]:
        return {
            "method": self.method,
            "fractions": list(self.fractions),
            "pos": int(self.pos),
            "total_steps": int(self.total_steps),
        }

    @classmethod
    def from_meta(cls, meta: Dict[str, Any]) -> "QuantState":
        return cls(
            method=meta["method"],
            fractions=tuple(float(f) for f in meta["fractions"]),
            pos=int(meta["pos"]),
            total_steps=int(meta["total_steps"]),
        )


def inq_event_steps(total_steps: int, fractions: Sequence[float]) -> Tuple[int, ...]:
    """Step indices the INQ events fire at: freezing fraction ``f`` of the
    weights lands at fraction ``f`` of the run (one self-describing knob),
    so the first half of a default schedule is unconstrained (QAT-style)
    adaptation, each freeze acts on already-adapted weights, and most of
    the tensor commits only near the end.  The final (100%) event is
    clamped to the last step -- training ends with the whole tensor exactly
    on-grid, so deployment shifts nothing."""
    last = max(total_steps - 1, 0)
    return tuple(
        min(math.floor(total_steps * f), last) for f in fractions
    )


def _map_site(fn, *arrays):
    """vmap ``fn`` over any stacked leading axes (layers / experts)."""
    f = fn
    for _ in range(arrays[0].ndim - 2):
        f = jax.vmap(f)
    return f(*arrays)


def init_quant_state(
    params,
    plan: QuantPlan,
    method: str,
    *,
    fractions: Sequence[float] = DEFAULT_INQ_FRACTIONS,
    total_steps: int = 0,
) -> Tuple[Any, QuantState]:
    """Inject state leaves at every quantizable projection site.

    ttq: ``ttq_scales`` initialized symmetrically from the Algorithm-1 fit
    (Wp = Wn = alpha), so step 0 of TTQ training reproduces plain ternary
    PTQ exactly and the scales then diverge by gradient.

    inq: ``inq_mask`` all-zero (nothing frozen yet) + ``inq_scales`` from the
    initial full-tensor fit -- the grid then trains by gradient
    (``core.ste.inq_ste``) and is never re-fit.

    Returns ``(params_with_state, QuantState)``.
    """
    from repro.core import ternary

    if method not in ("ttq", "inq"):
        raise ValueError(f"unknown stateful quant method: {method!r}")

    def walk(node, path):
        if not isinstance(node, dict):
            return node
        out = {}
        for key, val in node.items():
            if is_projection_site(key, val):
                out[key] = val
                prec = plan.resolve(path)
                if not _quantizable(prec, val.shape[-2]):
                    continue
                w = val.astype(jnp.float32)
                if method == "ttq":
                    if prec.fmt != "ttq":
                        continue

                    def init_one(m, p=prec):
                        # L2-optimal scales GIVEN the ttq threshold codes:
                        # per-cluster mean |w| over each sign partition (the
                        # best starting point for the gradient to refine;
                        # empty partitions fall back to the Algorithm-1 fit)
                        from repro.quant.formats import ttq_partition

                        g = p.group_size
                        k, n = m.shape
                        cb = ttq_partition(m, g).reshape(k // g, g, n)
                        mb = jnp.abs(m).reshape(k // g, g, n)
                        _, alpha = ternary.ternarize_matrix(
                            m, g, p.filter_size, p.refit_scale
                        )
                        scales = []
                        for sign in (1, -1):
                            part = (cb == sign).astype(jnp.float32)
                            cnt = part.sum(axis=1)
                            s = (mb * part).sum(axis=1) / jnp.maximum(cnt, 1.0)
                            scales.append(jnp.where(cnt > 0, s, alpha))
                        return jnp.stack(scales, axis=0)  # (2, G, N)

                    out["ttq_scales"] = _map_site(init_one, w)
                else:  # inq
                    out["inq_mask"] = jnp.zeros(w.shape, jnp.float32)

                    def init_one(m, p=prec):
                        qt = quantize_weights(
                            m, p.w_bits, p.group_size, p.filter_size,
                            p.refit_scale, fmt=p.fmt,
                        )
                        from repro.core.quantizer import dequantize_scales

                        return dequantize_scales(qt.scale_m, qt.scale_e)

                    out["inq_scales"] = _map_site(init_one, w)
            elif key in STATE_KEYS:
                out[key] = val  # already initialized (idempotent re-walk)
            else:
                out[key] = walk(val, site_subpath(path, key))
        return out

    qs = QuantState(
        method=method, fractions=tuple(float(f) for f in fractions),
        pos=0, total_steps=int(total_steps),
    )
    return walk(params, ""), qs


def strip_quant_state(params):
    """Drop every state leaf, returning the pure parameter tree."""
    def walk(node):
        if not isinstance(node, dict):
            return node
        return {k: walk(v) for k, v in node.items() if k not in STATE_KEYS}

    return walk(params)


def has_quant_state(params) -> bool:
    def walk(node):
        if not isinstance(node, dict):
            return False
        return any(
            k in STATE_KEYS or walk(v) for k, v in node.items()
        )

    return walk(params)


def advance_inq(params, plan: QuantPlan, fraction: float):
    """Apply one INQ event: per site, grow the frozen set to the smallest
    ``fraction`` of coordinates by magnitude and snap the frozen set's
    master weights onto the CURRENT learned grid (``inq_scales``, which
    trains by gradient between events -- see ``core.ste.inq_ste``).  The
    mask is accumulative (union with the previous events'); the grid is
    never re-fit, so event-time snapping, the training forward, and the
    deployed artifact all derive codes from the same ``(w, s)`` pair."""

    def walk(node, path):
        if not isinstance(node, dict):
            return node
        out = dict(node)
        if "inq_mask" in node and "w" in node:
            prec = plan.resolve(path)
            w = node["w"].astype(jnp.float32)

            def adv_one(m, mask, sc, p=prec):
                flat = jnp.abs(m).reshape(-1)
                # freeze the SMALLEST `fraction` of coords first.  The INQ
                # paper freezes largest-first at 5 bits, where their
                # quantization error is small; at ternary/int4 widths the
                # largest weights carry the highest grid error, so locking
                # them first forfeits exactly the adaptation they need most.
                # Smallest-first snaps near-zero weights to the zero code
                # (negligible error) and keeps the accuracy-critical large
                # weights live until the final event.
                thr = jnp.quantile(flat, fraction)
                cand = (jnp.abs(m) <= thr).astype(jnp.float32)
                new_mask = jnp.maximum(mask, cand)
                qt = quantize_weights(
                    m, p.w_bits, p.group_size, p.filter_size,
                    p.refit_scale, fmt=p.fmt, scales=jnp.abs(sc),
                )
                deq = dequantize_weights(qt)
                new_w = jnp.where(new_mask > 0, deq, m)
                return new_w, new_mask

            new_w, new_mask = _map_site(
                adv_one, w, node["inq_mask"], node["inq_scales"]
            )
            out["w"] = new_w.astype(node["w"].dtype)
            out["inq_mask"] = new_mask
            return out
        return {k: walk(v, site_subpath(path, k)) for k, v in node.items()}

    return walk(params, "")
