"""QTensor container + packing primitives (canonical API surface).

The implementation lives in ``repro.core.quantizer`` (the dependency base
layer under the kernels); this module is the ``repro.quant`` face of it.
See that module's docstring for the storage layout.
"""
from repro.core.quantizer import (  # noqa: F401
    INT4_PER_WORD,
    NF4_LUT_I8,
    NF4_PER_WORD,
    TERNARY_PER_WORD,
    QTensor,
    dequantize_scales,
    nf4_lut_decode,
    pack2,
    pack4,
    pack4u,
    quantize_scales,
    unpack2,
    unpack4,
    unpack4u,
)
