"""QTensor container + packing primitives (canonical API surface).

The implementation lives in ``repro.core.quantizer`` (the dependency base
layer under the kernels); this module is the ``repro.quant`` face of it.
See that module's docstring for the storage layout.
"""
from repro.core.quantizer import (  # noqa: F401
    INT4_PER_WORD,
    TERNARY_PER_WORD,
    QTensor,
    dequantize_scales,
    pack2,
    pack4,
    quantize_scales,
    unpack2,
    unpack4,
)
