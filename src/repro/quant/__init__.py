"""`repro.quant`: the single entry point for all quantization.

The paper's pipeline -- cluster-ternarize weights, re-quantize scale tables
to 8-bit DFP, profile activations for shared exponents, serve on a full
integer path -- is exposed as one coherent API:

  * ``QTensor`` + packing primitives       (repro.quant.qtensor)
  * format registry (ternary/int4/int8)    (repro.quant.formats)
  * backend registry + ``qmatmul``/``qdense`` (repro.quant.backends; qdense
    is the whole-site call -- fused single-kernel pipeline on pallas)
  * ``QuantPlan`` / ``QuantCtx`` / compile (repro.quant.plan)
  * ``quantize_model`` calibration-aware PTQ (repro.quant.api)
  * ``save_artifact`` / ``load_artifact`` packed on-disk artifacts
    (quantize once, cold-start serving many times; repro.quant.api)

Migration from the legacy surfaces (still re-exported for compatibility):

  * ``repro.core.quantizer.quantize_weights``  -> ``repro.quant.quantize_weights``
  * ``repro.kernels.ops.qmatmul``              -> ``repro.quant.qmatmul``
  * ``repro.models.make_ctx(cfg)``             -> ``QuantCtx.from_config(cfg.quant)``
  * ``repro.models.quantize_model_params(p, policy)``
        -> ``qparams, plan = repro.quant.quantize_model(p, policy)`` then
           ``api = api.with_plan(plan)`` so every consumer shares the plan.
"""
from repro.quant.qtensor import (
    INT4_PER_WORD,
    NF4_LUT_I8,
    NF4_PER_WORD,
    TERNARY_PER_WORD,
    QTensor,
    dequantize_scales,
    nf4_lut_decode,
    pack2,
    pack4,
    pack4u,
    quantize_scales,
    unpack2,
    unpack4,
    unpack4u,
)
from repro.quant.formats import (
    QuantFormat,
    decode_codes,
    dequantize_weights,
    fake_quantize_weights,
    format_for_bits,
    format_names,
    format_of,
    get_format,
    quantize_weights,
    register_format,
    weight_quantization_error,
)
from repro.quant.backends import (
    backend_names,
    ep_divisible,
    expert_ffn_ep,
    get_backend,
    has_fused_backend,
    qdense,
    qmatmul,
    qmatmul_jit,
    quantize_activations,
    register_backend,
    register_fused_backend,
    resolve_backend,
)
from repro.quant.plan import (
    QuantCtx,
    QuantPlan,
    compile_policy,
    iter_weight_sites,
)
from repro.quant.api import (
    Artifact,
    Observer,
    load_artifact,
    observe_site,
    quantize_model,
    quantize_params,
    save_artifact,
)
from repro.quant.state import (
    STATE_KEYS,
    QuantState,
    advance_inq,
    has_quant_state,
    init_quant_state,
    inq_event_steps,
    strip_quant_state,
)
from repro.quant.formats import TTQ_THRESHOLD, ttq_partition
from repro.core.policy import FULL_PRECISION, LayerPrecision, PrecisionPolicy
