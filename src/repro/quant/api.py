"""Calibration-aware PTQ entry points over compiled precision plans.

``quantize_model(params, policy, calib_batches=...)`` is the one call every
consumer (server, dry-run, examples, benchmarks) makes to go from trained
float params to a servable quantized model:

  1. compile the policy against the param tree -> ``QuantPlan``,
  2. replace projection ``w`` leaves with QTensors per the plan
     (``quantize_params``),
  3. optionally run calibration batches through an observing forward pass,
     profile per-site activation ranges, and thread the finalized shared
     exponents into the plan (the paper's profiled static-DFP activation
     mode; un-profiled sites keep dynamic per-row exponents).

The observer uses ``jax.debug.callback`` so it records real runtime values
even when sites live inside ``lax.scan`` block loops (stacked layers share
one site path, hence one exponent -- consistent with the plan table).

``save_artifact`` / ``load_artifact`` make the quantized model a first-class
on-disk artifact: the QTensor tree persists packed (payload + scale table +
format tag, sha256 per payload) alongside the compiled plan with its
calibrated exponents -- quantize once, then cold-start any number of serving
processes from the 4-16x-smaller artifact with fp32 weights never touched.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dfp
from repro.core.policy import PrecisionPolicy
from repro.quant.formats import quantize_weights
from repro.quant.plan import (
    QuantCtx,
    QuantPlan,
    compile_policy,
    is_projection_site,
    site_subpath,
)
from repro.quant.qtensor import TERNARY_PER_WORD


def _record(store, site: str, max_abs: float, msq: float) -> None:
    """Accumulate one batch's stats into any {site: entry} mapping."""
    e = store.get(site)
    if e is None:
        store[site] = {"max_abs": max_abs, "msq": msq, "count": 1.0}
    else:
        e["max_abs"] = max(e["max_abs"], max_abs)
        e["msq"] += msq
        e["count"] += 1.0


class Observer(dict):
    """Host-side activation-range store: {site: {"max_abs", "msq", "count"}}.

    Populated by ``observe_site`` callbacks during a calibration forward;
    ``exponents()`` finalizes ``max_abs`` into shared 8-bit DFP exponents.
    ``msq``/``count`` mirror ``core.calibration.ObserverState`` so the same
    pass can drive the BN-recompute analogue (``recalibrate_gamma`` needs
    the per-site second moment).
    """

    def record(self, site: str, max_abs: float, msq: float) -> None:
        _record(self, site, max_abs, msq)

    def exponents(self, bits: int = 8, bits_for=None) -> Dict[str, int]:
        """Finalize ranges into shared DFP exponents.  ``bits_for(site)``
        overrides the mantissa width per site (must match the act_bits the
        consumer quantizes with, or the exponent mis-scales)."""
        return {
            site: int(
                dfp.choose_exponent(
                    jnp.float32(e["max_abs"]),
                    bits_for(site) if bits_for is not None else bits,
                )
            )
            for site, e in self.items()
        }


def observe_site(store, site: str, x: jax.Array) -> None:
    """Record one activation batch at ``site`` into a mutable host store.

    Runs via jax.debug.callback so it works identically in eager, jit and
    lax.scan contexts (max/mean accumulation is order-independent).
    """
    xf = x.astype(jnp.float32)
    max_abs = jnp.max(jnp.abs(xf))
    msq = jnp.mean(jnp.square(xf))

    def cb(m, s, _store=store, _site=site):
        _record(_store, _site, float(m), float(s))

    jax.debug.callback(cb, max_abs, msq)


# ---------------------------------------------------------------------------
# Param-tree conversion.
# ---------------------------------------------------------------------------
def _quantizable(prec, kdim: int) -> bool:
    return (
        prec is not None
        and prec.quantized
        and prec.w_bits < 16
        and kdim % prec.group_size == 0
        and kdim % TERNARY_PER_WORD == 0
    )


def quantize_params(params, plan: QuantPlan):
    """Walk the param tree; replace projection 'w' leaves with QTensors.

    Stacked leading axes (layers and/or experts) are vmapped over.  The
    embedding table (a gather, not a GEMM) is snapped to the 8-bit DFP grid
    in place (values quantized, storage dtype unchanged).  Precision comes
    from the compiled plan table -- no per-leaf regex resolution.

    Sites carrying trained quantization state (``repro.quant.state``) are
    quantized on their *learned* grid: a ``ttq_scales`` leaf supplies the
    trained Wp/Wn magnitudes and an ``inq_scales`` leaf the last INQ event's
    scale table, threaded into ``quantize_weights(scales=...)`` so the
    artifact is never re-fit from the master weights.  State leaves are
    consumed here -- the output tree holds only servable parameters.
    """
    from repro.core import calibration
    from repro.quant.state import STATE_KEYS

    def quant_w(w, prec, scales=None):
        def q2(m, sc=None):
            return quantize_weights(
                m, prec.w_bits, prec.group_size, prec.filter_size,
                prec.refit_scale, fmt=prec.fmt, scales=sc,
            )

        if scales is None:
            fn = lambda m: q2(m)
            for _ in range(w.ndim - 2):
                fn = jax.vmap(fn)
            return fn(w.astype(jnp.float32))
        fn = q2
        for _ in range(w.ndim - 2):
            fn = jax.vmap(fn)
        return fn(w.astype(jnp.float32), scales.astype(jnp.float32))

    def walk(node, path):
        if isinstance(node, dict):
            out = {}
            for key, val in node.items():
                sub = site_subpath(path, key)
                if is_projection_site(key, val):
                    prec = plan.resolve(path)
                    if _quantizable(prec, val.shape[-2]):
                        if prec.fmt == "ttq" and "ttq_scales" in node:
                            sc = node["ttq_scales"]
                        else:
                            # |s|: the trained grid is a magnitude (the STE
                            # chains gradients through sign, training may
                            # cross zero) -- same fold as ste.inq_ste
                            sc = node.get("inq_scales")
                            sc = None if sc is None else jnp.abs(sc)
                        out[key] = quant_w(val, prec, scales=sc)
                    else:
                        out[key] = val
                elif key in STATE_KEYS:
                    continue  # consumed above; not a servable parameter
                elif key == "table" and hasattr(val, "ndim"):
                    out[key] = calibration.fake_quantize_act(
                        val.astype(jnp.float32), 8, per_row=True
                    ).astype(val.dtype)
                else:
                    out[key] = walk(val, sub)
            return out
        return node

    return walk(params, "")


# ---------------------------------------------------------------------------
# The one-call PTQ entry point.
# ---------------------------------------------------------------------------
def quantize_model(
    params,
    policy: PrecisionPolicy,
    *,
    mode: str = "ptq",
    backend: str = "auto",
    calib_batches: Optional[Iterable[Any]] = None,
    forward: Optional[Callable[[Any, Any, QuantCtx], Any]] = None,
    act_bits: int = 8,
) -> Tuple[Any, QuantPlan]:
    """Convert float params to QTensors under a compiled plan.

    Returns ``(qparams, plan)``.  With ``calib_batches`` (any iterable of
    model inputs) and ``forward(params, batch, ctx)``, a full-precision
    observing pass profiles activation ranges at every projection site and
    the finalized static exponents ride in the plan; PTQ inference then uses
    static per-site DFP activations where profiled and dynamic per-row
    everywhere else.
    """
    if calib_batches is not None and forward is None:
        raise ValueError("calib_batches requires a forward(params, batch, ctx)")
    plan = compile_policy(policy, params, mode=mode, backend=backend)
    qparams = quantize_params(params, plan)
    if calib_batches is not None:
        obs = Observer()
        ctx = QuantCtx(mode="fp", policy=policy, observer=obs)
        for batch in calib_batches:
            forward(params, batch, ctx)
        # the observer records through jax.debug.callback: on async-dispatch
        # backends the callbacks may still be in flight here -- flush them
        # before finalizing, or the plan silently loses calibrated sites
        jax.effects_barrier()

        def bits_for(site):
            prec = plan.resolve(site)
            # must match the act_bits dense() quantizes this site with
            return prec.act_bits if prec is not None else act_bits

        plan = plan.with_act_exponents(obs.exponents(act_bits, bits_for))
    return qparams, plan


# ---------------------------------------------------------------------------
# Quantized artifacts: packed QTensor tree + plan as the unit of deployment.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Artifact:
    """One loaded quantized artifact: packed params + plan + metadata."""

    params: Any  # param tree with QTensor projection leaves (still packed)
    plan: Optional[QuantPlan]
    extra: Dict[str, Any]  # producer metadata (e.g. the serialized ArchConfig)
    step: int
    path: str  # the verified on-disk step directory


def save_artifact(
    artifact_dir: str,
    params: Any,
    plan: Optional[QuantPlan],
    *,
    extra: Optional[Dict[str, Any]] = None,
    step: int = 0,
    mesh: Any = None,
) -> str:
    """Persist a quantized model as a self-contained on-disk artifact.

    QTensor leaves serialize through the checkpoint codec layer as packed
    payload + scale table + format tag (sha256 per payload, step-atomic
    publish); the compiled plan -- calibrated activation exponents included
    -- rides in the manifest's ``quant_plan`` section.  ``extra`` is free
    producer metadata; pass the serialized ArchConfig
    (``dataclasses.asdict(cfg)`` under key ``"arch_config"``) so serving can
    cold-start without any out-of-band configuration.

    With ``mesh``, payloads write per-host sharded (``payload.shard{k}``,
    per-shard sha256) under the serving-mode sharding rules
    (``repro.parallel.qtensor_shardings``): each host persists only its
    addressable shards, and a mesh-aware ``load_artifact`` reassembles them
    device-by-device.
    """
    from repro.training import checkpoint as ckpt

    shardings = None
    if mesh is not None:
        from repro.parallel.sharding import qtensor_shardings

        shardings = qtensor_shardings(params, mesh, plan)
    meta = dict(extra or {})
    meta.setdefault("kind", "quant_artifact")
    return ckpt.save(
        artifact_dir, step, params, extra=meta, plan=plan, shardings=shardings
    )


def load_artifact(artifact_dir: str, *, mesh: Any = None) -> Artifact:
    """Load the newest intact artifact in ``artifact_dir``.

    Template-free: the param tree (QTensors still packed -- fp32 weights are
    never materialized) and the plan rebuild purely from the verified
    manifest.  Corrupt steps (including a truncated plan JSON) are skipped
    in favor of older intact ones; no intact step raises IOError.

    With ``mesh``, the serving shardings are computed against the
    manifest's abstract tree (``ckpt.tree_shapes``; no payload reads) and
    every payload assembles straight onto its owning devices -- sharded
    payloads via ``jax.make_array_from_single_device_arrays``, so neither
    the global fp32 NOR the global packed tree ever exists on one host.
    """
    from repro.training import checkpoint as ckpt

    # verify once (reads + sha256-hashes every payload), then thread the
    # verified manifest through -- a large artifact is hashed one time per
    # cold start, not once per helper
    step, manifest = ckpt.latest_intact(artifact_dir)
    if step is None:
        raise IOError(f"no intact quantized artifact under {artifact_dir!r}")
    d = ckpt.step_dir(artifact_dir, step)
    plan = ckpt.load_plan(d, manifest=manifest)
    shardings = None
    if mesh is not None:
        from repro.parallel.sharding import qtensor_shardings

        shardings = qtensor_shardings(ckpt.tree_shapes(manifest), mesh, plan)
    return Artifact(
        params=ckpt.restore_tree(d, manifest=manifest, shardings=shardings),
        plan=plan,
        extra=manifest.get("extra", {}),
        step=step,
        path=d,
    )
