"""Quantization format registry: pluggable weight encodings.

A ``QuantFormat`` bundles everything bit-width specific about a weight
encoding -- how float weights become integer codes + cluster scales
(``weight_codes``), how codes are packed/unpacked (``encode``/``decode``),
and which Pallas matmul kernel consumes the packed form (``kernel``).  The
built-in formats reproduce the paper:

  * ``ternary`` (bits=2): Algorithms 1 & 2 hierarchical cluster
    ternarization, 16 codes per uint32.
  * ``int4``    (bits=4): per-cluster DFP mantissas, max-abs scaling,
    8 codes per uint32.
  * ``int8``    (bits=8): per-cluster DFP mantissas, raw int8 storage.

New formats plug in with ``register_format`` and flow through every consumer
(``quantize_weights``, ``qmatmul`` backends, PTQ conversion) without touching
dispatch code -- this replaces the old ``bits == 2/4/8`` if-chains in
``core/quantizer.py`` and ``kernels/ops.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dfp, ternary
from repro.core.quantizer import (
    QTensor,
    dequantize_scales,
    pack2,
    pack4,
    quantize_scales,
    unpack2,
    unpack4,
)
from repro.kernels.int4_matmul import int4_matmul, int4_matmul_fused
from repro.kernels.int8_matmul import int8_matmul, int8_matmul_fused
from repro.kernels.ternary_matmul import ternary_matmul, ternary_matmul_fused

# weight_codes: (w f32 (K, N), group_size, filter_size, refit_scale)
#   -> (codes int8 (K, N), scale_m int8 (K/g, N), scale_e int32 scalar)
WeightCodesFn = Callable[..., Tuple[jax.Array, jax.Array, jax.Array]]


@dataclasses.dataclass(frozen=True)
class QuantFormat:
    """One registered weight encoding (see module docstring)."""

    name: str
    bits: int
    encode: Callable[[jax.Array], jax.Array]  # int8 codes (K, N) -> packed
    decode: Callable[[jax.Array, int], jax.Array]  # (packed, K) -> int8 codes
    weight_codes: WeightCodesFn
    kernel: Optional[Callable] = None  # Pallas matmul over the packed form
    # prologue/epilogue-fused Pallas dense kernel: takes RAW f32/bf16
    # activations plus (packed, scale_m, scale_e) and applies quantization,
    # exponents, bias and activation in one pallas_call (see
    # kernels/_common.fused_qmm_call for the signature contract)
    fused_kernel: Optional[Callable] = None


_FORMATS: Dict[str, QuantFormat] = {}
_BY_BITS: Dict[int, str] = {}


def register_format(
    name: str,
    *,
    bits: int,
    encode: Callable,
    decode: Callable,
    weight_codes: WeightCodesFn,
    kernel: Optional[Callable] = None,
    fused_kernel: Optional[Callable] = None,
    overwrite: bool = False,
) -> QuantFormat:
    """Register a weight format under ``name`` (and as default for ``bits``
    if no format claimed that width yet)."""
    if name in _FORMATS and not overwrite:
        raise ValueError(f"format {name!r} already registered")
    if overwrite and name in _FORMATS:
        old_bits = _FORMATS[name].bits
        if old_bits != bits and _BY_BITS.get(old_bits) == name:
            del _BY_BITS[old_bits]  # this name no longer encodes that width
    fmt = QuantFormat(name, bits, encode, decode, weight_codes, kernel, fused_kernel)
    _FORMATS[name] = fmt
    # claim the bits default only if unclaimed or already owned by this name:
    # overwriting an unrelated format must not change how fmt="" QTensors
    # (e.g. pre-existing checkpoints) resolve
    if bits not in _BY_BITS or _BY_BITS[bits] == name:
        _BY_BITS[bits] = name
    return fmt


def get_format(name: str) -> QuantFormat:
    try:
        return _FORMATS[name]
    except KeyError:
        raise KeyError(
            f"unknown quant format {name!r}; registered: {sorted(_FORMATS)}"
        ) from None


def format_for_bits(bits: int) -> QuantFormat:
    try:
        return _FORMATS[_BY_BITS[bits]]
    except KeyError:
        raise ValueError(
            f"no quant format registered for bits={bits}; "
            f"registered: {sorted(_FORMATS)}"
        ) from None


def format_of(qt: QTensor) -> QuantFormat:
    return get_format(qt.fmt) if qt.fmt else format_for_bits(qt.bits)


def format_names() -> Tuple[str, ...]:
    return tuple(sorted(_FORMATS))


# ---------------------------------------------------------------------------
# Built-in formats (the paper's 2t / 4 / 8-bit cluster schemes).
# ---------------------------------------------------------------------------
def _ternary_weight_codes(w, group_size, filter_size, refit_scale):
    codes, alpha = ternary.ternarize_matrix(w, group_size, filter_size, refit_scale)
    scale_m, scale_e = quantize_scales(alpha)
    return codes, scale_m, scale_e


def _dfp_weight_codes(bits: int) -> WeightCodesFn:
    def weight_codes(w, group_size, filter_size, refit_scale):
        k, n = w.shape
        blocks = w.reshape(k // group_size, group_size, n)
        max_abs = jnp.max(jnp.abs(blocks), axis=1)  # (groups, N)
        alpha = max_abs / dfp.qmax(bits)
        scale_m, scale_e = quantize_scales(alpha)
        # mantissas are chosen against the *re-quantized* scales so the
        # stored (codes, scale table) pair is self-consistent
        scale = dequantize_scales(scale_m, scale_e)[:, None, :]
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(blocks / safe), -dfp.qmax(bits), dfp.qmax(bits))
        return q.astype(jnp.int8).reshape(k, n), scale_m, scale_e

    return weight_codes


register_format(
    "ternary",
    bits=2,
    encode=pack2,
    decode=unpack2,
    weight_codes=_ternary_weight_codes,
    kernel=ternary_matmul,
    fused_kernel=ternary_matmul_fused,
)
register_format(
    "int4",
    bits=4,
    encode=pack4,
    decode=unpack4,
    weight_codes=_dfp_weight_codes(4),
    kernel=int4_matmul,
    fused_kernel=int4_matmul_fused,
)
register_format(
    "int8",
    bits=8,
    encode=lambda codes: codes,  # raw int8 storage
    decode=lambda packed, k: packed,
    weight_codes=_dfp_weight_codes(8),
    kernel=int8_matmul,
    fused_kernel=int8_matmul_fused,
)


# ---------------------------------------------------------------------------
# Generic weight quantization entry points (format-registry driven).
# ---------------------------------------------------------------------------
def quantize_weights(
    w: jax.Array,
    bits: int = 2,
    group_size: int = 64,
    filter_size: int = 1,
    refit_scale: bool = False,
    fmt: Optional[str] = None,
) -> QTensor:
    """Quantize a (K, N) projection with the paper's cluster scheme.

    The encoding is resolved through the format registry: ``fmt`` by name,
    else the default format for ``bits``.  In every case the scale table
    itself is re-quantized to 8-bit DFP so the whole pipeline stays
    sub-8-bit.
    """
    k, n = w.shape
    w = w.astype(jnp.float32)
    f = get_format(fmt) if fmt else format_for_bits(bits)
    codes, scale_m, scale_e = f.weight_codes(w, group_size, filter_size, refit_scale)
    return QTensor(
        f.encode(codes), scale_m, scale_e, f.bits, group_size, (k, n),
        fmt=f.name if fmt else "",
    )


def decode_codes(qt: QTensor) -> jax.Array:
    """Integer mantissas (K, N) int8 of a QTensor."""
    return format_of(qt).decode(qt.packed, qt.k)


def dequantize_weights(qt: QTensor) -> jax.Array:
    """f32 (K, N) reconstruction."""
    codes = decode_codes(qt).astype(jnp.float32)
    scale = dequantize_scales(qt.scale_m, qt.scale_e)  # (groups, N)
    c = codes.reshape(qt.n_groups, qt.group_size, qt.n)
    return (c * scale[:, None, :]).reshape(qt.k, qt.n)


def fake_quantize_weights(
    w: jax.Array, bits: int, group_size: int, filter_size: int = 1,
    refit_scale: bool = False,
) -> jax.Array:
    """quantize -> dequantize (QAT forward / error measurement)."""
    return dequantize_weights(
        quantize_weights(w, bits, group_size, filter_size, refit_scale)
    )


def weight_quantization_error(w, bits, group_size, filter_size=1) -> jax.Array:
    wq = fake_quantize_weights(w, bits, group_size, filter_size)
    return jnp.sum((w - wq) ** 2)
