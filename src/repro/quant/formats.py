"""Quantization format registry: pluggable weight encodings.

A ``QuantFormat`` bundles everything bit-width specific about a weight
encoding -- how float weights become integer codes + cluster scales
(``weight_codes``), how codes are packed/unpacked (``encode``/``decode``),
and which Pallas matmul kernel consumes the packed form (``kernel``).  The
built-in formats reproduce the paper:

  * ``ternary`` (bits=2): Algorithms 1 & 2 hierarchical cluster
    ternarization, 16 codes per uint32.
  * ``int4``    (bits=4): per-cluster DFP mantissas, max-abs scaling,
    8 codes per uint32.
  * ``int8``    (bits=8): per-cluster DFP mantissas, raw int8 storage.
  * ``nf4``     (bits=4): NormalFloat lookup-table codes (QLoRA) against a
    per-cluster absmax scale; 8 codes per uint32, decoded through a 16-entry
    LUT on the int8 grid (in-kernel on the fused path).
  * ``mx``      (bits=8): microscaling-style shared power-of-two exponent
    per 32-element block (``block_size`` pinned to 32): the scale table
    carries only exact powers of two, so dequantization is all shifts.

New formats plug in with ``register_format`` and flow through every consumer
(``quantize_weights``, ``qmatmul`` backends, PTQ conversion) without touching
dispatch code -- this replaces the old ``bits == 2/4/8`` if-chains in
``core/quantizer.py`` and ``kernels/ops.py``.  nf4 and mx deliberately share
their bit-widths with int4 and int8: every QTensor is stamped with its
resolved format *name*, and the ``_BY_BITS`` table only answers for legacy
(empty-fmt) artifacts, where it keeps pointing at the built-in claimant.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dfp, ternary
from repro.core.quantizer import (
    NF4_LUT_I8,
    QTensor,
    dequantize_scales,
    nf4_lut_decode,
    pack2,
    pack4,
    pack4u,
    quantize_scales,
    unpack2,
    unpack4,
    unpack4u,
)
from repro.kernels._common import MX_BLOCK
from repro.kernels.int4_matmul import int4_matmul, int4_matmul_fused
from repro.kernels.int8_matmul import int8_matmul, int8_matmul_fused
from repro.kernels.mx_matmul import mx_matmul, mx_matmul_fused
from repro.kernels.nf4_matmul import nf4_matmul, nf4_matmul_fused
from repro.kernels.ternary_matmul import ternary_matmul, ternary_matmul_fused

# weight_codes: (w f32 (K, N), group_size, filter_size, refit_scale)
#   -> (codes int8 (K, N), scale_m int8 (K/g, N), scale_e int32 scalar)
# Implementations MAY additionally accept a ``scales=`` keyword (f32 cluster
# scales supplied externally -- e.g. TTQ-trained Wp/Wn magnitudes or the
# INQ freeze-event grid) in which case the scale table is built from the
# given values instead of being re-fit from ``w``.  ``quantize_weights``
# only forwards the keyword when the caller passes one, so formats
# registered before this hook keep working unchanged.
WeightCodesFn = Callable[..., Tuple[jax.Array, jax.Array, jax.Array]]


@dataclasses.dataclass(frozen=True)
class QuantFormat:
    """One registered weight encoding (see module docstring)."""

    name: str
    bits: int
    encode: Callable[[jax.Array], jax.Array]  # int8 codes (K, N) -> packed
    decode: Callable[[jax.Array, int], jax.Array]  # (packed, K) -> int8 codes
    weight_codes: WeightCodesFn
    kernel: Optional[Callable] = None  # Pallas matmul over the packed form
    # prologue/epilogue-fused Pallas dense kernel: takes RAW f32/bf16
    # activations plus (packed, scale_m, scale_e) and applies quantization,
    # exponents, bias and activation in one pallas_call (see
    # kernels/_common.fused_qmm_call for the signature contract)
    fused_kernel: Optional[Callable] = None
    # formats whose encoding fixes the cluster length (mx: 32 elements per
    # shared exponent) pin it here; quantize_weights then overrides the
    # caller's group_size so the QTensor metadata always matches the scales
    block_size: Optional[int] = None
    # formats whose scale table is NOT one value per cluster (ttq carries a
    # (2*groups, N) Wp/Wn pair table) override the generic reconstruction
    # here: (qt) -> f32 (K, N)
    dequantize: Optional[Callable[[QTensor], jax.Array]] = None
    # matching override for the integer oracle (kernels/ref.qmatmul_ref
    # dispatches through this): (x_q int8 (M, K), x_e, qt) -> f32 (M, N)
    ref_matmul: Optional[Callable] = None


_FORMATS: Dict[str, QuantFormat] = {}
_BY_BITS: Dict[int, str] = {}


def register_format(
    name: str,
    *,
    bits: int,
    encode: Callable,
    decode: Callable,
    weight_codes: WeightCodesFn,
    kernel: Optional[Callable] = None,
    fused_kernel: Optional[Callable] = None,
    block_size: Optional[int] = None,
    dequantize: Optional[Callable] = None,
    ref_matmul: Optional[Callable] = None,
    overwrite: bool = False,
) -> QuantFormat:
    """Register a weight format under ``name`` (and as default for ``bits``
    if no format claimed that width yet)."""
    if name in _FORMATS and not overwrite:
        raise ValueError(f"format {name!r} already registered")
    if overwrite and name in _FORMATS:
        old = _FORMATS[name]
        old_bits = old.bits
        if old_bits != bits and _BY_BITS.get(old_bits) == name:
            # this name no longer encodes old_bits: hand the width default to
            # a surviving claimant (first-registered wins, deterministically)
            # instead of orphaning it -- deleting outright made
            # format_for_bits(old_bits) raise for a width that resolved
            # before the re-registration, even with other formats of that
            # width still registered.  The default is only what legacy
            # empty-fmt QTensors decode through, so a survivor qualifies
            # ONLY with the departing claimant's exact codec (same
            # encode/decode callables -- a re-registration of the same
            # encoding under another name); handing the width to a format
            # with different code semantics (e.g. int4 -> nf4's LUT) would
            # silently mis-decode legacy payloads, where no default at all
            # fails loudly
            survivor = next(
                (f.name for f in _FORMATS.values()
                 if f.bits == old_bits and f.name != name
                 and f.decode is old.decode and f.encode is old.encode),
                None,
            )
            if survivor is not None:
                _BY_BITS[old_bits] = survivor
            else:
                del _BY_BITS[old_bits]  # fail closed: no compatible claimant
    fmt = QuantFormat(
        name, bits, encode, decode, weight_codes, kernel, fused_kernel,
        block_size, dequantize, ref_matmul,
    )
    _FORMATS[name] = fmt
    # claim the bits default only if unclaimed or already owned by this name:
    # overwriting an unrelated format must not change how fmt="" QTensors
    # (e.g. pre-existing checkpoints) resolve
    if bits not in _BY_BITS or _BY_BITS[bits] == name:
        _BY_BITS[bits] = name
    return fmt


def get_format(name: str) -> QuantFormat:
    try:
        return _FORMATS[name]
    except KeyError:
        raise KeyError(
            f"unknown quant format {name!r}; registered: {sorted(_FORMATS)}"
        ) from None


def format_for_bits(bits: int) -> QuantFormat:
    try:
        return _FORMATS[_BY_BITS[bits]]
    except KeyError:
        raise ValueError(
            f"no quant format registered for bits={bits}; "
            f"registered: {sorted(_FORMATS)}"
        ) from None


def format_of(qt: QTensor) -> QuantFormat:
    return get_format(qt.fmt) if qt.fmt else format_for_bits(qt.bits)


def format_names() -> Tuple[str, ...]:
    return tuple(sorted(_FORMATS))


# ---------------------------------------------------------------------------
# Built-in formats (the paper's 2t / 4 / 8-bit cluster schemes).
# ---------------------------------------------------------------------------
def _ternary_weight_codes(w, group_size, filter_size, refit_scale, scales=None):
    if scales is not None:
        # externally-supplied grid (INQ freeze events deploy the trained
        # grid, never a re-fit): mantissas snap to the GIVEN per-cluster
        # alpha; weights already on that grid reconstruct exactly
        scale_m, scale_e = quantize_scales(scales)
        k, n = w.shape
        scale = dequantize_scales(scale_m, scale_e)[:, None, :]
        safe = jnp.where(scale > 0, scale, 1.0)
        blocks = w.reshape(k // group_size, group_size, n)
        q = jnp.clip(jnp.round(blocks / safe), -1, 1)
        return q.astype(jnp.int8).reshape(k, n), scale_m, scale_e
    codes, alpha = ternary.ternarize_matrix(w, group_size, filter_size, refit_scale)
    scale_m, scale_e = quantize_scales(alpha)
    return codes, scale_m, scale_e


def _dfp_weight_codes(bits: int) -> WeightCodesFn:
    def weight_codes(w, group_size, filter_size, refit_scale, scales=None):
        k, n = w.shape
        blocks = w.reshape(k // group_size, group_size, n)
        if scales is None:
            max_abs = jnp.max(jnp.abs(blocks), axis=1)  # (groups, N)
            alpha = max_abs / dfp.qmax(bits)
        else:
            alpha = scales  # externally-supplied cluster scales (no re-fit)
        scale_m, scale_e = quantize_scales(alpha)
        # mantissas are chosen against the *re-quantized* scales so the
        # stored (codes, scale table) pair is self-consistent
        scale = dequantize_scales(scale_m, scale_e)[:, None, :]
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(blocks / safe), -dfp.qmax(bits), dfp.qmax(bits))
        return q.astype(jnp.int8).reshape(k, n), scale_m, scale_e

    return weight_codes


register_format(
    "ternary",
    bits=2,
    encode=pack2,
    decode=unpack2,
    weight_codes=_ternary_weight_codes,
    kernel=ternary_matmul,
    fused_kernel=ternary_matmul_fused,
)
register_format(
    "int4",
    bits=4,
    encode=pack4,
    decode=unpack4,
    weight_codes=_dfp_weight_codes(4),
    kernel=int4_matmul,
    fused_kernel=int4_matmul_fused,
)
register_format(
    "int8",
    bits=8,
    encode=lambda codes: codes,  # raw int8 storage
    decode=lambda packed, k: packed,
    weight_codes=_dfp_weight_codes(8),
    kernel=int8_matmul,
    fused_kernel=int8_matmul_fused,
)


# ---------------------------------------------------------------------------
# Sub-8-bit block formats beyond the paper: nf4 (LUT codes) and mx (shared
# power-of-two block exponents).  Both registered AFTER the built-ins so the
# bits defaults (4 -> int4, 8 -> int8) that legacy empty-fmt artifacts
# resolve through stay untouched.
# ---------------------------------------------------------------------------
def _nf4_weight_codes(w, group_size, filter_size, refit_scale, scales=None):
    """Nearest-NF4-quantile codes against a per-cluster absmax scale.

    The cluster scale is absmax / 127 (so code 15 -- LUT value +127 --
    reconstructs the cluster max exactly), re-quantized to 8-bit DFP like
    every other format's scale table.  Codes are chosen against the
    *re-quantized* scale so (codes, scale table) stay self-consistent.
    ``filter_size``/``refit_scale`` are Algorithm-2 knobs with no analogue
    in a quantile LUT; they are accepted and ignored.  ``scales`` supplies
    an external per-cluster alpha table (trained grid) instead of the
    absmax fit.
    """
    del filter_size, refit_scale
    k, n = w.shape
    blocks = w.reshape(k // group_size, group_size, n)
    if scales is None:
        max_abs = jnp.max(jnp.abs(blocks), axis=1)  # (groups, N)
        alpha = max_abs / float(NF4_LUT_I8[-1])  # int8-grid LUT: 127 = max
    else:
        alpha = scales
    scale_m, scale_e = quantize_scales(alpha)
    scale = dequantize_scales(scale_m, scale_e)[:, None, :]
    safe = jnp.where(scale > 0, scale, 1.0)
    u = blocks / safe  # normalized onto the int8 LUT grid
    # nearest quantile via the 15 decision midpoints (the LUT is sorted):
    # equivalent to argmin |u - lut| without materializing the 16x-wider
    # broadcast temporary (which OOMs quantize-on-boot at production scale)
    lut = jnp.asarray(NF4_LUT_I8, jnp.float32)
    mids = (lut[:-1] + lut[1:]) / 2.0
    idx = jnp.searchsorted(mids, u.reshape(-1)).reshape(u.shape)
    return idx.astype(jnp.int8).reshape(k, n), scale_m, scale_e


def _nf4_decode(packed, k):
    """packed LUT codes -> int8 mantissas (the jnp twin of the in-kernel
    16-entry LUT; bit-identical by construction)."""
    return nf4_lut_decode(unpack4u(packed, k))


_MX_SCALE_BITS = 6  # scale_m spans 2**0 .. 2**6 (64 <= int8 max)


def _mx_weight_codes(w, group_size, filter_size, refit_scale, scales=None):
    """int8 mantissas with one power-of-two exponent per 32-element block.

    Per block b: e_b = choose_exponent(absmax_b, 8).  The shared QTensor base
    is ``scale_e = max_b(e_b) - 6`` and each block stores
    ``scale_m = 2**(e_b - scale_e)`` -- an exact power of two in [1, 64], so
    every per-cluster scale application is an exponent shift, never a true
    multiply.  Blocks more than 6 octaves below the loudest block clamp to
    the base (their mantissas quantize on a coarser grid -- the price of the
    shared int8 scale container; real mx hardware gives each block an
    independent 8-bit exponent).  ``group_size`` is pinned to 32 by the
    format's ``block_size``; ``filter_size``/``refit_scale`` do not apply.
    """
    del filter_size, refit_scale
    assert group_size == MX_BLOCK, (
        f"mx blocks are fixed at {MX_BLOCK} elements, got group_size={group_size}"
    )
    k, n = w.shape
    blocks = w.reshape(k // MX_BLOCK, MX_BLOCK, n)
    if scales is not None:
        # external grid: the given per-block scales are (by the format's own
        # construction) exact powers of two -- recover the block exponents
        # and rebuild the shared base from them instead of re-fitting
        e_b = jnp.where(
            scales > 0,
            jnp.round(jnp.log2(jnp.maximum(scales, jnp.finfo(jnp.float32).tiny))
                      ).astype(jnp.int32),
            jnp.zeros(scales.shape, jnp.int32),
        )
        max_abs = scales  # live-block detection below: scale > 0 iff live
    else:
        max_abs = jnp.max(jnp.abs(blocks), axis=1)  # (K/32, N)
        e_b = dfp.choose_exponent(max_abs, bits=8)  # per-block int32
    # the shared base is the loudest LIVE block: choose_exponent maps an
    # all-zero block to e=0, far above real weight-block exponents (~-16),
    # and letting a dead block (zero padding, pruned channel) into the max
    # would clamp every live block to d=0 and quantize the whole tensor on
    # a grid thousands of times coarser
    live = max_abs > 0
    e_base = jnp.max(jnp.where(live, e_b, jnp.iinfo(jnp.int32).min))
    scale_e = jnp.where(jnp.any(live), e_base, 0) - _MX_SCALE_BITS
    d = jnp.clip(e_b - scale_e, 0, _MX_SCALE_BITS)
    scale_m = (jnp.int32(1) << d).astype(jnp.int8)  # exact powers of two
    eff_e = scale_e + d  # the realized per-block exponent (>= e_b)
    q = jnp.clip(
        jnp.round(blocks * dfp.exp2i(-eff_e)[:, None, :]),
        -dfp.qmax(8), dfp.qmax(8),
    )
    return q.astype(jnp.int8).reshape(k, n), scale_m, scale_e


register_format(
    "nf4",
    bits=4,
    encode=pack4u,
    decode=_nf4_decode,
    weight_codes=_nf4_weight_codes,
    kernel=nf4_matmul,
    fused_kernel=nf4_matmul_fused,
)
register_format(
    "mx",
    bits=8,
    encode=lambda codes: codes,  # raw int8 storage (1 B/weight)
    decode=lambda packed, k: packed,
    weight_codes=_mx_weight_codes,
    kernel=mx_matmul,
    fused_kernel=mx_matmul_fused,
    block_size=MX_BLOCK,
)


# ---------------------------------------------------------------------------
# ttq: Trained Ternary Quantization (arxiv 1612.01064).  Ternary codes like
# the paper's Algorithm 1, but the positive and negative cluster magnitudes
# (Wp, Wn) are independent *trained parameters* (see repro.quant.state /
# core.ste.ttq_ste for the training side).  The scale table therefore holds
# TWO rows per cluster -- scale_m is (2*groups, N): Wp mantissas in the
# first half, Wn mantissas in the second, one shared exponent -- which is
# why the format overrides ``dequantize`` and ``ref_matmul`` instead of
# flowing through the one-scale-per-cluster generic paths.  Deployment
# stays all-integer: per cluster the oracle takes TWO ternary-accumulated
# partials (positive and negative codes) and applies one mantissa multiply
# each, so the paper's multiply-elimination claim degrades from 1 to 2
# multiplies per cluster, not to dense.
# ---------------------------------------------------------------------------
TTQ_THRESHOLD = 0.05  # Delta = t * max|w| per cluster (paper's t)


def ttq_partition(w, group_size: int, threshold: float = TTQ_THRESHOLD):
    """Sign partition: codes {-1, 0, +1} via the per-cluster threshold
    Delta = t * max|w|.  Shared by the QAT forward (core.ste.ttq_ste) and
    deployment (``_ttq_weight_codes``) so they can never disagree."""
    k, n = w.shape
    blocks = w.reshape(k // group_size, group_size, n)
    delta = threshold * jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    c = jnp.where(blocks > delta, 1, jnp.where(blocks < -delta, -1, 0))
    return c.astype(jnp.int8).reshape(k, n)


def _ttq_weight_codes(w, group_size, filter_size, refit_scale, scales=None):
    """``scales`` is the trained (2, groups, N) [or (2*groups, N)] f32
    Wp/Wn magnitude table; without one (PTQ cold start) both magnitudes
    initialize symmetrically from the Algorithm-1 alpha fit."""
    k, n = w.shape
    g = k // group_size
    if scales is None:
        _, alpha = ternary.ternarize_matrix(w, group_size, filter_size, refit_scale)
        wpn = jnp.concatenate([alpha, alpha], axis=0)  # (2g, N) symmetric
    else:
        wpn = jnp.abs(scales.reshape(2 * g, n))
    scale_m, scale_e = quantize_scales(wpn)
    return ttq_partition(w, group_size), scale_m, scale_e


def _ttq_dequantize(qt: QTensor) -> jax.Array:
    codes = unpack2(qt.packed, qt.k).astype(jnp.float32)  # (K, N)
    g = qt.n_groups
    sc = dequantize_scales(qt.scale_m, qt.scale_e)  # (2g, N)
    wp, wn = sc[:g][:, None, :], sc[g:][:, None, :]
    c = codes.reshape(g, qt.group_size, qt.n)
    return jnp.where(c > 0, c * wp, c * wn).reshape(qt.k, qt.n)


def _ttq_ref_matmul(x_q: jax.Array, x_e: jax.Array, qt: QTensor) -> jax.Array:
    """Integer oracle: two ternary accumulations per cluster (positive and
    negative code masks), one mantissa multiply each, shared exponents."""
    m, k = x_q.shape
    g = qt.group_size
    codes = unpack2(qt.packed, qt.k).astype(jnp.int32)
    xg = x_q.astype(jnp.int32).reshape(m, k // g, g)
    wg = codes.reshape(k // g, g, qt.n)
    part_p = jnp.einsum("mkg,kgn->kmn", xg, jnp.maximum(wg, 0))  # int32
    part_n = jnp.einsum("mkg,kgn->kmn", xg, jnp.minimum(wg, 0))
    ng = qt.n_groups
    smp = qt.scale_m[:ng].astype(jnp.float32)[:, None, :]
    smn = qt.scale_m[ng:].astype(jnp.float32)[:, None, :]
    out = (part_p.astype(jnp.float32) * smp
           + part_n.astype(jnp.float32) * smn).sum(axis=0)
    scale = dfp.exp2i(qt.scale_e + jnp.asarray(x_e, jnp.int32))
    return out * (jnp.broadcast_to(scale, (m, 1)) if scale.ndim else scale)


register_format(
    "ttq",
    bits=2,
    encode=pack2,
    decode=unpack2,
    weight_codes=_ttq_weight_codes,
    kernel=None,  # Pallas path would need the two-row scale layout in VMEM
    fused_kernel=None,
    dequantize=_ttq_dequantize,
    ref_matmul=_ttq_ref_matmul,
)


# ---------------------------------------------------------------------------
# Generic weight quantization entry points (format-registry driven).
# ---------------------------------------------------------------------------
def quantize_weights(
    w: jax.Array,
    bits: int = 2,
    group_size: int = 64,
    filter_size: int = 1,
    refit_scale: bool = False,
    fmt: Optional[str] = None,
    scales: Optional[jax.Array] = None,
) -> QTensor:
    """Quantize a (K, N) projection with the paper's cluster scheme.

    The encoding is resolved through the format registry: ``fmt`` by name,
    else the default format for ``bits``.  In every case the scale table
    itself is re-quantized to 8-bit DFP so the whole pipeline stays
    sub-8-bit.

    The QTensor is always stamped with the *resolved* format name -- even
    when the caller selected by bits.  An empty ``fmt`` stamp re-resolves
    through the mutable ``_BY_BITS`` table at every later decode, which is
    ambiguous once two formats share a width (nf4/int4, mx/int8): the
    artifact's meaning would depend on registry state at load time instead
    of quantize time.  ``format_of`` still accepts legacy empty-fmt
    QTensors (pre-fix checkpoints) via the bits default, which registration
    keeps pointed at the built-ins.

    ``scales`` supplies an external f32 cluster-scale table (trained state:
    TTQ's learned Wp/Wn, an INQ freeze-event grid) -- the format builds its
    scale table from the GIVEN values instead of re-fitting from ``w``, so
    the deployed artifact runs on exactly the grid training converged to.
    Only forwarded when present, so formats registered without the keyword
    keep working.
    """
    k, n = w.shape
    w = w.astype(jnp.float32)
    f = get_format(fmt) if fmt else format_for_bits(bits)
    if f.block_size is not None:
        group_size = f.block_size  # format-fixed cluster length (mx: 32)
    if scales is not None:
        codes, scale_m, scale_e = f.weight_codes(
            w, group_size, filter_size, refit_scale,
            scales=scales.astype(jnp.float32),
        )
    else:
        codes, scale_m, scale_e = f.weight_codes(
            w, group_size, filter_size, refit_scale
        )
    return QTensor(
        f.encode(codes), scale_m, scale_e, f.bits, group_size, (k, n),
        fmt=f.name,
    )


def decode_codes(qt: QTensor) -> jax.Array:
    """Integer mantissas (K, N) int8 of a QTensor."""
    return format_of(qt).decode(qt.packed, qt.k)


def dequantize_weights(qt: QTensor) -> jax.Array:
    """f32 (K, N) reconstruction."""
    f = format_of(qt)
    if f.dequantize is not None:  # non-standard scale layout (ttq: Wp/Wn)
        return f.dequantize(qt)
    codes = decode_codes(qt).astype(jnp.float32)
    scale = dequantize_scales(qt.scale_m, qt.scale_e)  # (groups, N)
    c = codes.reshape(qt.n_groups, qt.group_size, qt.n)
    return (c * scale[:, None, :]).reshape(qt.k, qt.n)


def fake_quantize_weights(
    w: jax.Array, bits: int, group_size: int, filter_size: int = 1,
    refit_scale: bool = False, fmt: Optional[str] = None,
) -> jax.Array:
    """quantize -> dequantize (QAT forward / error measurement).

    ``fmt`` resolves a named format exactly like ``quantize_weights`` --
    QAT on nf4/mx must adapt the weights to the LUT/shift grid they will
    actually deploy on, not the bits-default uniform grid."""
    return dequantize_weights(
        quantize_weights(w, bits, group_size, filter_size, refit_scale, fmt=fmt)
    )


def weight_quantization_error(w, bits, group_size, filter_size=1) -> jax.Array:
    wq = fake_quantize_weights(w, bits, group_size, filter_size)
    return jnp.sum((w - wq) ** 2)
