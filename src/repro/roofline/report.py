"""Render the EXPERIMENTS.md roofline tables from dry-run JSON sweeps."""
from __future__ import annotations

import json
from typing import Dict, List

from repro import configs
from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_BF16_FLOPS


def _model_flops(row: Dict) -> float:
    """6*N*D for train (fwd+bwd), 2*N_active*D for one serve step."""
    cfg = configs.get_config(row["arch"])
    shape = configs.get_shape(row["shape"])
    n = row["n_params"]
    if cfg.n_experts:  # active params: experts scaled by top_k/E
        api_n = n  # total; approximate expert share via ffn fraction
        expert_frac = (
            3 * cfg.d_ff * cfg.d_model * cfg.n_experts * cfg.n_layers
        ) / max(n, 1)
        n = n * (1 - expert_frac) + n * expert_frac * cfg.top_k / cfg.n_experts
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def load(path: str) -> List[Dict]:
    with open(path) as f:
        return json.load(f)


def table(rows: List[Dict]) -> str:
    out = [
        "| arch | shape | mesh | mode | compute ms | memory ms | collective ms "
        "| dominant | roofline frac | MODEL/HLO flops | args GiB | temps GiB |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | - | - | skipped | - | - | - |"
            )
            continue
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | - | - | FAILED | - | - | - |"
            )
            continue
        roof = r["roofline_s"]
        per = r["per_device"]
        ma = r["memory_analysis"]
        total = roof["compute"] + roof["memory"] + roof["collective"]
        frac = max(roof["compute"], roof["memory"], roof["collective"]) / total if total else 0
        chips = r["n_chips"]
        mf = _model_flops(r) / chips
        useful = mf / per["flops"] if per["flops"] else 0.0
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['quant_mode']} "
            f"| {roof['compute'] * 1e3:.2f} | {roof['memory'] * 1e3:.2f} "
            f"| {roof['collective'] * 1e3:.2f} | {roof['dominant']} | {frac:.2f} "
            f"| {useful:.2f} | {ma['argument_size'] / 2**30:.2f} "
            f"| {ma['temp_size'] / 2**30:.2f} |"
        )
    return "\n".join(out)


def summarize(rows: List[Dict]) -> Dict[str, List[str]]:
    """Classify cells for the hillclimb pick (worst frac / most collective)."""
    ok = [r for r in rows if r["status"] == "ok"]

    def frac(r):
        roof = r["roofline_s"]
        tot = roof["compute"] + roof["memory"] + roof["collective"]
        return max(roof.values(), key=lambda v: v if isinstance(v, float) else 0) / tot if tot else 0

    coll_bound = sorted(
        ok,
        key=lambda r: -(
            r["roofline_s"]["collective"]
            / max(r["roofline_s"]["compute"] + r["roofline_s"]["memory"] + r["roofline_s"]["collective"], 1e-12)
        ),
    )
    worst_frac = sorted(ok, key=lambda r: _useful(r))
    return {
        "most_collective_bound": [f"{r['arch']}x{r['shape']}" for r in coll_bound[:5]],
        "worst_useful_flops": [f"{r['arch']}x{r['shape']}" for r in worst_frac[:5]],
    }


def _useful(r) -> float:
    per = r["per_device"]
    return (_model_flops(r) / r["n_chips"]) / per["flops"] if per["flops"] else 0.0


if __name__ == "__main__":
    import sys

    rows = load(sys.argv[1])
    print(table(rows))
    print()
    print(json.dumps(summarize(rows), indent=1))
