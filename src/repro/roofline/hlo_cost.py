"""Loop-expanded cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` on the CPU backend counts each while-loop
body ONCE, not times its trip count -- scanned layer stacks / microbatch
loops / chunked attention make the aggregate meaningless (verified: doubling
microbatches halves reported flops).  This module re-derives the three
roofline inputs by statically walking the optimized HLO:

  * computations are parsed into per-instruction (shape, opcode, operands),
  * ``while`` ops multiply their body cost by the trip count recovered from
    the loop condition's comparison constant (scans have static trips),
  * ``fusion`` counts operand+result bytes only (internals never touch HBM)
    but recurses for dot FLOPs,
  * ``conditional`` takes the max across branches,
  * collective bytes (all-gather/all-reduce/reduce-scatter/all-to-all/
    collective-permute) accumulate with the same loop multipliers.

dot FLOPs = 2 * prod(result shape) * prod(contracting dims).  Elementwise
arithmetic contributes prod(result) (negligible next to the GEMMs but kept
for completeness).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 0.5, "u4": 0.5, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE_FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "log", "rsqrt", "sqrt", "tanh", "power", "negate", "abs", "floor", "ceil",
    "round-nearest-even", "round-nearest-afz", "cosine", "sine", "logistic",
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}\s]*?))\s*"
    r"([\w\-]+)\((.*)$"
)


def _shape_elems_bytes(shape_str: str) -> Tuple[float, float]:
    elems = byts = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1.0
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * mult


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._memo: Dict[str, Cost] = {}
        self._parse(hlo_text)

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for line in text.splitlines():
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{", line)
            if m and not line.startswith(" "):
                cur = m.group(2)
                self.comps[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if cur is not None:
                if line.startswith("}"):
                    cur = None
                else:
                    self.comps[cur].append(line)

    # -- helpers -----------------------------------------------------------
    def _instructions(self, comp: str):
        shapes: Dict[str, str] = {}
        for line in self.comps.get(comp, ()):
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, shape_str, opcode, rest = m.groups()
            shapes[name] = shape_str
            yield name, shape_str, opcode, rest, shapes

    def _trip_count(self, cond_comp: str) -> float:
        """Largest integer comparison constant in the loop condition."""
        best = 1
        for line in self.comps.get(cond_comp, ()):
            m = re.search(r"constant\((\d+)\)", line)
            if m:
                best = max(best, int(m.group(1)))
        return float(best)

    def _called(self, rest: str, attr: str) -> List[str]:
        m = re.search(rf"{attr}=%?([\w.\-]+)", rest)
        if m:
            return [m.group(1)]
        m = re.search(rf"{attr}=\{{([^}}]*)\}}", rest)
        if m:
            return [c.strip().lstrip("%") for c in m.group(1).split(",")]
        return []

    def _operand_bytes(self, rest: str, shapes: Dict[str, str]) -> float:
        total = 0.0
        for op in re.findall(r"%([\w.\-]+)", rest.split("),")[0]):
            if op in shapes:
                _, b = _shape_elems_bytes(shapes[op])
                total += b
        return total

    def _dot_flops(self, shape_str: str, rest: str, shapes: Dict[str, str]) -> float:
        out_elems, _ = _shape_elems_bytes(shape_str)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
        ops = re.findall(r"%([\w.\-]+)", rest)
        if not m or not ops or ops[0] not in shapes:
            return 2.0 * out_elems  # fallback
        lhs_dims = [int(d) for d in m.group(1).split(",") if d]
        lhs_shape = _SHAPE_RE.findall(shapes[ops[0]])
        if not lhs_shape:
            return 2.0 * out_elems
        dims = [int(d) for d in lhs_shape[0][1].split(",") if d]
        k = 1.0
        for d in lhs_dims:
            if d < len(dims):
                k *= dims[d]
        return 2.0 * out_elems * k

    def _fusion_bytes(
        self, rest: str, shapes: Dict[str, str], fused: Optional[str],
        out_bytes: float, out_shape_str: str,
    ) -> float:
        """HBM traffic of one fusion: region-aware for fused slices and
        in-place cache updates.

        * an operand whose every use inside the fused computation is a
          slice/dynamic-slice/gather is read only at the REGION size (the
          stacked layer weights sliced inside a scan body otherwise count at
          full size x trip count);
        * a dynamic-update-slice at (or feeding) the fusion root writes only
          the update region (the KV cache is loop-aliased in place).
        """
        if fused is None or fused not in self.comps:
            return out_bytes + self._operand_bytes(rest, shapes)

        param_reads: Dict[int, float] = {}
        param_sliced: Dict[int, bool] = {}
        dus_updates: List[Tuple[str, float]] = []  # (out shape str, update bytes)
        inner_shapes: Dict[str, str] = {}
        param_names: Dict[str, int] = {}
        for name, shape_str, opcode, prest, _sh in self._instructions(fused):
            inner_shapes[name] = shape_str
            if opcode == "parameter":
                m = re.match(r"\s*(\d+)", prest)
                if m:
                    idx = int(m.group(1))
                    param_names[name] = idx
                    param_sliced[idx] = True
                    param_reads[idx] = 0.0
                continue
            ops_ = re.findall(r"%([\w.\-]+)", prest)
            _, ob = _shape_elems_bytes(shape_str)
            if opcode == "dynamic-update-slice" and len(ops_) > 1:
                ub = _shape_elems_bytes(inner_shapes.get(ops_[1], ""))[1]
                dus_updates.append((shape_str.strip(), ub))
            for o in ops_:
                if o in param_names:
                    idx = param_names[o]
                    if opcode in ("slice", "dynamic-slice", "gather"):
                        param_reads[idx] += ob  # region read
                    elif opcode == "dynamic-update-slice" and ops_ and ops_[0] == o:
                        pass  # aliased destination: not a full read
                    else:
                        param_sliced[idx] = False

        # operand list in order = parameter order
        operand_names = re.findall(r"%([\w.\-]+)", rest.split("),")[0])
        total = 0.0
        for idx, o in enumerate(operand_names):
            if o not in shapes:
                continue
            _, full = _shape_elems_bytes(shapes[o])
            if param_sliced.get(idx, False):
                total += min(param_reads.get(idx, full), full)
            else:
                total += full

        # output: replace DUS-shaped components with their update regions
        out_total = out_bytes
        for dus_shape, ub in dus_updates:
            comp_b = _shape_elems_bytes(dus_shape)[1]
            if comp_b <= out_bytes + 1:
                out_total = out_total - comp_b + 2.0 * ub
        return total + max(out_total, 0.0)

    # -- main recursion ------------------------------------------------------
    def cost(self, comp: Optional[str] = None) -> Cost:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total  # break cycles defensively
        for name, shape_str, opcode, rest, shapes in self._instructions(comp):
            out_elems, out_bytes = _shape_elems_bytes(shape_str)
            if opcode in ("parameter", "constant", "get-tuple-element", "tuple",
                          "bitcast", "after-all"):
                continue
            if opcode == "while":
                bodies = self._called(rest, "body")
                conds = self._called(rest, "condition")
                trip = self._trip_count(conds[0]) if conds else 1.0
                if bodies:
                    total.add(self.cost(bodies[0]), trip)
                continue
            if opcode == "conditional":
                branches = self._called(rest, "branch_computations") or (
                    self._called(rest, "true_computation")
                    + self._called(rest, "false_computation")
                )
                if branches:
                    costs = [self.cost(b) for b in branches]
                    worst = max(costs, key=lambda c: c.flops + c.bytes)
                    total.add(worst)
                continue
            if opcode == "call":
                for c in self._called(rest, "to_apply"):
                    total.add(self.cost(c))
                continue
            if opcode == "fusion":
                called = self._called(rest, "calls")
                total.bytes += self._fusion_bytes(
                    rest, shapes, called[0] if called else None, out_bytes, shape_str
                )
                for c in called:
                    total.flops += self.cost(c).flops  # dots inside fusions
                continue
            # collectives: result bytes, plus they move memory
            hit = next((c for c in _COLLECTIVES if opcode.startswith(c)), None)
            if hit:
                total.coll[hit] += out_bytes
                total.bytes += out_bytes + self._operand_bytes(rest, shapes)
                continue
            if opcode in ("dot", "convolution"):
                total.flops += self._dot_flops(shape_str, rest, shapes)
                total.bytes += out_bytes + self._operand_bytes(rest, shapes)
                continue
            if opcode in ("slice", "dynamic-slice", "gather"):
                # slicing reads only the selected REGION, not the operand --
                # counting full operands multiplies stacked-layer weights by
                # the scan trip count (~100x overcount on 80L models)
                total.bytes += 2.0 * out_bytes
                continue
            if opcode in ("dynamic-update-slice", "scatter"):
                # in-place region update: read+write of the update operand
                ops_ = re.findall(r"%([\w.\-]+)", rest)
                upd = ops_[1] if len(ops_) > 1 else None
                if upd and upd in shapes:
                    _, ub = _shape_elems_bytes(shapes[upd])
                    total.bytes += 2.0 * ub
                else:
                    total.bytes += out_bytes
                if opcode == "scatter":
                    total.flops += out_elems
                continue
            if opcode in ("copy", "copy-start", "transpose", "reshape", "broadcast",
                          "concatenate", "reduce", "sort", "iota", "convert",
                          "compare", "select", "pad", "reverse", "rng", "map"):
                total.bytes += out_bytes + self._operand_bytes(rest, shapes)
                if opcode in ("reduce", "sort", "map"):
                    total.flops += out_elems
                continue
            if opcode in _ELEMENTWISE_FLOP:
                total.flops += out_elems
                total.bytes += out_bytes + self._operand_bytes(rest, shapes)
                continue
            # default: count memory movement only
            total.bytes += out_bytes + self._operand_bytes(rest, shapes)
        return total


def loop_expanded_cost(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).cost()
