"""Three-term roofline analysis from the compiled dry-run artifact.

  compute term    = HLO_FLOPs / (peak_FLOP/s per chip)
  memory term     = HLO_bytes / (HBM bandwidth per chip)
  collective term = collective_bytes / (ICI link bandwidth per chip)

FLOPs and bytes come from ``compiled.cost_analysis()`` (per-partition after
SPMD).  Collective bytes are NOT in cost_analysis: we parse the optimized
HLO and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.  Target: TPU v5e.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Tuple

# TPU v5e hardware constants (per chip) from the assignment.
PEAK_BF16_FLOPS = 197e12
PEAK_INT8_OPS = 394e12  # 2x bf16 on the MXU (used by the Pallas int8 path)
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    """Total bytes of all dtype[shape] groups in an HLO result signature."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-kind result bytes summed over the module."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^=]*?)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)[\w.\-]*\(", line)
        if not m:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
    return out


@dataclasses.dataclass
class Roofline:
    flops: float  # per device, loop-expanded
    bytes_accessed: float  # per device, loop-expanded
    coll_bytes: float  # per device, loop-expanded
    coll_breakdown: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    xla_raw: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def fraction_of_roofline(self) -> float:
        """dominant-term share of total serialized time: how close the
        three-term sum is to the pure bottleneck (1.0 = perfectly
        overlapped/bottleneck-only)."""
        tot = self.compute_s + self.memory_s + self.collective_s
        return self.bound_s / tot if tot else 0.0


def analyze(compiled, lowered_text: str = "", peak_flops: float = PEAK_BF16_FLOPS) -> Roofline:
    """Loop-expanded roofline terms.

    XLA's CPU ``cost_analysis()`` counts while-loop bodies once (verified:
    doubling the microbatch scan halves its reported flops), which makes
    scanned layer stacks meaningless.  We therefore derive flops / bytes /
    collective bytes from a static walk of the optimized HLO that multiplies
    loop bodies by their trip counts (roofline/hlo_cost.py).  The raw XLA
    numbers are kept in ``xla_raw`` for reference.
    """
    from repro.roofline.hlo_cost import loop_expanded_cost

    raw = compiled.cost_analysis()
    if isinstance(raw, list):  # some backends return [dict]
        raw = raw[0]
    text = lowered_text or compiled.as_text()
    c = loop_expanded_cost(text)
    cbytes = sum(c.coll.values())
    r = Roofline(
        flops=c.flops,
        bytes_accessed=c.bytes,
        coll_bytes=cbytes,
        coll_breakdown={k: v for k, v in c.coll.items() if v},
        compute_s=c.flops / peak_flops,
        memory_s=c.bytes / HBM_BW,
        collective_s=cbytes / ICI_BW,
    )
    r.xla_raw = {
        "flops": float(raw.get("flops", 0.0)),
        "bytes_accessed": float(raw.get("bytes accessed", 0.0)),
    }
    return r


def model_flops(n_params_active: float, tokens: float) -> float:
    """MODEL_FLOPS = 6 * N_active * D (training) or 2 * N * D (inference)."""
    return 6.0 * n_params_active * tokens


def count_params(params_shapes) -> Tuple[float, float]:
    """(total, active) param count from an eval_shape tree.

    'active' divides MoE expert stacks by experts/top_k (top-k routing).
    QTensor packed fields are expanded back to logical element counts.
    """
    import jax

    from repro.core.quantizer import QTensor

    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shapes)[0]:
        keys = [getattr(e, "key", getattr(e, "name", "")) for e in path]
        name = "/".join(str(k) for k in keys)
        if name.endswith("scale_m") or name.endswith("scale_e"):
            continue
        n = 1
        for d in leaf.shape:
            n *= d
        if name.endswith("packed"):
            if str(leaf.dtype).startswith("uint32"):
                n *= 16  # ternary packing (approx; int4 is 8 -- fine for 6ND scale)
        total += n
    return total, total


def summary_row(arch: str, shape: str, mesh: str, r: Roofline, mflops: float) -> str:
    usef = mflops / r.flops if r.flops else 0.0
    return (
        f"| {arch} | {shape} | {mesh} | {r.compute_s*1e3:.2f} | {r.memory_s*1e3:.2f} "
        f"| {r.collective_s*1e3:.2f} | {r.dominant} | {usef:.2f} |"
    )
