"""Roofline analysis from compiled dry-run artifacts."""
from repro.roofline.analysis import Roofline, analyze, collective_bytes
