"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 -- 5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-*; unverified]"""
from repro.configs.base import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="gemma3-12b", family="dense",
        n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
        d_ff=15360, vocab=262144, head_dim=240,
        sliding_window=1024, local_global_ratio=5, rope_theta=1_000_000.0,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="gemma3-12b-smoke", family="dense",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16,
        sliding_window=8, local_global_ratio=5, remat=False, dtype="float32",
    )
