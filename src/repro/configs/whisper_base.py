"""whisper-base [audio]: 6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865 -- enc-dec, conv frontend stubbed (input_specs feeds precomputed
frame embeddings).  [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="whisper-base", family="encdec",
        n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        d_ff=2048, vocab=51865, head_dim=64,
        frontend="audio", n_audio_frames=1500,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="whisper-base-smoke", family="encdec",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, head_dim=16,
        frontend="audio", n_audio_frames=16, remat=False, dtype="float32",
    )
