"""Architecture + runtime configuration schema.

One ``ArchConfig`` dataclass covers all ten assigned families (dense / MoE /
VLM / hybrid-SSM / SSM / enc-dec audio).  Each configs/<id>.py module exports
``full()`` (the exact published configuration) and ``smoke()`` (a reduced
same-family config for CPU tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Paper knobs: weight bits, cluster size (group along reduction dim)."""

    w_bits: int = 2  # 2 = ternary (Algorithm 1), 4, 8, 32 = off
    act_bits: int = 8
    group_size: int = 64  # paper's N*K^2 reduction segment per alpha
    filter_size: int = 1  # Algorithm-2 unit within a cluster
    refit_scale: bool = False  # beyond-paper L2 refit of alpha
    mode: str = "fp"  # 'fp' | 'qat' | 'ptq'
    backend: str = "auto"  # qmatmul backend for ptq
    # registered weight-format name (nf4, mx, ...); None keeps the w_bits
    # ladder (ternary/int4/int8).  Formats with a fixed block (mx: 32)
    # override group_size for the default sites.
    fmt: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | hybrid | ssm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # attention flavour
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False  # qwen2-vl 3-D rotary
    sliding_window: Optional[int] = None  # local-attention window
    local_global_ratio: int = 0  # gemma3: N local layers per 1 global

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False  # arctic: dense MLP in parallel w/ MoE
    capacity_factor: float = 1.25
    moe_chunk_tokens: int = 65536  # dispatch chunk (bounds buffer memory)

    # SSM (mamba1/mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 1  # 1 = falcon-mamba, 2 = zamba2
    ssm_heads: int = 0  # mamba2 heads (d_inner // head size)

    # hybrid (zamba2): one of ``n_shared`` shared attn blocks every period
    shared_attn_period: int = 0
    n_shared_blocks: int = 2

    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_audio_frames: int = 1500  # encoder sequence (stub frontend output)

    # modality frontend stub: 'vision' | 'audio' | None
    frontend: Optional[str] = None
    n_frontend_tokens: int = 0  # e.g. vision tokens prepended to text

    # numerics / memory
    dtype: str = "bfloat16"
    kv_bits: int = 16  # 8 = DFP-quantized KV cache (per-token-head exponents)
    # registered KV-cache format name (models/kv_cache.py); None defers to
    # kv_bits back-compat: 8 -> 'kv_int8', else 'kv_bf16'
    kv_fmt: Optional[str] = None
    flash_decode: bool = False  # fused Pallas flash-decode kernel for S==1
    # fused Pallas flash kernel for S>1 cache-attends (chunked prefill) and
    # the in-chunk self-attention tail; independent of flash_decode
    flash_prefill: bool = False
    remat: bool = True
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    vocab_pad_to: int = 256  # pad vocab so logits shard over 'model'

    quant: QuantConfig = QuantConfig()

    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return -(-self.vocab // m) * m if m else self.vocab

    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def supports_long_context(self) -> bool:
        """Sub-quadratic context scaling (decides the long_500k cell)."""
        return self.family in ("ssm", "hybrid") or self.local_global_ratio > 0

    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-dec)


def config_to_dict(cfg: ArchConfig) -> dict:
    """JSON-safe serialization of an ArchConfig (nested QuantConfig included).

    This is what rides in a quantized artifact's manifest so a serving
    process can rebuild the exact model configuration with no out-of-band
    state (``repro.models.load_servable``)."""
    return dataclasses.asdict(cfg)


def config_from_dict(d: dict) -> ArchConfig:
    """Inverse of ``config_to_dict``."""
    d = dict(d)
    d["quant"] = QuantConfig(**d.get("quant", {}))
    # JSON turns tuples into lists; ArchConfig has no tuple fields today,
    # but keep unknown keys loud rather than silently dropped
    return ArchConfig(**d)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
