"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 -- M-RoPE, dynamic resolution (vision frontend stubbed:
input_specs feeds precomputed patch embeddings).  [arXiv:2409.12191; hf]"""
from repro.configs.base import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, head_dim=128,
        mrope=True, frontend="vision", n_frontend_tokens=1024,
        rope_theta=1_000_000.0,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-72b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16,
        mrope=True, frontend="vision", n_frontend_tokens=4,
        remat=False, dtype="float32",
    )
