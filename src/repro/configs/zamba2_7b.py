"""zamba2-7b [hybrid]: 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64 -- Mamba2 backbone + 2 alternating SHARED attention blocks
every 6 layers (zamba2 weight sharing).  [arXiv:2411.15242; unverified]"""
from repro.configs.base import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab=32000, head_dim=112,
        ssm_state=64, ssm_version=2, ssm_expand=2, ssm_heads=112,
        shared_attn_period=6, n_shared_blocks=2,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b-smoke", family="hybrid",
        n_layers=7, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, head_dim=16,
        ssm_state=8, ssm_version=2, ssm_expand=2, ssm_heads=2,
        shared_attn_period=3, n_shared_blocks=2, remat=False, dtype="float32",
    )
