"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="grok-1-314b", family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=32768, vocab=131072, head_dim=128,
        n_experts=8, top_k=2, rope_theta=10_000.0,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="grok-1-314b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16,
        n_experts=4, top_k=2, remat=False, dtype="float32",
    )
