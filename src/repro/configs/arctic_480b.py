"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual MLP.  [hf:Snowflake/snowflake-arctic-base]"""
from repro.configs.base import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b", family="moe",
        n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=4864, vocab=32000, head_dim=128,
        n_experts=128, top_k=2, moe_dense_residual=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=256, head_dim=16,
        n_experts=8, top_k=2, moe_dense_residual=True, remat=False, dtype="float32",
    )
