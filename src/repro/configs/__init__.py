"""Config registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``.

Arch ids match the assignment table; ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.configs.base import SHAPES, ArchConfig, QuantConfig, ShapeConfig, get_shape

_MODULES: Dict[str, str] = {
    "grok-1-314b": "grok_1_314b",
    "arctic-480b": "arctic_480b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen3-8b": "qwen3_8b",
    "qwen1.5-110b": "qwen1_5_110b",
    "gemma3-12b": "gemma3_12b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "zamba2-7b": "zamba2_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-base": "whisper_base",
}

ARCH_IDS: List[str] = list(_MODULES)


def _load(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str, quant: QuantConfig | None = None) -> ArchConfig:
    cfg = _load(arch).full()
    if quant is not None:
        cfg = dataclasses.replace(cfg, quant=quant)
    return cfg


def get_smoke(arch: str, quant: QuantConfig | None = None) -> ArchConfig:
    cfg = _load(arch).smoke()
    if quant is not None:
        cfg = dataclasses.replace(cfg, quant=quant)
    return cfg


def cells():
    """All assigned (arch x shape) cells with skip annotations."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            skip = None
            if shape.name == "long_500k" and not cfg.supports_long_context():
                skip = "pure full-attention arch: 500k decode is quadratic-cost; skipped per assignment"
            out.append((arch, shape, skip))
    return out


__all__ = [
    "ARCH_IDS",
    "ArchConfig",
    "QuantConfig",
    "SHAPES",
    "ShapeConfig",
    "cells",
    "get_config",
    "get_shape",
    "get_smoke",
]
