"""Pallas TPU kernel: fused per-row dynamic activation quantization.

One VMEM pass per row block: max|x| -> shared exponent -> round-to-nearest
int8 mantissas.  Fusing the three steps avoids two extra HBM round-trips of
the f32 activation tensor (the dominant cost of dynamic quantization on a
bandwidth-bound chip).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dfp import qmax

try:
    from jax.experimental.pallas import tpu as pltpu

    _COMPILER_PARAMS = pltpu.CompilerParams(dimension_semantics=("parallel",))
except Exception:  # pragma: no cover
    _COMPILER_PARAMS = None


def _kernel(x_ref, q_ref, e_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)  # (bm, D)
    max_abs = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    safe = jnp.maximum(max_abs, jnp.finfo(jnp.float32).tiny)
    e = jnp.ceil(jnp.log2(safe / qmax(bits)))
    e = jnp.where(max_abs > 0, e, jnp.zeros_like(e))
    q = jnp.clip(jnp.round(x * jnp.exp2(-e)), -qmax(bits), qmax(bits))
    q_ref[...] = q.astype(jnp.int8)
    e_ref[...] = e.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits", "block_m", "interpret"))
def quantize_rows(
    x: jax.Array,  # f32/bf16 (M, D)
    *,
    bits: int = 8,
    block_m: int = 256,
    interpret: bool = False,
):
    """Returns (int8 mantissas (M, D), int32 exponents (M, 1))."""
    m, d = x.shape
    bm = min(block_m, m)
    assert m % bm == 0, (m, bm)
    kern = functools.partial(_kernel, bits=bits)
    return pl.pallas_call(
        kern,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, d), jnp.int8),
            jax.ShapeDtypeStruct((m, 1), jnp.int32),
        ],
        compiler_params=None if interpret else _COMPILER_PARAMS,
        interpret=interpret,
    )(x)
