"""Pallas TPU kernel: fused per-row dynamic activation quantization.

One VMEM pass per row block: max|x| -> shared exponent -> round-to-nearest
int8 mantissas.  Fusing the three steps avoids two extra HBM round-trips of
the f32 activation tensor (the dominant cost of dynamic quantization on a
bandwidth-bound chip).

This is the standalone prologue used by the *unfused* qmatmul pipeline
(``quantize_activations`` selects it on TPU); the fused ``qdense`` path goes
further and runs the same quantization inside the matmul kernel itself
(``kernels/_common.fused_qmm_call``) so the int8 mantissas never touch HBM
at all.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dfp import exp2i, qmax
from repro.kernels._common import m_bucket, pick_block

try:
    from jax.experimental.pallas import tpu as pltpu

    _COMPILER_PARAMS = pltpu.CompilerParams(dimension_semantics=("parallel",))
except Exception:  # pragma: no cover
    _COMPILER_PARAMS = None


def _kernel(x_ref, q_ref, e_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)  # (bm, D)
    max_abs = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    safe = jnp.maximum(max_abs, jnp.finfo(jnp.float32).tiny)
    e = jnp.ceil(jnp.log2(safe / qmax(bits)))
    e = jnp.where(max_abs > 0, e, jnp.zeros_like(e))
    q = jnp.clip(jnp.round(x * exp2i(-e)), -qmax(bits), qmax(bits))
    q_ref[...] = q.astype(jnp.int8)
    e_ref[...] = e.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits", "block_m", "interpret"))
def quantize_rows(
    x: jax.Array,  # f32/bf16 (M, D)
    *,
    bits: int = 8,
    block_m: int = 256,
    interpret: bool = False,
):
    """Returns (int8 mantissas (M, D), int32 exponents (M, 1))."""
    m, d = x.shape
    # ragged serving batches: pad rows to a power-of-two bucket (same policy
    # as the matmul backends -- aligned blocks, one trace per bucket) rather
    # than shrinking the block to an arbitrary divisor of M
    mp = m_bucket(m)
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))  # zero rows -> q=0, e=0
    bm = pick_block(mp, block_m)
    kern = functools.partial(_kernel, bits=bits)
    q, e = pl.pallas_call(
        kern,
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, d), jnp.int8),
            jax.ShapeDtypeStruct((mp, 1), jnp.int32),
        ],
        compiler_params=None if interpret else _COMPILER_PARAMS,
        interpret=interpret,
    )(x)
    return (q[:m], e[:m]) if mp != m else (q, e)
