"""Pallas TPU kernel: int8 activations x packed 4-bit NormalFloat weights.

Same tiling/accumulation structure as int4_matmul (8 codes per uint32 word,
4x HBM traffic reduction vs bf16), but the 4-bit fields are *lookup-table
indices*, not two's-complement mantissas: each code selects one of the 16
NF4 quantiles, stored on the int8 grid (``repro.core.quantizer.NF4_LUT_I8``)
so the decoded tile feeds the MXU int8 contraction exactly like every other
format.  The per-cluster scale is the cluster's absmax / 127, re-quantized
to 8-bit DFP -- one multiply per cluster, the paper's arithmetic budget,
with the LUT soaking up the normal-shaped weight distribution that a uniform
int4 grid wastes codes on.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels._common import (
    NF4_PER_WORD,
    decode_nf4_tile,
    fused_qmm_call,
    packed_qmm_call,
)


@functools.partial(
    jax.jit, static_argnames=("group", "block_m", "block_n", "block_k", "interpret")
)
def nf4_matmul(
    x_q: jax.Array,  # int8 (M, K)
    packed: jax.Array,  # uint32 (K/8, N) of 4-bit LUT codes
    scale_m: jax.Array,  # int8 (K/group, N)
    *,
    group: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    return packed_qmm_call(
        x_q, packed, scale_m,
        decode=decode_nf4_tile, words_per_k=NF4_PER_WORD, group=group,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "group", "act", "act_bits", "act_exponent",
        "block_m", "block_n", "block_k", "interpret",
    ),
)
def nf4_matmul_fused(
    x: jax.Array,  # f32/bf16 (M, K) RAW activations (quantized in-kernel)
    packed: jax.Array,  # uint32 (K/8, N) of 4-bit LUT codes
    scale_m: jax.Array,  # int8 (K/group, N)
    scale_e: jax.Array,  # int32 scalar
    *,
    group: int,
    bias: jax.Array = None,
    act: str = None,
    act_bits: int = 8,
    act_exponent: int = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Whole dense site in one pallas_call: quantize prologue + in-kernel
    16-entry-LUT nf4 decode + matmul + exp2/bias/activation epilogue."""
    return fused_qmm_call(
        x, packed, scale_m, scale_e,
        decode=decode_nf4_tile, words_per_k=NF4_PER_WORD, n=packed.shape[1],
        group=group, bias=bias, act=act, act_bits=act_bits,
        act_exponent=act_exponent, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret,
    )
