"""Pallas TPU kernel: int8 activations x packed 4-bit DFP weights.

Same tiling/accumulation structure as ternary_matmul (see that module), with
4-bit two's-complement decode (8 weights per uint32 word -> 4x HBM traffic
reduction vs bf16) and per-cluster 8-bit scale mantissas.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._common import INT4_PER_WORD, decode4_tile, fused_qmm_call

try:
    from jax.experimental.pallas import tpu as pltpu

    _COMPILER_PARAMS = pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary")
    )
except Exception:  # pragma: no cover
    _COMPILER_PARAMS = None


def _kernel(x_ref, w_ref, s_ref, out_ref, *, bk: int, group: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w8 = decode4_tile(w_ref[...], bk)  # (bk, bn) int8 in [-8, 7]
    x = x_ref[...]
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for s in range(bk // group):
        xs = jax.lax.slice_in_dim(x, s * group, (s + 1) * group, axis=1)
        ws = jax.lax.slice_in_dim(w8, s * group, (s + 1) * group, axis=0)
        part = jax.lax.dot_general(
            xs, ws, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )
        acc = acc + part.astype(jnp.float32) * s_ref[s, :].astype(jnp.float32)[None, :]
    out_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("group", "block_m", "block_n", "block_k", "interpret")
)
def int4_matmul(
    x_q: jax.Array,  # int8 (M, K)
    packed: jax.Array,  # uint32 (K/8, N)
    scale_m: jax.Array,  # int8 (K/group, N)
    *,
    group: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    m, k = x_q.shape
    n = packed.shape[1]
    bm, bn = min(block_m, m), min(block_n, n)
    bk = min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bk % group == 0 and bk % INT4_PER_WORD == 0, (bk, group)

    kern = functools.partial(_kernel, bk=bk, group=group)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // INT4_PER_WORD, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=None if interpret else _COMPILER_PARAMS,
        interpret=interpret,
    )(x_q, packed, scale_m)


@functools.partial(
    jax.jit,
    static_argnames=(
        "group", "act", "act_bits", "act_exponent",
        "block_m", "block_n", "block_k", "interpret",
    ),
)
def int4_matmul_fused(
    x: jax.Array,  # f32/bf16 (M, K) RAW activations (quantized in-kernel)
    packed: jax.Array,  # uint32 (K/8, N)
    scale_m: jax.Array,  # int8 (K/group, N)
    scale_e: jax.Array,  # int32 scalar
    *,
    group: int,
    bias: jax.Array = None,
    act: str = None,
    act_bits: int = 8,
    act_exponent: int = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Whole dense site in one pallas_call: quantize prologue + int4 matmul
    + exp2/bias/activation epilogue (exponents applied in-kernel)."""
    return fused_qmm_call(
        x, packed, scale_m, scale_e,
        decode=decode4_tile, words_per_k=INT4_PER_WORD, n=packed.shape[1],
        group=group, bias=bias, act=act, act_bits=act_bits,
        act_exponent=act_exponent, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret,
    )
