"""Pallas TPU kernel: causal flash attention (online softmax over KV tiles).

The serving/training hot spot next to the quantized GEMMs.  Grid
(B*H, S/bq, T/bk) with the KV axis innermost ("arbitrary"): each (batch*head,
query-tile) revisits its output tile across KV tiles carrying running
(max, denom) statistics in VMEM scratch -- the S x T score matrix never
exists, mirroring the XLA-level chunked formulation in models/attention.py
(which remains the ref oracle / portable path).

Causal masking is positional: the KV tile index against the query tile
index; fully-masked tiles still run (grid is static) but contribute zero
via the -inf bias.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _COMPILER_PARAMS = pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary")
    )
except Exception:  # pragma: no cover
    _COMPILER_PARAMS = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, bq, bk, causal, scale):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bk)
    if causal:
        q_pos = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kv_idx == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,  # (BH, S, hd)
    k: jax.Array,  # (BH, T, hd)
    v: jax.Array,  # (BH, T, hd)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bh, s, hd = q.shape
    t = k.shape[1]
    bq, bk = min(block_q, s), min(block_k, t)
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    scale = hd**-0.5

    kern = functools.partial(_kernel, bq=bq, bk=bk, causal=causal, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(bh, s // bq, t // bk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            # running max / denom / accumulator live across the kv axis
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=None if interpret else _COMPILER_PARAMS,
        interpret=interpret,
    )(q, k, v)


def flash_attention_ref(q, k, v, causal: bool = True) -> jax.Array:
    """Pure-jnp oracle (dense softmax)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bsh,bth->bst", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if causal:
        sq, t = s.shape[1], s.shape[2]
        mask = jnp.arange(t)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,bth->bsh", p, v.astype(jnp.float32)).astype(q.dtype)
