"""Pure-jnp oracles for every Pallas kernel.

These implement the *exact* integer semantics the kernels must match:
int8 activations x packed sub-8-bit weights, int32 accumulation per cluster
(k-group), one scale multiply per cluster, shared power-of-two exponents.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dfp
from repro.core.quantizer import QTensor


def qmatmul_ref(x_q: jax.Array, x_e: jax.Array, qt: QTensor) -> jax.Array:
    """out[m, n] = sum_g scale[g, n] * (sum_{k in g} x_q[m, k] * w[k, n])
                   * 2**(scale_e + x_e[m])

    x_q : int8 (M, K) activation mantissas
    x_e : int32 () or (M, 1) activation exponent(s)
    qt  : QTensor weights (K, N)
    Returns f32 (M, N).
    """
    from repro.quant.formats import decode_codes, format_of  # lazy: avoids import cycle

    f = format_of(qt)
    if f.ref_matmul is not None:  # non-standard scale layout (ttq: Wp/Wn)
        return f.ref_matmul(x_q, x_e, qt)
    m, k = x_q.shape
    g = qt.group_size
    codes = decode_codes(qt)  # (K, N) int8
    xg = x_q.astype(jnp.int32).reshape(m, k // g, g)
    wg = codes.astype(jnp.int32).reshape(k // g, g, qt.n)
    # integer accumulation per cluster (the paper's "ternary accumulations")
    part = jnp.einsum("mkg,kgn->kmn", xg, wg)  # int32 (groups, M, N)
    # one multiply per cluster: scale mantissa applied to the int32 partial
    scaled = part.astype(jnp.float32) * qt.scale_m.astype(jnp.float32)[:, None, :]
    out = scaled.sum(axis=0)
    exp = qt.scale_e + jnp.asarray(x_e, jnp.int32)
    scale = dfp.exp2i(exp)  # exact power of two (the DFP contract)
    return out * (jnp.broadcast_to(scale, (m, 1)) if scale.ndim else scale)


def qmatmul_dequant_ref(x: jax.Array, qt: QTensor) -> jax.Array:
    """Float-side reference: fake-quantized activations x dequantized weights.
    Matches qmatmul_ref exactly when x comes from dynamic_quantize_act."""
    from repro.quant.formats import dequantize_weights

    return x.astype(jnp.float32) @ dequantize_weights(qt)


def quantize_rows_ref(x: jax.Array, bits: int = 8):
    """Per-row dynamic activation quantization oracle."""
    max_abs = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    e = dfp.choose_exponent(max_abs, bits)
    return dfp.quantize(x, e, bits), e
