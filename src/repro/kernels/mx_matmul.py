"""mx-format matmul kernels: int8 storage, all-shift block scales.

The mx format is the microscaling-style variant of the paper's DFP clusters:
every 32-element block along K shares one power-of-two scale and nothing
else.  Its QTensor scale table carries that as ``scale_m`` values restricted
to exact powers of two (1, 2, 4, ... 64) over a shared ``scale_e`` base --
the scale's DFP mantissa is 1 in the floating-point sense, so the
per-cluster "multiply" is a pure exponent shift.  Multiplying an int32
partial by an exact power-of-two f32 never rounds, so on today's kernels the
shift is realized as that multiply bit-exactly; integer hardware realizes it
as a barrel shift on the partial, which is the paper's
multiplication-elimination argument taken one step further than ternary:
int8 mantissa products on the MXU, *zero* true scale multiplies per cluster.

EXECUTION is identical to the int8 format -- raw int8 mantissas (1 B/weight
HBM stream), per-cluster scale application, int32 accumulation.  All of the
mx-ness lives in ``quant/formats._mx_weight_codes`` (what the scale table is
allowed to contain), so the kernels ARE the int8 kernels, aliased rather
than copied: any tuning or fix to ``int8_matmul`` (block heuristics,
compiler params, accumulation order) applies to mx automatically instead of
silently diverging.  The aliases keep mx a first-class registry citizen with
its own kernel module, per the format-authoring contract
(docs/WRITING_A_FORMAT.md); a future mx kernel that exploits the shift-only
scales natively (e.g. int32 shifts before a single f32 convert) replaces
these aliases without touching the registry.
"""
from __future__ import annotations

from repro.kernels.int8_matmul import int8_matmul, int8_matmul_fused

# signatures and semantics: see int8_matmul / int8_matmul_fused.  scale_m is
# additionally guaranteed (by the mx encoder) to hold only powers of two.
mx_matmul = int8_matmul
mx_matmul_fused = int8_matmul_fused
