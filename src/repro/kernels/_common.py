"""Shared Pallas helpers: in-VMEM sub-8-bit decode, tiling math, and the
prologue/epilogue-fused quantized-dense kernel builder.

TPU adaptation notes (see DESIGN.md Sec. 2.1): weights live in HBM packed
2-bit (16/uint32) or 4-bit (8/uint32).  A weight tile is decoded once in
VMEM to int8 lanes and contracted on the MXU with int32 accumulation; the
per-cluster scale is applied to the int32 partial -- one multiply per
cluster, exactly the paper's arithmetic budget.

``fused_qmm_call`` builds the whole dense-site pipeline as ONE pallas_call:

  prologue  : f32/bf16 activations quantized to int8 DFP mantissas in VMEM
              (per-row dynamic exponents computed on the first k-step, or a
              calibrated static exponent baked in as a compile-time scalar),
  matmul    : the per-format decode + per-cluster int32 accumulation loop,
  epilogue  : ``out * exp2(scale_e + xe)``, bias add, optional activation
              applied inside the resident output tile on the last k-step.

The unfused path round-trips the activation tensor through HBM three extra
times per projection (int8 write, raw f32 write, scaled/bias re-write); the
fused form reads x once and writes the finished output once.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dfp import exp2i as _exp2i

try:  # TPU-specific scratch allocator; absent on exotic installs is fine
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

try:  # scheduling hints: the class name moved across jax releases
    _cp = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    _FUSED_COMPILER_PARAMS = _cp(
        dimension_semantics=("parallel", "parallel", "arbitrary")
    )
except Exception:  # pragma: no cover
    _FUSED_COMPILER_PARAMS = None

TERNARY_PER_WORD = 16
INT4_PER_WORD = 8
NF4_PER_WORD = 8  # 4-bit LUT codes per uint32 (packed like int4)
MX_BLOCK = 32  # mx shared-exponent block length along K


def decode2_tile(words: jnp.ndarray, bk: int) -> jnp.ndarray:
    """(bk/16, bn) uint32 -> (bk, bn) int8 in {-1, 0, 1}."""
    lanes = []
    for i in range(TERNARY_PER_WORD):
        c = (words >> (2 * i)) & jnp.uint32(3)
        lanes.append((((c + 1) & 3).astype(jnp.int8) - 1))
    return jnp.stack(lanes, axis=1).reshape(bk, words.shape[-1])


def decode4_tile(words: jnp.ndarray, bk: int) -> jnp.ndarray:
    """(bk/8, bn) uint32 -> (bk, bn) int8 in [-8, 7]."""
    lanes = []
    for i in range(INT4_PER_WORD):
        c = ((words >> (4 * i)) & jnp.uint32(0xF)).astype(jnp.int8)
        lanes.append(jnp.where(c >= 8, c - 16, c))
    return jnp.stack(lanes, axis=1).reshape(bk, words.shape[-1])


def decode_nf4_tile(words: jnp.ndarray, bk: int) -> jnp.ndarray:
    """(bk/8, bn) uint32 of nf4 LUT codes -> (bk, bn) int8 LUT mantissas.

    The 16-entry lookup runs in-kernel as a select chain over the constant
    table (gathers from VMEM constants do not lower on all Pallas targets;
    16 vector selects per lane do, and vectorize on the VPU).  The resulting
    mantissas are ordinary int8 lanes, so the MXU contraction and per-cluster
    scale application downstream are identical to every other format."""
    from repro.core.quantizer import NF4_LUT_I8

    lanes = []
    for i in range(NF4_PER_WORD):
        c = ((words >> (4 * i)) & jnp.uint32(0xF)).astype(jnp.int32)
        v = jnp.zeros_like(c)
        for code, val in enumerate(NF4_LUT_I8):
            v = jnp.where(c == code, jnp.int32(val), v)
        lanes.append(v.astype(jnp.int8))
    return jnp.stack(lanes, axis=1).reshape(bk, words.shape[-1])


def pick_block(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is <= want (block shape helper)."""
    b = min(dim, want)
    while dim % b:
        b -= 1
    return b


def m_bucket(m: int) -> int:
    """Power-of-two row bucket (>= 8) ragged batches pad up to.

    Serving batches come in every size; padding M to the next power of two
    collapses them onto a handful of kernel specializations instead of one
    fresh trace/compile per distinct batch size."""
    b = 8
    while b < m:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# The unfused packed-matmul kernel (shared across weight formats).
# ---------------------------------------------------------------------------
def _packed_kernel(x_ref, w_ref, s_ref, out_ref, *, decode, bk: int, group: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w8 = decode(w_ref[...], bk)  # (bk, bn) int8 mantissa lanes
    x = x_ref[...]  # (bm, bk) int8
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for s in range(bk // group):
        xs = jax.lax.slice_in_dim(x, s * group, (s + 1) * group, axis=1)
        ws = jax.lax.slice_in_dim(w8, s * group, (s + 1) * group, axis=0)
        part = jax.lax.dot_general(
            xs, ws, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )
        # one multiply per cluster: scale mantissa applied to the int32 partial
        acc = acc + part.astype(jnp.float32) * s_ref[s, :].astype(jnp.float32)[None, :]
    out_ref[...] += acc


def packed_qmm_call(
    x_q: jax.Array,  # int8 (M, K) activation mantissas
    packed: jax.Array,  # per-format packed weights ((K/words_per_k, N))
    scale_m: jax.Array,  # int8 (K/group, N)
    *,
    decode: Callable,  # (words tile, bk) -> (bk, bn) int8
    words_per_k: int,
    group: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """One pallas_call for the unfused per-format matmul: tile decode +
    per-cluster int32 accumulation.  The grid/BlockSpec scaffolding is
    identical for every weight encoding -- only ``decode``/``words_per_k``
    vary -- so every per-format kernel module (ternary/int4/int8/nf4; mx
    aliases int8) wraps this builder instead of copying the tiling loop
    (the fused twin is ``fused_qmm_call``).  Exponents (scale_e +
    activation e) are applied by the caller."""
    m, k = x_q.shape
    n = packed.shape[1]
    bm, bn = min(block_m, m), min(block_n, n)
    bk = min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bk % group == 0 and bk % words_per_k == 0, (bk, group, words_per_k)

    kern = functools.partial(_packed_kernel, decode=decode, bk=bk, group=group)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // words_per_k, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        # same parallel/parallel/arbitrary semantics as the fused builder
        compiler_params=None if interpret else _FUSED_COMPILER_PARAMS,
        interpret=interpret,
    )(x_q, packed, scale_m)


# ---------------------------------------------------------------------------
# The fused quantized-dense kernel (shared across weight formats).
# ---------------------------------------------------------------------------
# The ONE activation-name table: both the fused kernel epilogue and the
# unfused jnp epilogue (quant/backends.apply_act) dispatch through it, so
# the supported-name sets can never drift apart.
ACTIVATIONS = {
    None: lambda y: y,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def activation_fn(name: Optional[str]) -> Callable:
    try:
        return ACTIVATIONS[name]
    except KeyError:
        raise ValueError(
            f"unknown activation {name!r}; supported: "
            f"{sorted(k for k in ACTIVATIONS if k)}"
        ) from None


def _fused_kernel(
    x_ref,  # (bm, K) f32/bf16: the full activation row block, resident per i
    w_ref,  # (bk/words_per_k, bn): packed weight words for this k-tile
    s_ref,  # (bk/group, bn) int8: per-cluster scale mantissas
    se_ref,  # (1, 1) int32: shared weight-scale exponent
    *rest,  # [b_ref (1, bn) f32 when has_bias,] out_ref (bm, bn) f32, e_scr
    decode: Callable,
    bk: int,
    group: int,
    nk: int,
    act_bits: int,
    static_e: Optional[int],
    act: Optional[str],
    has_bias: bool,
    exact: bool,
):
    if has_bias:
        b_ref, out_ref, e_scr = rest
    else:
        (out_ref, e_scr), b_ref = rest, None
    kk = pl.program_id(2)
    qmax = float(2 ** (act_bits - 1) - 1)
    # interpret mode pins bit-parity with the jnp oracle: the barrier forces
    # each f32 product to round before it feeds an add, which XLA:CPU would
    # otherwise contract into an fma (single rounding, 1-ulp drift)
    rnd = jax.lax.optimization_barrier if exact else (lambda v: v)

    @pl.when(kk == 0)
    def _prologue():
        out_ref[...] = jnp.zeros_like(out_ref)
        if static_e is None:
            # per-row dynamic DFP exponent over the FULL row (the row block
            # is resident, so the first k-step sees all of K); bit-identical
            # to kernels/quantize.py and dfp.choose_exponent
            x = x_ref[...].astype(jnp.float32)
            max_abs = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
            safe = jnp.maximum(max_abs, jnp.finfo(jnp.float32).tiny)
            e = jnp.ceil(jnp.log2(safe / qmax))
            e_scr[...] = jnp.where(max_abs > 0, e, jnp.zeros_like(e))

    if static_e is None:
        e = e_scr[...]  # (bm, 1) f32
    else:
        e = jnp.full((x_ref.shape[0], 1), float(static_e), jnp.float32)

    # quantize just this k-tile of the resident row block (VMEM -> VMEM);
    # exp2i builds the power-of-two scale exactly (jnp.exp2 is approximated
    # on some backends, which breaks the DFP contract AND bit parity)
    xs = x_ref[:, pl.ds(kk * bk, bk)].astype(jnp.float32)
    xq = jnp.clip(jnp.round(xs * _exp2i(-e)), -qmax, qmax).astype(jnp.int8)

    w8 = decode(w_ref[...], bk)  # (bk, bn) int8 lanes
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for s in range(bk // group):
        xg = jax.lax.slice_in_dim(xq, s * group, (s + 1) * group, axis=1)
        wg = jax.lax.slice_in_dim(w8, s * group, (s + 1) * group, axis=0)
        part = jax.lax.dot_general(
            xg, wg, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )
        # one multiply per cluster: scale mantissa applied to the int32 partial
        acc = acc + rnd(
            part.astype(jnp.float32) * s_ref[s, :].astype(jnp.float32)[None, :]
        )
    out_ref[...] += acc

    @pl.when(kk == nk - 1)
    def _epilogue():
        y = out_ref[...] * _exp2i(se_ref[0, 0].astype(jnp.float32) + e)
        if has_bias:
            y = rnd(y) + b_ref[...]
        out_ref[...] = activation_fn(act)(y)


def fused_qmm_call(
    x: jax.Array,  # f32/bf16 (M, K) raw activations
    packed: jax.Array,  # per-format packed weights
    scale_m: jax.Array,  # int8 (K/group, N)
    scale_e: jax.Array,  # int32 scalar
    *,
    decode: Callable,  # (words tile, bk) -> (bk, bn) int8
    words_per_k: int,  # K rows per packed row (1 for raw int8 storage)
    n: int,
    group: int,
    bias: Optional[jax.Array] = None,  # (N,) f32, fused into the epilogue
    act: Optional[str] = None,
    act_bits: int = 8,
    act_exponent: Optional[int] = None,  # static exponent; None -> dynamic
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """One pallas_call for quantize-prologue + qmatmul + scale/bias/act."""
    if pltpu is None:  # pragma: no cover
        raise RuntimeError("fused qdense kernels need jax.experimental.pallas.tpu")
    m, k = x.shape
    bm, bn = min(block_m, m), min(block_n, n)
    bk = min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bk % group == 0 and bk % words_per_k == 0, (bk, group, words_per_k)
    nk = k // bk

    kern = functools.partial(
        _fused_kernel,
        decode=decode, bk=bk, group=group, nk=nk, act_bits=act_bits,
        static_e=None if act_exponent is None else int(act_exponent),
        act=act, has_bias=bias is not None, exact=interpret,
    )
    in_specs = [
        # full activation row block: resident across the j and kk axes, so x
        # is read from HBM once per row tile, not once per (j, kk) step
        pl.BlockSpec((bm, k), lambda i, j, kk: (i, 0)),
        pl.BlockSpec((bk // words_per_k, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bk // group, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
    ]
    args = [x, packed, scale_m, jnp.asarray(scale_e, jnp.int32).reshape(1, 1)]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        args.append(bias.astype(jnp.float32).reshape(1, n))
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, 1), jnp.float32)],
        compiler_params=None if interpret else _FUSED_COMPILER_PARAMS,
        interpret=interpret,
    )(*args)
