"""Shared Pallas helpers: in-VMEM sub-8-bit decode + tiling math.

TPU adaptation notes (see DESIGN.md Sec. 2.1): weights live in HBM packed
2-bit (16/uint32) or 4-bit (8/uint32).  A weight tile is decoded once in
VMEM to int8 lanes and contracted on the MXU with int32 accumulation; the
per-cluster scale is applied to the int32 partial -- one multiply per
cluster, exactly the paper's arithmetic budget.
"""
from __future__ import annotations

import jax.numpy as jnp

TERNARY_PER_WORD = 16
INT4_PER_WORD = 8


def decode2_tile(words: jnp.ndarray, bk: int) -> jnp.ndarray:
    """(bk/16, bn) uint32 -> (bk, bn) int8 in {-1, 0, 1}."""
    lanes = []
    for i in range(TERNARY_PER_WORD):
        c = (words >> (2 * i)) & jnp.uint32(3)
        lanes.append((((c + 1) & 3).astype(jnp.int8) - 1))
    return jnp.stack(lanes, axis=1).reshape(bk, words.shape[-1])


def decode4_tile(words: jnp.ndarray, bk: int) -> jnp.ndarray:
    """(bk/8, bn) uint32 -> (bk, bn) int8 in [-8, 7]."""
    lanes = []
    for i in range(INT4_PER_WORD):
        c = ((words >> (4 * i)) & jnp.uint32(0xF)).astype(jnp.int8)
        lanes.append(jnp.where(c >= 8, c - 16, c))
    return jnp.stack(lanes, axis=1).reshape(bk, words.shape[-1])


def pick_block(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is <= want (block shape helper)."""
    b = min(dim, want)
    while dim % b:
        b -= 1
    return b
