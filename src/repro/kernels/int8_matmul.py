"""Pallas TPU kernel: int8 x int8 matmul with per-cluster DFP scales.

Used for the layers the policy pins to 8-bit (embedding/C1 analogue,
lm_head, MoE router).  int8 MXU contraction at 2x bf16 throughput, int32
accumulation, one scale multiply per cluster.  Both entry points wrap the
shared builders in ``kernels/_common`` (``packed_qmm_call`` /
``fused_qmm_call``) with the identity decode: raw int8 storage, the tile IS
the mantissas (words_per_k=1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._common import fused_qmm_call, packed_qmm_call


def _decode_raw(words: jnp.ndarray, bk: int) -> jnp.ndarray:
    return words  # raw int8 storage: the tile IS the mantissas


@functools.partial(
    jax.jit, static_argnames=("group", "block_m", "block_n", "block_k", "interpret")
)
def int8_matmul(
    x_q: jax.Array,  # int8 (M, K)
    w_q: jax.Array,  # int8 (K, N)
    scale_m: jax.Array,  # int8 (K/group, N)
    *,
    group: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    return packed_qmm_call(
        x_q, w_q, scale_m,
        decode=_decode_raw, words_per_k=1, group=group,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "group", "act", "act_bits", "act_exponent",
        "block_m", "block_n", "block_k", "interpret",
    ),
)
def int8_matmul_fused(
    x: jax.Array,  # f32/bf16 (M, K) RAW activations (quantized in-kernel)
    w_q: jax.Array,  # int8 (K, N)
    scale_m: jax.Array,  # int8 (K/group, N)
    scale_e: jax.Array,  # int32 scalar
    *,
    group: int,
    bias: jax.Array = None,
    act: str = None,
    act_bits: int = 8,
    act_exponent: int = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Whole dense site in one pallas_call: quantize prologue + int8 matmul
    + exp2/bias/activation epilogue (exponents applied in-kernel)."""
    return fused_qmm_call(
        x, w_q, scale_m, scale_e,
        decode=_decode_raw, words_per_k=1, n=w_q.shape[1],
        group=group, bias=bias, act=act, act_bits=act_bits,
        act_exponent=act_exponent, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret,
    )
