"""Pallas TPU kernel: int8 x int8 matmul with per-cluster DFP scales.

Used for the layers the policy pins to 8-bit (embedding/C1 analogue,
lm_head, MoE router).  int8 MXU contraction at 2x bf16 throughput, int32
accumulation, one scale multiply per cluster.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._common import fused_qmm_call

try:
    from jax.experimental.pallas import tpu as pltpu

    _COMPILER_PARAMS = pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary")
    )
except Exception:  # pragma: no cover
    _COMPILER_PARAMS = None


def _kernel(x_ref, w_ref, s_ref, out_ref, *, bk: int, group: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]
    w8 = w_ref[...]  # already int8 mantissas
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for s in range(bk // group):
        xs = jax.lax.slice_in_dim(x, s * group, (s + 1) * group, axis=1)
        ws = jax.lax.slice_in_dim(w8, s * group, (s + 1) * group, axis=0)
        part = jax.lax.dot_general(
            xs, ws, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )
        acc = acc + part.astype(jnp.float32) * s_ref[s, :].astype(jnp.float32)[None, :]
    out_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("group", "block_m", "block_n", "block_k", "interpret")
)
def int8_matmul(
    x_q: jax.Array,  # int8 (M, K)
    w_q: jax.Array,  # int8 (K, N)
    scale_m: jax.Array,  # int8 (K/group, N)
    *,
    group: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    m, k = x_q.shape
    n = w_q.shape[1]
    bm, bn = min(block_m, m), min(block_n, n)
    bk = min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bk % group == 0, (bk, group)

    kern = functools.partial(_kernel, bk=bk, group=group)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=None if interpret else _COMPILER_PARAMS,
        interpret=interpret,
    )(x_q, w_q, scale_m)


def _decode_raw(words: jnp.ndarray, bk: int) -> jnp.ndarray:
    return words  # raw int8 storage: the tile IS the mantissas


@functools.partial(
    jax.jit,
    static_argnames=(
        "group", "act", "act_bits", "act_exponent",
        "block_m", "block_n", "block_k", "interpret",
    ),
)
def int8_matmul_fused(
    x: jax.Array,  # f32/bf16 (M, K) RAW activations (quantized in-kernel)
    w_q: jax.Array,  # int8 (K, N)
    scale_m: jax.Array,  # int8 (K/group, N)
    scale_e: jax.Array,  # int32 scalar
    *,
    group: int,
    bias: jax.Array = None,
    act: str = None,
    act_bits: int = 8,
    act_exponent: int = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Whole dense site in one pallas_call: quantize prologue + int8 matmul
    + exp2/bias/activation epilogue (exponents applied in-kernel)."""
    return fused_qmm_call(
        x, w_q, scale_m, scale_e,
        decode=_decode_raw, words_per_k=1, n=w_q.shape[1],
        group=group, bias=bias, act=act, act_bits=act_bits,
        act_exponent=act_exponent, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret,
    )
