"""Pallas TPU kernel: unified flash attention over a packed quantized KV cache.

``flash_attend`` generalizes the PR-7 flash-decode kernel from S == 1 to
whole prefill chunks: a (B, S, Kh, G, hd) query block attends against the
full cache with grid (B, Kh, S/bq, T/bk), the KV axis innermost
("arbitrary").  Each (batch, kv-head, query-block) program revisits its
output tile across KV tiles carrying running (m, l, acc) online-softmax
statistics in VMEM scratch -- the (S, T) score plane never exists, and the
cache streams from HBM exactly once per chunk, *packed*:

  * kv_bf16  tiles load as bf16 and cast,
  * kv_int8  tiles load int8 mantissas + a (bk, 1) exponent column and
    dequantize in-VMEM via exact power-of-two scales (``dfp.exp2i``),
  * kv_mx    tiles load nibble-packed int4 mantissas (bk, hd/2) + one
    exponent per 32-token block (bk/32, 1), unpack and shift in-VMEM.

All G query heads of a KV group ride in one tile as bq*G rows, so GQA and
MHA (G == 1) share the layout.  Masking is positional per query row: the
chunk's traced ``q_start[b]`` anchors row r of query block qi at absolute
position q_start[b] + qi*bq + r//G, and a key column is live iff

    k_pos < valid[b]  (cache fill level -- ragged rows)
    k_pos <= q_pos    (causal, against the absolute chunk offset)
    q_pos - k_pos < window  (sliding-window layers; 2**30 = global)

Query rows are assumed CONTIGUOUS from ``q_start`` (position q_start + s
for chunk row s) -- exactly what ``transformer.prefill_chunk`` and the
decode step produce.  Fully-masked tiles still run (the grid is static)
but contribute zero through the -inf bias.

The XLA fold-the-scales path in ``models/attention.py::_attend_dense``
stays as the oracle; ``tests/test_flash_prefill.py`` holds the S > 1
parity matrix (formats x masking x head mapping x ragged starts) next to
the S == 1 matrix in ``tests/test_flash_decode.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import dfp
from repro.models.kv_cache import MX_KV_BLOCK

try:  # class name moved across JAX versions (see kernels/_common.py)
    from jax.experimental.pallas import tpu as pltpu

    _CP_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    _COMPILER_PARAMS = _CP_CLS(
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
    )
except Exception:  # pragma: no cover
    _COMPILER_PARAMS = None

NEG_INF = -1e30


def _dequant_tile(ref, eref, fmt: str, bk: int, hd: int) -> jax.Array:
    """One (bk, hd) f32 KV tile from packed VMEM blocks."""
    tile = ref[0, :, 0, :]
    if fmt == "kv_bf16":
        return tile.astype(jnp.float32)
    if fmt == "kv_int8":
        e = eref[0, :, 0, :]  # (bk, 1) int8
        return tile.astype(jnp.float32) * dfp.exp2i(e)
    # kv_mx: unpack nibble pairs along head_dim, one exponent per 32 tokens
    b32 = tile.astype(jnp.int32)  # (bk, hd//2) uint8 widened
    lo, hi = b32 & 0xF, (b32 >> 4) & 0xF
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    codes = jnp.stack([lo, hi], axis=-1).reshape(bk, hd).astype(jnp.float32)
    e = eref[0, :, 0, :]  # (bk // 32, 1) int8
    nb = bk // MX_KV_BLOCK
    e_tok = jnp.broadcast_to(
        e.reshape(nb, 1, 1), (nb, MX_KV_BLOCK, 1)
    ).reshape(bk, 1)
    return codes * dfp.exp2i(e_tok)


def _kernel(*refs, fmt, bq, bk, g, hd, scale):
    if fmt == "kv_bf16":
        (q_ref, k_ref, v_ref, qs_ref, vl_ref, win_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
        ke_ref = ve_ref = None
    else:
        (q_ref, k_ref, v_ref, ke_ref, ve_ref, qs_ref, vl_ref, win_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
    q_idx = pl.program_id(2)
    kv_idx = pl.program_id(3)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = bq * g  # all G heads of the group ride as interleaved rows
    q = q_ref[0, :, 0].reshape(rows, hd).astype(jnp.float32) * scale
    kf = _dequant_tile(k_ref, ke_ref, fmt, bk, hd)  # (bk, hd)
    s = jax.lax.dot_general(
        q, kf, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (rows, bk)

    k_pos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
    q_pos = qs_ref[0, 0] + q_idx * bq + row // g  # (rows, 1) absolute
    valid, win = vl_ref[0, 0], win_ref[0, 0]
    ok = (k_pos < valid) & (k_pos <= q_pos) & (q_pos - k_pos < win)
    s = jnp.where(ok, s, NEG_INF)  # (rows, bk)

    m_prev, l_prev = m_ref[...], l_ref[...]  # (rows, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    vf = _dequant_tile(v_ref, ve_ref, fmt, bk, hd)  # (bk, hd)
    pv = jax.lax.dot_general(
        p, vf, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kv_idx == pl.num_programs(3) - 1)
    def _finalize():
        o_ref[0, :, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).reshape(bq, g, hd).astype(o_ref.dtype)


def pick_kv_block(t: int, fmt: str, want: int = 128) -> int:
    """Largest divisor of T that is <= want; a 32-multiple for kv_mx."""
    if fmt == "kv_mx":
        nb = t // MX_KV_BLOCK
        b = min(nb, max(1, want // MX_KV_BLOCK))
        while nb % b:
            b -= 1
        return b * MX_KV_BLOCK
    b = min(t, want)
    while t % b:
        b -= 1
    return b


def pick_q_block(s: int, g: int, want: int = 64) -> int:
    """Largest divisor of S keeping bq*G query rows near ``want``.

    The kernel flattens a query block to bq*G rows (all G heads of the KV
    group), so the row budget -- not bq alone -- is what VMEM sees."""
    b = min(s, max(1, want // g))
    while s % b:
        b -= 1
    return b


def flash_attend(
    q: jax.Array,  # (B, S, Kh, G, hd) chunk queries, grouped heads
    k: jax.Array,  # (B, T, Kh, hd) | (B, T, Kh, hd//2) packed mantissas
    v: jax.Array,
    ke,  # None | (B, T, Kh, 1) | (B, T/32, Kh, 1) int8 exponents
    ve,
    q_start: jax.Array,  # (B, 1) int32 absolute position of chunk row 0
    valid: jax.Array,  # (B, 1) int32 cache fill level per batch row
    window: jax.Array,  # (1, 1) int32 sliding window (2**30 = global)
    *,
    fmt: str,
    block_q: int = 64,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns (B, S, Kh, G, hd) f32 attention output.

    Query row s of batch b sits at absolute position q_start[b] + s (the
    contiguous-chunk contract); masking is causal against that offset plus
    the fill level and sliding window.  S == 1 with q_start = q_pos is
    exactly the flash-decode special case."""
    b, s, kh, g, hd = q.shape
    t = k.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bq = pick_q_block(s, g, block_q)
    bk = pick_kv_block(t, fmt, block_k)
    scale = hd**-0.5

    q_spec = pl.BlockSpec(
        (1, bq, 1, g, hd), lambda bi, hi, qi, ji: (bi, qi, hi, 0, 0)
    )
    kv_spec = pl.BlockSpec(
        (1, bk, 1, k.shape[-1]), lambda bi, hi, qi, ji: (bi, ji, hi, 0)
    )
    in_specs = [q_spec, kv_spec, kv_spec]
    args = [q, k, v]
    if fmt != "kv_bf16":
        eb = bk if fmt == "kv_int8" else bk // MX_KV_BLOCK
        e_spec = pl.BlockSpec(
            (1, eb, 1, 1), lambda bi, hi, qi, ji: (bi, ji, hi, 0)
        )
        in_specs += [e_spec, e_spec]
        args += [ke, ve]
    scalar_spec = pl.BlockSpec((1, 1), lambda bi, hi, qi, ji: (bi, 0))
    bcast_spec = pl.BlockSpec((1, 1), lambda bi, hi, qi, ji: (0, 0))
    in_specs += [scalar_spec, scalar_spec, bcast_spec]
    args += [q_start, valid, window]

    kern = functools.partial(
        _kernel, fmt=fmt, bq=bq, bk=bk, g=g, hd=hd, scale=scale
    )
    return pl.pallas_call(
        kern,
        grid=(b, kh, s // bq, t // bk),
        in_specs=in_specs,
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((b, s, kh, g, hd), jnp.float32),
        scratch_shapes=[
            # running max / denom / accumulator survive the kv axis
            pltpu.VMEM((bq * g, 1), jnp.float32),
            pltpu.VMEM((bq * g, 1), jnp.float32),
            pltpu.VMEM((bq * g, hd), jnp.float32),
        ],
        compiler_params=None if interpret else _COMPILER_PARAMS,
        interpret=interpret,
    )(*args)
