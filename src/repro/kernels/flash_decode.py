"""Flash-decode over a packed quantized KV cache: the S == 1 special case.

The kernel itself lives in ``kernels/flash_prefill.py`` as the unified
``flash_attend`` (grid (B, Kh, S/bq, T/bk), online softmax, in-VMEM
dequant of the packed kv_bf16 / kv_int8 / kv_mx leaves); a decode step is
a one-row chunk whose start IS its query position.  This module keeps the
original decode-shaped entry point -- (B, Kh, G, hd) queries, no S axis --
so PR-7 call sites and the S == 1 parity matrix
(``tests/test_flash_decode.py``) are untouched.

Masking per batch row: k_pos < valid[b] (cache fill level), k_pos <=
q_pos[b] (causal), q_pos[b] - k_pos < window (sliding-window layers; pass
2**30 for global).  The XLA fold-the-scales path in
``models/attention.py::_attend_dense`` stays as the oracle.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_prefill import (  # noqa: F401  (re-exports)
    NEG_INF,
    _dequant_tile,
    flash_attend,
    pick_kv_block,
)


def flash_decode(
    q: jax.Array,  # (B, Kh, G, hd) queries, one token per batch row
    k: jax.Array,  # (B, T, Kh, hd) | (B, T, Kh, hd//2) packed mantissas
    v: jax.Array,
    ke,  # None | (B, T, Kh, 1) | (B, T/32, Kh, 1) int8 exponents
    ve,
    q_pos: jax.Array,  # (B, 1) int32 write position of the query token
    valid: jax.Array,  # (B, 1) int32 cache fill level per batch row
    window: jax.Array,  # (1, 1) int32 sliding window (2**30 = global)
    *,
    fmt: str,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns (B, Kh, G, hd) f32 attention output."""
    out = flash_attend(
        q[:, None], k, v, ke, ve, q_pos, valid, window,
        fmt=fmt, block_k=block_k, interpret=interpret,
    )
    return out[:, 0]
