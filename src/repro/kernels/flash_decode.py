"""Pallas TPU kernel: flash-decode over a packed quantized KV cache.

One decode step attends S == 1 queries (all G heads of a KV group at once)
against the whole cache.  Grid (B, Kh, T/bk) with the KV axis innermost
("arbitrary"): each (batch, kv-head) revisits its output tile across KV
tiles carrying running (m, l, acc) online-softmax statistics in VMEM
scratch -- the (G, T) score row never exists, and the cache streams from
HBM exactly once, *packed*:

  * kv_bf16  tiles load as bf16 and cast,
  * kv_int8  tiles load int8 mantissas + a (bk, 1) exponent column and
    dequantize in-VMEM via exact power-of-two scales (``dfp.exp2i``),
  * kv_mx    tiles load nibble-packed int4 mantissas (bk, hd/2) + one
    exponent per 32-token block (bk/32, 1), unpack and shift in-VMEM.

So attention joins the dense sites in the 1-HBM-pass club: bytes/tick is
the packed cache size (2x smaller for kv_int8, ~4x for kv_mx).

Masking is positional per batch row: k_pos < valid[b] (cache fill level),
k_pos <= q_pos[b] (causal), q_pos[b] - k_pos < window (sliding-window
layers; pass 2**30 for global).  Fully-masked tiles still run (the grid is
static) but contribute zero through the -inf bias.

The XLA fold-the-scales path in ``models/attention.py::_attend_dense``
stays as the oracle; ``tests/test_flash_decode.py`` holds the parity
matrix across formats x write modes x attention flavours.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import dfp
from repro.models.kv_cache import MX_KV_BLOCK

try:  # class name moved across JAX versions (see kernels/_common.py)
    from jax.experimental.pallas import tpu as pltpu

    _CP_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    _COMPILER_PARAMS = _CP_CLS(
        dimension_semantics=("parallel", "parallel", "arbitrary")
    )
except Exception:  # pragma: no cover
    _COMPILER_PARAMS = None

NEG_INF = -1e30


def _dequant_tile(ref, eref, fmt: str, bk: int, hd: int) -> jax.Array:
    """One (bk, hd) f32 KV tile from packed VMEM blocks."""
    tile = ref[0, :, 0, :]
    if fmt == "kv_bf16":
        return tile.astype(jnp.float32)
    if fmt == "kv_int8":
        e = eref[0, :, 0, :]  # (bk, 1) int8
        return tile.astype(jnp.float32) * dfp.exp2i(e)
    # kv_mx: unpack nibble pairs along head_dim, one exponent per 32 tokens
    b32 = tile.astype(jnp.int32)  # (bk, hd//2) uint8 widened
    lo, hi = b32 & 0xF, (b32 >> 4) & 0xF
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    codes = jnp.stack([lo, hi], axis=-1).reshape(bk, hd).astype(jnp.float32)
    e = eref[0, :, 0, :]  # (bk // 32, 1) int8
    nb = bk // MX_KV_BLOCK
    e_tok = jnp.broadcast_to(
        e.reshape(nb, 1, 1), (nb, MX_KV_BLOCK, 1)
    ).reshape(bk, 1)
    return codes * dfp.exp2i(e_tok)


def _kernel(*refs, fmt, bk, hd, scale):
    if fmt == "kv_bf16":
        (q_ref, k_ref, v_ref, qp_ref, vl_ref, win_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
        ke_ref = ve_ref = None
    else:
        (q_ref, k_ref, v_ref, ke_ref, ve_ref, qp_ref, vl_ref, win_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, hd)
    kf = _dequant_tile(k_ref, ke_ref, fmt, bk, hd)  # (bk, hd)
    s = jax.lax.dot_general(
        q, kf, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, bk)

    k_pos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    q_pos, valid, win = qp_ref[0, 0], vl_ref[0, 0], win_ref[0, 0]
    ok = (k_pos < valid) & (k_pos <= q_pos) & (q_pos - k_pos < win)
    s = jnp.where(ok, s, NEG_INF)  # (1, bk) mask broadcasts over G

    m_prev, l_prev = m_ref[...], l_ref[...]  # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    vf = _dequant_tile(v_ref, ve_ref, fmt, bk, hd)  # (bk, hd)
    pv = jax.lax.dot_general(
        p, vf, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kv_idx == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def pick_kv_block(t: int, fmt: str, want: int = 128) -> int:
    """Largest divisor of T that is <= want; a 32-multiple for kv_mx."""
    if fmt == "kv_mx":
        nb = t // MX_KV_BLOCK
        b = min(nb, max(1, want // MX_KV_BLOCK))
        while nb % b:
            b -= 1
        return b * MX_KV_BLOCK
    b = min(t, want)
    while t % b:
        b -= 1
    return b


def flash_decode(
    q: jax.Array,  # (B, Kh, G, hd) queries, one token per batch row
    k: jax.Array,  # (B, T, Kh, hd) | (B, T, Kh, hd//2) packed mantissas
    v: jax.Array,
    ke,  # None | (B, T, Kh, 1) | (B, T/32, Kh, 1) int8 exponents
    ve,
    q_pos: jax.Array,  # (B, 1) int32 write position of the query token
    valid: jax.Array,  # (B, 1) int32 cache fill level per batch row
    window: jax.Array,  # (1, 1) int32 sliding window (2**30 = global)
    *,
    fmt: str,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns (B, Kh, G, hd) f32 attention output."""
    b, kh, g, hd = q.shape
    t = k.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bk = pick_kv_block(t, fmt, block_k)
    scale = hd**-0.5

    kv_spec = pl.BlockSpec(
        (1, bk, 1, k.shape[-1]), lambda bi, hi, ji: (bi, ji, hi, 0)
    )
    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda bi, hi, ji: (bi, hi, 0, 0)),
        kv_spec,
        kv_spec,
    ]
    args = [q, k, v]
    if fmt != "kv_bf16":
        eb = bk if fmt == "kv_int8" else bk // MX_KV_BLOCK
        e_spec = pl.BlockSpec((1, eb, 1, 1), lambda bi, hi, ji: (bi, ji, hi, 0))
        in_specs += [e_spec, e_spec]
        args += [ke, ve]
    scalar_spec = pl.BlockSpec((1, 1), lambda bi, hi, ji: (bi, 0))
    bcast_spec = pl.BlockSpec((1, 1), lambda bi, hi, ji: (0, 0))
    in_specs += [scalar_spec, scalar_spec, bcast_spec]
    args += [q_pos, valid, window]

    kern = functools.partial(_kernel, fmt=fmt, bk=bk, hd=hd, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(b, kh, t // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, hi, ji: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd), jnp.float32),
        scratch_shapes=[
            # running max / denom / accumulator survive the kv axis
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        compiler_params=None if interpret else _COMPILER_PARAMS,
        interpret=interpret,
    )(*args)
