"""Pallas TPU kernel: int8 activations x packed 2-bit ternary weights.

Grid (M/bm, N/bn, K/bk); the k axis is an accumulation ("arbitrary") axis
revisiting the same (bm, bn) output tile.  Per k-step:

  1. fetch a (bk/16, bn) uint32 weight-word tile HBM->VMEM  (2 bits/weight:
     8x less HBM traffic than bf16 -- the TPU-native payoff of ternary),
  2. decode to int8 lanes in VMEM,
  3. for each of the bk/G clusters in the tile: one MXU int8 dot with int32
     accumulation over the G-element segment, then ONE multiply by the
     cluster's 8-bit scale mantissa (the paper's 1-multiply-per-N*K^2-accs),
  4. accumulate into the f32 output tile.

Shared exponents (weight scale_e + activation e) are powers of two applied
by the ops.py wrapper outside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._common import TERNARY_PER_WORD, decode2_tile, fused_qmm_call

try:  # TPU-specific scheduling hints; absent on CPU-only installs is fine
    from jax.experimental.pallas import tpu as pltpu

    _COMPILER_PARAMS = pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary")
    )
except Exception:  # pragma: no cover
    _COMPILER_PARAMS = None


def _kernel(x_ref, w_ref, s_ref, out_ref, *, bk: int, group: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w8 = decode2_tile(w_ref[...], bk)  # (bk, bn) int8 in {-1,0,1}
    x = x_ref[...]  # (bm, bk) int8
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for s in range(bk // group):
        xs = jax.lax.slice_in_dim(x, s * group, (s + 1) * group, axis=1)
        ws = jax.lax.slice_in_dim(w8, s * group, (s + 1) * group, axis=0)
        part = jax.lax.dot_general(
            xs, ws, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )
        sc = s_ref[s, :].astype(jnp.float32)  # one multiply per cluster
        acc = acc + part.astype(jnp.float32) * sc[None, :]
    out_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("group", "block_m", "block_n", "block_k", "interpret")
)
def ternary_matmul(
    x_q: jax.Array,  # int8 (M, K)
    packed: jax.Array,  # uint32 (K/16, N)
    scale_m: jax.Array,  # int8 (K/group, N)
    *,
    group: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    m, k = x_q.shape
    n = packed.shape[1]
    bm, bn = min(block_m, m), min(block_n, n)
    bk = min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bk % group == 0 and bk % TERNARY_PER_WORD == 0, (bk, group)

    kern = functools.partial(_kernel, bk=bk, group=group)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // TERNARY_PER_WORD, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=None if interpret else _COMPILER_PARAMS,
        interpret=interpret,
    )(x_q, packed, scale_m)


@functools.partial(
    jax.jit,
    static_argnames=(
        "group", "act", "act_bits", "act_exponent",
        "block_m", "block_n", "block_k", "interpret",
    ),
)
def ternary_matmul_fused(
    x: jax.Array,  # f32/bf16 (M, K) RAW activations (quantized in-kernel)
    packed: jax.Array,  # uint32 (K/16, N)
    scale_m: jax.Array,  # int8 (K/group, N)
    scale_e: jax.Array,  # int32 scalar
    *,
    group: int,
    bias: jax.Array = None,  # (N,) fused into the epilogue
    act: str = None,
    act_bits: int = 8,
    act_exponent: int = None,  # static DFP exponent; None -> per-row dynamic
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Whole dense site in one pallas_call: quantize prologue + ternary
    matmul + exp2/bias/activation epilogue (exponents applied in-kernel)."""
    return fused_qmm_call(
        x, packed, scale_m, scale_e,
        decode=decode2_tile, words_per_k=TERNARY_PER_WORD, n=packed.shape[1],
        group=group, bias=bias, act=act, act_bits=act_bits,
        act_exponent=act_exponent, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret,
    )
