"""Pallas TPU kernel: int8 activations x packed 2-bit ternary weights.

Grid (M/bm, N/bn, K/bk); the k axis is an accumulation ("arbitrary") axis
revisiting the same (bm, bn) output tile.  Per k-step:

  1. fetch a (bk/16, bn) uint32 weight-word tile HBM->VMEM  (2 bits/weight:
     8x less HBM traffic than bf16 -- the TPU-native payoff of ternary),
  2. decode to int8 lanes in VMEM,
  3. for each of the bk/G clusters in the tile: one MXU int8 dot with int32
     accumulation over the G-element segment, then ONE multiply by the
     cluster's 8-bit scale mantissa (the paper's 1-multiply-per-N*K^2-accs),
  4. accumulate into the f32 output tile.

Shared exponents (weight scale_e + activation e) are powers of two applied
by the ops.py wrapper outside the kernel.  Both entry points wrap the shared
builders in ``kernels/_common`` (``packed_qmm_call`` / ``fused_qmm_call``):
the scaffolding above is format-independent, only the 2-bit tile decode is
ternary's own.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels._common import (
    TERNARY_PER_WORD,
    decode2_tile,
    fused_qmm_call,
    packed_qmm_call,
)


@functools.partial(
    jax.jit, static_argnames=("group", "block_m", "block_n", "block_k", "interpret")
)
def ternary_matmul(
    x_q: jax.Array,  # int8 (M, K)
    packed: jax.Array,  # uint32 (K/16, N)
    scale_m: jax.Array,  # int8 (K/group, N)
    *,
    group: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    return packed_qmm_call(
        x_q, packed, scale_m,
        decode=decode2_tile, words_per_k=TERNARY_PER_WORD, group=group,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "group", "act", "act_bits", "act_exponent",
        "block_m", "block_n", "block_k", "interpret",
    ),
)
def ternary_matmul_fused(
    x: jax.Array,  # f32/bf16 (M, K) RAW activations (quantized in-kernel)
    packed: jax.Array,  # uint32 (K/16, N)
    scale_m: jax.Array,  # int8 (K/group, N)
    scale_e: jax.Array,  # int32 scalar
    *,
    group: int,
    bias: jax.Array = None,  # (N,) fused into the epilogue
    act: str = None,
    act_bits: int = 8,
    act_exponent: int = None,  # static DFP exponent; None -> per-row dynamic
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Whole dense site in one pallas_call: quantize prologue + ternary
    matmul + exp2/bias/activation epilogue (exponents applied in-kernel)."""
    return fused_qmm_call(
        x, packed, scale_m, scale_e,
        decode=decode2_tile, words_per_k=TERNARY_PER_WORD, n=packed.shape[1],
        group=group, bias=bias, act=act, act_bits=act_bits,
        act_exponent=act_exponent, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret,
    )
