"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel ships with a jit'd dispatcher (ops.py) and a pure-jnp oracle
(ref.py); all kernels are validated bit-exactly (integer paths) or to float
tolerance (flash attention) in interpret mode on CPU.
"""
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.int4_matmul import int4_matmul, int4_matmul_fused
from repro.kernels.int8_matmul import int8_matmul, int8_matmul_fused
from repro.kernels.mx_matmul import mx_matmul, mx_matmul_fused
from repro.kernels.nf4_matmul import nf4_matmul, nf4_matmul_fused
from repro.kernels.ops import qmatmul, quantize_activations
from repro.kernels.quantize import quantize_rows
from repro.kernels.ternary_matmul import ternary_matmul, ternary_matmul_fused
