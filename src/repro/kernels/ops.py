"""Compatibility shim: quantized-op dispatch now lives in ``repro.quant``.

``qmatmul`` routes through the backend registry
(``repro.quant.backends``): ``pallas`` / ``xla`` / ``xla_int8`` / ``ref``
are registered strategies sharing one activation-quantization prologue, and
the Pallas path picks its kernel from the format registry -- there is no
backend string ladder or per-bits if-chain here anymore.  New backends plug
in via ``repro.quant.register_backend``.

Migration note (old -> new):

    from repro.kernels.ops import qmatmul, quantize_activations
        -> from repro.quant import qmatmul, quantize_activations

Whole-site calls (prologue + matmul + epilogue fused on pallas) should use
``repro.quant.qdense`` directly.
"""
from __future__ import annotations

from repro.quant.backends import (  # noqa: F401
    qmatmul,
    qmatmul_jit,
    quantize_activations,
)
