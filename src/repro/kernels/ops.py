"""Public quantized-op API: backend dispatch over Pallas / XLA paths.

``qmatmul(x, qt)`` is the single entry point models use for PTQ inference:

  * backend="pallas"   : the real integer pipeline (TPU target; runs in
                         interpret mode on CPU so tests validate the exact
                         kernel semantics).
  * backend="xla"      : dequantize-weights -> bf16 dot.  Mathematically
                         identical up to f32 rounding; this is what the
                         distributed (pjit) graph lowers for the dry-run,
                         where collectives/sharding are the object of study.
  * backend="auto"     : pallas-interpret off-TPU for small shapes, xla
                         otherwise.

Activations are dynamically quantized per row (one DFP exponent per token),
matching calibration.dynamic_quantize_act / the fused Pallas quantize kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import calibration, dfp
from repro.core.quantizer import QTensor, dequantize_weights
from repro.kernels import ref
from repro.kernels.int4_matmul import int4_matmul
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.quantize import quantize_rows
from repro.kernels.ternary_matmul import ternary_matmul


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantize_activations(
    x: jax.Array, bits: int = 8, use_pallas: Optional[bool] = None
):
    """Per-row dynamic DFP quantization; pallas kernel or jnp fallback."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or not _on_tpu():
        # interpret-mode pallas on CPU is exact but slow; only use it when
        # explicitly requested. Default CPU path: the jnp oracle.
        if use_pallas:
            return quantize_rows(x, bits=bits, interpret=not _on_tpu())
    return ref.quantize_rows_ref(x, bits)


def qmatmul(
    x: jax.Array,
    qt: QTensor,
    *,
    backend: str = "auto",
    act_bits: int = 8,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
) -> jax.Array:
    """x [..., K] (float) x QTensor (K, N) -> [..., N] f32.

    Full integer pipeline: per-row 8-bit DFP activations, sub-8-bit weights,
    int32 cluster accumulation, one scale multiply per cluster.
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    xm = x.reshape(-1, k)
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "xla"

    if backend == "xla":
        # float-side equivalent: fake-quantized activations x dequant weights
        # (f32 dot output; a bf16-output variant was tried as Perf iteration
        # B3 and had NO effect on collective bytes -- the TP reductions in
        # the MoE cells come from the combine scatter-add, see moe.py B4)
        xq, xe = ref.quantize_rows_ref(xm, act_bits)
        xf = dfp.dequantize(xq, xe).astype(jnp.bfloat16)
        w = dequantize_weights(qt).astype(jnp.bfloat16)
        out = jax.lax.dot_general(
            xf, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return out.reshape(*lead, qt.n)

    if backend == "xla_int8":
        # integer pipeline without Pallas: per-group batched int8 dots with
        # int32 accumulation; weights materialize as int8 codes (1 B/elem)
        # instead of a scaled bf16 copy (2 B/elem) -- halves the decode-phase
        # weight stream and uses the 2x int8 MXU path on TPU.
        from repro.core.quantizer import decode_codes

        xq, xe = ref.quantize_rows_ref(xm, act_bits)
        g = qt.group_size
        m = xq.shape[0]
        kg = qt.k // g
        xg = jnp.moveaxis(xq.reshape(m, kg, g), 1, 0)  # (Kg, M, G) int8
        wg = decode_codes(qt).reshape(kg, g, qt.n)  # (Kg, G, N) int8
        part = jax.lax.dot_general(
            xg, wg, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32,
        )  # (Kg, M, N) int32
        scaled = part.astype(jnp.float32) * qt.scale_m.astype(jnp.float32)[:, None, :]
        out = scaled.sum(axis=0)
        exp = qt.scale_e.astype(jnp.float32) + xe.astype(jnp.float32)
        out = out * jnp.exp2(exp)
        return out.reshape(*lead, qt.n)

    if backend == "ref":
        xq, xe = ref.quantize_rows_ref(xm, act_bits)
        return ref.qmatmul_ref(xq, xe, qt).reshape(*lead, qt.n)

    if backend == "pallas":
        interpret = not _on_tpu()
        xq, xe = ref.quantize_rows_ref(xm, act_bits)
        m = xq.shape[0]
        # pad rows to a tile multiple (serving batches are ragged)
        bm = min(block_m, max(8, m))
        pad = (-m) % bm
        if pad:
            xq = jnp.pad(xq, ((0, pad), (0, 0)))
        kwargs = dict(
            group=qt.group_size,
            block_m=bm,
            block_n=block_n,
            block_k=block_k,
            interpret=interpret,
        )
        if qt.bits == 2:
            out = ternary_matmul(xq, qt.packed, qt.scale_m, **kwargs)
        elif qt.bits == 4:
            out = int4_matmul(xq, qt.packed, qt.scale_m, **kwargs)
        elif qt.bits == 8:
            out = int8_matmul(xq, qt.packed, qt.scale_m, **kwargs)
        else:
            raise ValueError(f"bits={qt.bits}")
        out = out[:m] if pad else out
        exp = qt.scale_e.astype(jnp.float32) + xe.astype(jnp.float32)
        out = out * jnp.exp2(exp)
        return out.reshape(*lead, qt.n)

    raise ValueError(f"unknown backend {backend!r}")


@functools.partial(jax.jit, static_argnames=("backend", "act_bits"))
def qmatmul_jit(x, qt, backend="auto", act_bits=8):
    return qmatmul(x, qt, backend=backend, act_bits=act_bits)
