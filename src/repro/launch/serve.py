"""Production serving launcher (PTQ integer pipeline + continuous batching).

Two boot modes:

  * quantize-on-boot: build the model, quantize through the unified
    ``repro.quant`` API (optional calibration batches profile static
    per-site activation exponents), and optionally persist the result as a
    packed artifact (``--save-artifact DIR``).
  * cold start (``--artifact DIR``): load a previously saved artifact --
    packed QTensors + compiled plan + serialized ArchConfig -- and serve
    directly.  No fp32 weights are materialized and no calibration runs;
    the 4-16x-smaller artifact is the unit of deployment.

With ``--mesh dp=2,ep=2`` the whole pipeline runs sharded: the artifact's
per-host shard files assemble straight onto their owning devices, the
engine's decode step runs under NamedSharding, and MoE expert sites
dispatch through the shard_map expert-parallel fused qdense when the plan
carries the "pallas_ep" backend.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --bits 2 --group-size 16 --requests 8 [--calibrate 4] \
      [--save-artifact DIR] [--plan-json p.json]
  PYTHONPATH=src python -m repro.launch.serve --artifact DIR --requests 8 \
      [--mesh dp=2,ep=2]

Serving runs the staged engine by default (prefill / insert / generate
stages, chunked prefill, SLO percentiles in the run report); ``--engine
lockstep`` selects the shared-tick oracle, ``--prefill-chunk`` and
``--policy {decode,prefill}`` tune the staged scheduler.  Fault tolerance:
``--deadline-ms / --max-queue / --ttft-slo-ms`` gate admission,
``--tpot-slo-ms`` arms overload degradation, ``--retries`` budgets
quarantine retries, and ``--chaos "rate=0.01,kinds=nan_logits|kv_corrupt"``
injects seeded faults to demonstrate containment.  See docs/SERVING.md.
"""
from __future__ import annotations

import argparse
import sys
import time

# --mesh on a host without enough devices (CPU smoke runs): force the host
# platform device count BEFORE the first jax initialization -- mirrors
# dryrun.py, but only when the operator did not set XLA_FLAGS themselves.
from repro.launch.mesh import parse_mesh_spec, preinit_mesh_flag

preinit_mesh_flag(sys.argv)

import jax
import numpy as np

from repro import configs
from repro.configs.base import QuantConfig
from repro.models import (
    build_model,
    load_servable,
    make_smoke_batch,
    quantize_and_plan,
    save_servable,
)
from repro.serving import (
    AdmissionConfig,
    FaultInjector,
    HealthConfig,
    Request,
    SamplerConfig,
    SchedulerConfig,
    ServingEngine,
    StagedEngine,
)


def tree_mb(tree) -> float:
    return sum(np.asarray(l).nbytes for l in jax.tree.leaves(tree)) / 1e6


def boot_from_artifact(artifact_dir: str, mesh=None):
    """Cold start: (api, qparams, plan) from a packed on-disk artifact."""
    t0 = time.time()
    api, qparams, art = load_servable(artifact_dir, mesh=mesh)
    plan = art.plan
    plan_str = (
        f"plan: {len(plan.site_paths)} sites, "
        f"{len(plan.act_exponents)} calibrated"
        if plan is not None else "plan: none (unquantized artifact)"
    )
    mesh_str = (
        "" if mesh is None
        else f" onto mesh {dict(mesh.shape)} (per-host shards assembled)"
    )
    print(
        f"arch={api.cfg.name} cold-started from {art.path} in "
        f"{time.time() - t0:.2f}s: {tree_mb(qparams):.1f} MB packed, "
        f"{plan_str} (fp32 never materialized){mesh_str}"
    )
    return api, qparams, plan


def boot_quantize(args, mesh=None):
    """Quantize-on-boot: init fp params, PTQ (optionally calibrated)."""
    qc = QuantConfig(w_bits=args.bits, group_size=args.group_size,
                     mode="ptq", backend=args.backend,
                     fmt=getattr(args, "fmt", None))
    cfg = (configs.get_smoke if args.smoke else configs.get_config)(args.arch, qc)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    calib = None
    if args.calibrate:
        calib = [
            make_smoke_batch(jax.random.PRNGKey(100 + i), cfg, batch=2, seq=16)
            for i in range(args.calibrate)
        ]
    qparams, plan, api = quantize_and_plan(api, params, calib_batches=calib)
    fp_mb, q_mb = tree_mb(params), tree_mb(qparams)
    print(f"arch={cfg.name} weights {fp_mb:.1f} MB -> {q_mb:.1f} MB "
          f"({fp_mb / q_mb:.1f}x)  plan: {len(plan.site_paths)} sites, "
          f"{len(plan.act_exponents)} calibrated")
    if args.save_artifact:
        out = save_servable(args.save_artifact, api, qparams, plan, mesh=mesh)
        shard_str = " (per-host shards)" if mesh is not None else ""
        print(f"saved packed artifact to {out}{shard_str} "
              f"(serve it with --artifact {args.save_artifact})")
    if args.plan_json:
        with open(args.plan_json, "w") as f:
            f.write(plan.to_json())
        print(f"wrote QuantPlan to {args.plan_json}")
    return api, qparams, plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=configs.ARCH_IDS)
    ap.add_argument("--artifact", default=None, metavar="DIR",
                    help="cold-start from a packed quantized artifact "
                         "(replaces --arch/--calibrate: no fp32, no requant)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--bits", type=int, default=2, choices=[2, 4, 8])
    ap.add_argument("--fmt", default=None, metavar="NAME",
                    help="registered weight format by name (e.g. nf4, mx); "
                         "overrides the --bits ladder for default sites")
    ap.add_argument("--group-size", type=int, default=16)
    ap.add_argument("--kv-fmt", default=None, metavar="NAME",
                    choices=["kv_bf16", "kv_int8", "kv_mx"],
                    help="registered KV-cache format (models/kv_cache.py); "
                         "overrides the config (and its kv_bits back-compat)")
    ap.add_argument("--flash-decode", action="store_true",
                    help="route single-token decode through the fused "
                         "Pallas flash kernel (reads the packed cache; "
                         "interpreted off-TPU); says nothing about "
                         "prefill -- see --flash-prefill")
    ap.add_argument("--flash-prefill", action="store_true",
                    help="route chunked-prefill cache attends (and the "
                         "in-chunk tail) through the fused Pallas flash "
                         "kernel -- one pass over the packed cache per "
                         "chunk, which is what moves TTFT; independent "
                         "of --flash-decode")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent jax compilation cache: boot-time "
                         "decode/prefill compiles become disk reads on "
                         "the second boot (JAX_COMPILATION_CACHE_DIR is "
                         "honored when the flag is absent)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--engine", default="staged",
                    choices=["lockstep", "staged"],
                    help="staged (default): prefill/insert/generate stages "
                         "with chunked prefill; lockstep: the shared-tick "
                         "oracle (prefill and decode in one graph)")
    ap.add_argument("--prefill-chunk", type=int, default=32, metavar="N",
                    help="staged engine: max prompt tokens one prefill "
                         "dispatch may consume")
    ap.add_argument("--policy", default="decode",
                    choices=["decode", "prefill"],
                    help="staged engine stage arbitration: decode-priority "
                         "(inter-token latency) vs prefill-priority (TTFT)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--calibrate", type=int, default=0, metavar="N",
                    help="profile N batches for static activation exponents")
    ap.add_argument("--save-artifact", default=None, metavar="DIR",
                    help="persist the quantized model as a packed artifact")
    ap.add_argument("--plan-json", default=None,
                    help="write the compiled QuantPlan to this path")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="serve sharded, e.g. 'dp=2,ep=2' (dp->data, "
                         "ep/tp->model); cold starts assemble per-host "
                         "shard files straight onto their devices")
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "xla_int8", "pallas", "pallas_ep",
                             "ref", "auto"],
                    help="qmatmul backend the compiled plan carries "
                         "(pallas_ep routes MoE expert sites through the "
                         "shard_map fused path under --mesh)")
    # fault tolerance: deadlines, load shedding, overload SLOs, chaos
    ap.add_argument("--deadline-ms", type=float, default=None, metavar="MS",
                    help="default per-request deadline; past it a request "
                         "is expired wherever it is (queued or in flight)")
    ap.add_argument("--max-queue", type=int, default=None, metavar="N",
                    help="shed submissions once the queue holds N requests")
    ap.add_argument("--ttft-slo-ms", type=float, default=None, metavar="MS",
                    help="shed submissions whose estimated TTFT exceeds MS")
    ap.add_argument("--tpot-slo-ms", type=float, default=None, metavar="MS",
                    help="enter overload mode (smaller prefill chunks, "
                         "decode-priority) when recent TPOT p95 exceeds MS")
    ap.add_argument("--retries", type=int, default=1, metavar="N",
                    help="retry budget for fault-quarantined requests "
                         "(re-queued with exponential backoff)")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="inject faults, e.g. 'rate=0.01,kinds=nan_logits|"
                         "kv_corrupt|stall_tick,seed=0' -- seeded and "
                         "deterministic; see repro/serving/faults.py")
    args = ap.parse_args()
    if bool(args.artifact) == bool(args.arch):
        ap.error("exactly one of --arch or --artifact is required")

    from repro.launch.mesh import enable_compile_cache

    cache_dir = enable_compile_cache(args.compile_cache)
    if cache_dir:
        print(f"compile cache: {cache_dir} (persistent; cold-start "
              "compiles replay from disk)")
    mesh = parse_mesh_spec(args.mesh) if args.mesh else None
    if args.artifact:
        api, qparams, plan = boot_from_artifact(args.artifact, mesh=mesh)
    else:
        api, qparams, plan = boot_quantize(args, mesh=mesh)
    if args.kv_fmt or args.flash_decode or args.flash_prefill:
        # rebind the api closures to the overridden cache config; weights
        # and the compiled plan are untouched (the KV format is a pure
        # serving-time choice).  --flash-decode and --flash-prefill are
        # INDEPENDENT: one gates S == 1 ticks, the other chunked-prefill
        # cache attends -- neither implies the other.
        import dataclasses

        cfg2 = dataclasses.replace(
            api.cfg,
            kv_fmt=args.kv_fmt or api.cfg.kv_fmt,
            flash_decode=args.flash_decode or api.cfg.flash_decode,
            flash_prefill=args.flash_prefill or api.cfg.flash_prefill,
        )
        api = build_model(cfg2, api.ctx)
    from repro.models import kv_cache as kv_fmt_lib

    # the startup banner always states both flash knobs: "on for decode,
    # off for prefill" is a valid -- and previously invisible -- state
    print(f"kv cache: fmt={kv_fmt_lib.resolve_kv_fmt(api.cfg)} "
          f"flash_decode={api.cfg.flash_decode} "
          f"flash_prefill={api.cfg.flash_prefill}")
    cfg = api.cfg

    faults = FaultInjector.from_spec(args.chaos) if args.chaos else None
    if faults is not None:
        print(f"chaos: rate={faults.rate} kinds={'|'.join(faults.kinds)}")
    eng_kw = dict(n_slots=args.slots, max_len=args.max_len,
                  sampler=SamplerConfig(temperature=args.temperature),
                  mesh=mesh,
                  admission=AdmissionConfig(
                      max_queue=args.max_queue,
                      ttft_slo_ms=args.ttft_slo_ms,
                      deadline_ms=args.deadline_ms),
                  health=HealthConfig(overload_tpot_ms=args.tpot_slo_ms),
                  faults=faults)
    if args.engine == "staged":
        eng = StagedEngine(api, qparams, sched=SchedulerConfig(
            prefill_chunk=args.prefill_chunk, policy=args.policy), **eng_kw)
        print(f"engine=staged policy={args.policy} "
              f"prefill_chunk={args.prefill_chunk}")
    else:
        eng = ServingEngine(api, qparams, **eng_kw)
        print("engine=lockstep (shared-tick oracle)")
    rng = np.random.default_rng(0)
    not_admitted = []
    for i in range(args.requests):
        r = eng.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab, 6).tolist(),
            max_new_tokens=8, max_retries=args.retries,
        ))
        if r.status != "queued":
            not_admitted.append(r)
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    finished = [r for r in done if r.status == "finished"]
    toks = sum(len(r.output) for r in finished)
    print(f"{len(finished)} finished / {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s)")
    health = eng.stats()["health"]
    ev = health["events"]
    if not_admitted or any(ev[k] for k in
                           ("expired", "failed", "quarantined", "retried")):
        print(f"  fault tolerance: shed={ev['shed']} rejected={ev['rejected']} "
              f"expired={ev['expired']} quarantined={ev['quarantined']} "
              f"retried={ev['retried']} failed={ev['failed']}")
        for r in not_admitted[:4]:
            print(f"    req {r.uid} {r.status}: {r.reason}")
    print(f"  ticks={health['ticks']} slow={health['slow_ticks']} "
          f"hung={health['hung_ticks']} "
          f"tick_ewma={health['tick_ms_ewma']:.1f}ms "
          f"overload_entered={health['overload_entered']}")
    if health["faults"]:
        print(f"  chaos injected: {health['faults']}")
    left = eng.leftover()
    if left["in_flight"] or left["queued"]:
        print(f"UNFINISHED: {len(left['in_flight'])} in flight, "
              f"{len(left['queued'])} queued (tick budget expired; "
              "drain() returns them)")
    lat = eng.stats()["latency"]
    for name in ("queue_wait", "ttft", "tpot"):
        p = lat[name]
        if p is not None:
            print(f"  {name:10s} p50={p['p50'] * 1e3:7.1f}ms "
                  f"p95={p['p95'] * 1e3:7.1f}ms p99={p['p99'] * 1e3:7.1f}ms "
                  f"(n={p['n']})")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.output}")


if __name__ == "__main__":
    main()
