"""Production serving launcher (PTQ integer pipeline + continuous batching).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --bits 2 --group-size 16 --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.configs.base import QuantConfig
from repro.models import build_model, quantize_model_params
from repro.serving import Request, SamplerConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--bits", type=int, default=2, choices=[2, 4, 8])
    ap.add_argument("--group-size", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    qc = QuantConfig(w_bits=args.bits, group_size=args.group_size,
                     mode="ptq", backend="xla")
    cfg = (configs.get_smoke if args.smoke else configs.get_config)(args.arch, qc)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    qparams = quantize_model_params(params, api.ctx.policy)
    fp_b = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params))
    q_b = sum(np.asarray(l).nbytes for l in jax.tree.leaves(qparams))
    print(f"arch={cfg.name} weights {fp_b / 1e6:.1f} MB -> {q_b / 1e6:.1f} MB "
          f"({fp_b / q_b:.1f}x)")

    eng = ServingEngine(api, qparams, n_slots=args.slots, max_len=args.max_len,
                        sampler=SamplerConfig(temperature=args.temperature))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab, 6).tolist(),
            max_new_tokens=8,
        ))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"{len(done)} requests / {toks} tokens in {dt:.1f}s ({toks / dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.output}")


if __name__ == "__main__":
    main()
