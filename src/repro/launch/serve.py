"""Production serving launcher (PTQ integer pipeline + continuous batching).

Params are quantized through the unified ``repro.quant`` API: the precision
policy compiles into a serializable ``QuantPlan``, optional calibration
batches profile static per-site activation exponents (paper's profiled DFP
mode), and the engine serves from the plan-bound model view.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --bits 2 --group-size 16 --requests 8 [--calibrate 4] [--plan-json p.json]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.configs.base import QuantConfig
from repro.models import build_model, make_smoke_batch, quantize_and_plan
from repro.serving import Request, SamplerConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--bits", type=int, default=2, choices=[2, 4, 8])
    ap.add_argument("--group-size", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--calibrate", type=int, default=0, metavar="N",
                    help="profile N batches for static activation exponents")
    ap.add_argument("--plan-json", default=None,
                    help="write the compiled QuantPlan to this path")
    args = ap.parse_args()

    qc = QuantConfig(w_bits=args.bits, group_size=args.group_size,
                     mode="ptq", backend="xla")
    cfg = (configs.get_smoke if args.smoke else configs.get_config)(args.arch, qc)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    calib = None
    if args.calibrate:
        calib = [
            make_smoke_batch(jax.random.PRNGKey(100 + i), cfg, batch=2, seq=16)
            for i in range(args.calibrate)
        ]
    qparams, plan, api = quantize_and_plan(api, params, calib_batches=calib)
    fp_b = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params))
    q_b = sum(np.asarray(l).nbytes for l in jax.tree.leaves(qparams))
    print(f"arch={cfg.name} weights {fp_b / 1e6:.1f} MB -> {q_b / 1e6:.1f} MB "
          f"({fp_b / q_b:.1f}x)  plan: {len(plan.site_paths)} sites, "
          f"{len(plan.act_exponents)} calibrated")
    if args.plan_json:
        with open(args.plan_json, "w") as f:
            f.write(plan.to_json())
        print(f"wrote QuantPlan to {args.plan_json}")

    eng = ServingEngine(api, qparams, n_slots=args.slots, max_len=args.max_len,
                        sampler=SamplerConfig(temperature=args.temperature))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab, 6).tolist(),
            max_new_tokens=8,
        ))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"{len(done)} requests / {toks} tokens in {dt:.1f}s ({toks / dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.output}")


if __name__ == "__main__":
    main()
