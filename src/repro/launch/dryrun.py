import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and record memory/cost/roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]

Train cells lower a full train_step (fwd+bwd+AdamW w/ 8-bit DFP moments) in
the paper's QAT mode; prefill/decode cells lower the PTQ integer-pipeline
serve step with QTensor weights.  No arrays are allocated: params, optimizer
state, caches and batches are ShapeDtypeStructs; the 512 placeholder host
devices exist only so jax.make_mesh can build the 2x16x16 mesh.
"""
import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import QuantConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, input_specs
from repro.quant import quantize_params
from repro.parallel import sharding
from repro.roofline import analysis
from repro.training import OptConfig, init_state, make_train_step
from repro.training.trainer import TrainConfig


def _shaped(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def default_microbatches(arch: str, shape: ShapeConfig) -> int:
    """Gradient-accumulation factor for the train cells: XXL archs split the
    mandated global batch so per-device activations fit 16 GB HBM."""
    if shape.kind != "train":
        return 1
    big = {"grok-1-314b": 8, "arctic-480b": 16, "qwen1.5-110b": 4,
           "qwen2-vl-72b": 4, "qwen3-8b": 2, "gemma3-12b": 2,
           "phi4-mini-3.8b": 2, "zamba2-7b": 2, "falcon-mamba-7b": 2}
    return big.get(arch, 1)


def build_cell(
    arch: str,
    shape: ShapeConfig,
    mesh,
    quant_mode: str,
    w_bits: int,
    group_size: int,
    seq_shard: bool,
    act_dtype: str = "bfloat16",
    microbatches: int = 1,
    kv_bits: int = 16,
    backend: str = "xla",
    accum_dtype: str = "float32",
    kv_fmt: Optional[str] = None,
):
    """Returns (jitted_fn, example_args_as_specs)."""
    qc = QuantConfig(w_bits=w_bits, group_size=group_size, mode=quant_mode, backend=backend)
    cfg = configs.get_config(arch, qc)
    cfg = dataclasses.replace(cfg, dtype=act_dtype, kv_bits=kv_bits, kv_fmt=kv_fmt)
    api = build_model(cfg)
    specs, kind = input_specs(cfg, shape)
    params_shapes = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    if api.ctx.policy is not None:
        # compile the policy once against the (abstract) param tree; every
        # consumer below -- including the lowered QAT/PTQ graphs -- resolves
        # precision through the static plan table
        plan = api.ctx.policy.compile(
            params_shapes, mode=quant_mode, backend=backend
        )
        api = api.with_plan(plan)
    if quant_mode == "ptq":
        params_shapes = jax.eval_shape(
            lambda p: quantize_params(p, api.ctx.plan), params_shapes
        )
    mode = "train" if kind == "train" else "serve"
    p_sh = sharding.param_shardings(params_shapes, mesh, mode)
    if seq_shard:
        sharding.set_activation_mesh(mesh)
    else:
        sharding.set_activation_mesh(None)

    if kind == "train":
        ocfg = OptConfig(state_bits=8)
        opt_shapes = jax.eval_shape(lambda p: init_state(p, ocfg), params_shapes)
        o_sh = sharding.opt_shardings(opt_shapes, mesh, mode)
        b_sh = sharding.batch_shardings(specs, mesh)
        step = make_train_step(
            api.train_loss,
            TrainConfig(opt=ocfg, microbatches=microbatches, accum_dtype=accum_dtype),
        )
        fn = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            donate_argnums=(0, 1),
        )
        args = (params_shapes, opt_shapes, specs)
        return fn, args

    if kind == "prefill":
        cache_shapes = jax.eval_shape(lambda: api.init_cache(shape.global_batch, shape.seq_len))
        c_sh = sharding.cache_shardings(cache_shapes, mesh)
        b_sh = sharding.batch_shardings(specs, mesh)
        if api.prefill is None:  # SSM/hybrid: prefill == forward (state replay)
            fn = jax.jit(api.forward, in_shardings=(p_sh, b_sh))
            return fn, (params_shapes, specs)
        fn = jax.jit(api.prefill, in_shardings=(p_sh, b_sh, c_sh), donate_argnums=(2,))
        return fn, (params_shapes, specs, cache_shapes)

    # decode: one token against a seq_len cache
    cache_shapes = jax.eval_shape(lambda: api.init_cache(shape.global_batch, shape.seq_len))
    c_sh = sharding.cache_shardings(cache_shapes, mesh)
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tok_sh = sharding.batch_shardings({"t": tok}, mesh)["t"]
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    from jax.sharding import NamedSharding, PartitionSpec as P

    fn = jax.jit(
        api.decode,
        in_shardings=(p_sh, tok_sh, NamedSharding(mesh, P()), c_sh),
        donate_argnums=(3,),
    )
    return fn, (params_shapes, tok, pos, cache_shapes)


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    quant_mode: Optional[str] = None,
    w_bits: int = 2,
    group_size: int = 64,
    seq_shard: bool = True,
    verbose: bool = True,
    microbatches: Optional[int] = None,
    kv_bits: int = 16,
    backend: str = "xla",
    accum_dtype: str = "float32",
    kv_fmt: Optional[str] = None,
) -> Dict[str, Any]:
    shape = configs.get_shape(shape_name)
    cfg = configs.get_config(arch)
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return {
            "arch": arch, "shape": shape_name, "status": "skipped",
            "reason": "pure full-attention arch (see DESIGN.md)",
        }
    if quant_mode is None:
        quant_mode = "qat" if shape.kind == "train" else "ptq"
    if microbatches is None:
        microbatches = default_microbatches(arch, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with mesh:
            fn, args = build_cell(
                arch, shape, mesh, quant_mode, w_bits, group_size, seq_shard,
                microbatches=microbatches, kv_bits=kv_bits, backend=backend,
                accum_dtype=accum_dtype, kv_fmt=kv_fmt,
            )
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            text = compiled.as_text()
            roof = analysis.analyze(compiled, text)
    finally:
        sharding.set_activation_mesh(None)
    n_total, _ = analysis.count_params(
        jax.eval_shape(lambda: build_model(configs.get_config(arch)).init(jax.random.PRNGKey(0)))
    )
    n_chips = mesh.devices.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "status": "ok",
        "quant_mode": quant_mode,
        "microbatches": microbatches,
        "kv_bits": kv_bits,
        "kv_fmt": kv_fmt,
        "n_params": n_total,
        "per_device": {
            "flops": roof.flops,
            "bytes_accessed": roof.bytes_accessed,
            "collective_bytes": roof.coll_bytes,
            "collective_breakdown": roof.coll_breakdown,
            "xla_raw": roof.xla_raw,
        },
        "roofline_s": {
            "compute": roof.compute_s,
            "memory": roof.memory_s,
            "collective": roof.collective_s,
            "dominant": roof.dominant,
        },
        "memory_analysis": {
            "argument_size": getattr(mem, "argument_size_in_bytes", 0),
            "output_size": getattr(mem, "output_size_in_bytes", 0),
            "temp_size": getattr(mem, "temp_size_in_bytes", 0),
            "peak_estimate": getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0),
        },
        "timings_s": {"lower": t_lower, "compile": t_compile},
        "n_chips": n_chips,
    }
    if verbose:
        per = result["per_device"]
        ma = result["memory_analysis"]
        print(
            f"[{arch} x {shape_name} @ {result['mesh']}] {quant_mode} OK  "
            f"flops/dev={per['flops']:.3e} bytes/dev={per['bytes_accessed']:.3e} "
            f"coll/dev={per['collective_bytes']:.3e} dom={result['roofline_s']['dominant']} "
            f"args={ma['argument_size']/2**30:.2f}GiB temps={ma['temp_size']/2**30:.2f}GiB "
            f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
            flush=True,
        )
        print("  memory_analysis:", mem, flush=True)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        keys = ["flops", "bytes accessed"]
        print("  cost_analysis:", {k: cost.get(k) for k in keys}, flush=True)
    return result


def run_artifact_roundtrip(
    arch: str, w_bits: int = 2, group_size: int = 16, verbose: bool = True
) -> Dict[str, Any]:
    """Artifact round-trip cell: quantize a smoke model, persist the packed
    QTensor+plan artifact, cold-start it back, and check the served decode
    step is bit-identical to the in-memory quantize path.

    Unlike the lowering cells this one runs concrete (smoke-sized) arrays --
    the object of study is the persistence layer, not the compiled graph.
    """
    import tempfile

    import numpy as np

    from repro.models import load_servable, quantize_and_plan, save_servable
    from repro.training.checkpoint import dir_bytes

    qc = QuantConfig(w_bits=w_bits, group_size=group_size, mode="ptq", backend="xla")
    cfg = configs.get_smoke(arch, qc)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    qparams, plan, qapi = quantize_and_plan(api, params)
    fp_bytes = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params))
    with tempfile.TemporaryDirectory() as d:
        t0 = time.time()
        save_servable(d, qapi, qparams, plan)
        t_save = time.time() - t0
        art_bytes = dir_bytes(d)
        t0 = time.time()
        cold_api, cold_params, art = load_servable(d)
        t_load = time.time() - t0

        tok = jnp.zeros((1, 1), jnp.int32)
        pos = jnp.int32(0)
        l_mem, _ = qapi.decode(qparams, tok, pos, qapi.init_cache(1, 8))
        l_cold, _ = cold_api.decode(cold_params, tok, pos, cold_api.init_cache(1, 8))
        bit_exact = bool(np.array_equal(np.asarray(l_mem), np.asarray(l_cold)))
        plan_ok = art.plan is not None and art.plan.to_json() == plan.to_json()
    result = {
        "arch": arch,
        "shape": "artifact_roundtrip",
        "status": "ok" if (bit_exact and plan_ok) else "FAILED",
        "w_bits": w_bits,
        "fp32_bytes": fp_bytes,
        "artifact_bytes": art_bytes,
        "compression_x": fp_bytes / art_bytes,
        "decode_bit_exact": bit_exact,
        "plan_roundtrip": plan_ok,
        "timings_s": {"save": t_save, "load": t_load},
    }
    if verbose:
        print(
            f"[{arch} x artifact_roundtrip] {result['status']}  "
            f"fp32={fp_bytes / 1e6:.2f}MB artifact={art_bytes / 1e6:.2f}MB "
            f"({result['compression_x']:.1f}x) bit_exact={bit_exact} "
            f"plan={plan_ok} (save {t_save:.2f}s load {t_load:.2f}s)",
            flush=True,
        )
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quant", default=None, choices=[None, "fp", "qat", "ptq"])
    ap.add_argument("--w-bits", type=int, default=2)
    ap.add_argument("--group-size", type=int, default=64)
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--kv-bits", type=int, default=16, choices=[8, 16])
    ap.add_argument("--kv-fmt", default=None,
                    choices=["kv_bf16", "kv_int8", "kv_mx"],
                    help="registered KV-cache format; overrides --kv-bits")
    ap.add_argument("--backend", default="xla", choices=["xla", "xla_int8"])
    ap.add_argument("--accum-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--baseline-moe-chunk", action="store_true",
                    help="pre-B1 flat-token MoE chunking")
    ap.add_argument("--baseline-kv-shard", action="store_true",
                    help="pre-C4 head-dim cache sharding")
    ap.add_argument("--artifact-roundtrip", action="store_true",
                    help="run the packed-artifact save/load/parity cell "
                         "instead of lowering (uses --arch, --w-bits, "
                         "--group-size; --all covers every arch)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    if args.artifact_roundtrip:
        archs = configs.ARCH_IDS if args.all else [args.arch or "qwen3-8b"]
        results = []
        for arch in archs:
            try:
                results.append(
                    run_artifact_roundtrip(arch, args.w_bits, args.group_size)
                )
            except Exception as e:
                results.append({"arch": arch, "shape": "artifact_roundtrip",
                                "status": "FAILED", "error": repr(e)[:500]})
                print(f"[{arch} x artifact_roundtrip] FAILED: {repr(e)[:300]}",
                      flush=True)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(results, f, indent=1)
        bad = sum(1 for r in results if r["status"] != "ok")
        print(f"artifact round-trip: {len(results) - bad} ok, {bad} failed")
        return 1 if bad else 0

    cells = []
    if args.all:
        for arch, shape, skip in configs.cells():
            cells.append((arch, shape.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        cells.append((args.arch, args.shape))

    if args.baseline_moe_chunk:
        from repro.models import moe as _moe

        _moe.FLAT_CHUNKING[0] = True
    if args.baseline_kv_shard:
        sharding.KV_SEQ_SHARD[0] = False

    results = []
    failures = 0
    for arch, shape_name in cells:
        try:
            r = run_cell(
                arch, shape_name, args.multi_pod, args.quant,
                args.w_bits, args.group_size, not args.no_seq_shard,
                microbatches=args.microbatches, kv_bits=args.kv_bits,
                backend=args.backend, accum_dtype=args.accum_dtype,
                kv_fmt=args.kv_fmt,
            )
        except Exception as e:  # a failing cell is a bug in the system
            failures += 1
            r = {"arch": arch, "shape": shape_name, "status": "FAILED", "error": repr(e)[:500]}
            print(f"[{arch} x {shape_name}] FAILED: {repr(e)[:300]}", flush=True)
        results.append(r)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    print(f"dry-run: {ok} ok, {sk} skipped, {failures} failed / {len(results)} cells")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
