"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state -- dryrun.py must set XLA_FLAGS before the
first jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across 2 pods.

    The 'pod' axis is pure data parallelism (one cross-pod gradient
    all-reduce per step, DCN-friendly); 'data' is in-pod batch/FSDP; 'model'
    is tensor/expert parallelism confined to the pod's ICI domain.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over the real local devices (tests / examples)."""
    n = jax.device_count()
    return jax.make_mesh((n // model, model), ("data", "model"))


# Launcher-friendly aliases: dp -> batch parallelism, ep/tp -> the 'model'
# axis (tensor and expert parallelism share it; see parallel/sharding.py).
_MESH_AXIS_ALIASES = {"dp": "data", "ep": "model", "tp": "model"}


def mesh_spec_sizes(spec: str) -> tuple:
    """Parse 'dp=2,ep=2' -> ((axis, size), ...) WITHOUT touching jax device
    state -- launchers call this to set XLA_FLAGS before the first jax use."""
    out = []
    for part in spec.split(","):
        k, sep, v = part.partition("=")
        if not sep:
            raise ValueError(f"bad mesh spec {spec!r}: expected name=size pairs")
        out.append((_MESH_AXIS_ALIASES.get(k.strip(), k.strip()), int(v)))
    names = [n for n, _ in out]
    if len(set(names)) != len(names):
        raise ValueError(
            f"mesh spec {spec!r} maps two names onto one axis "
            f"(aliases: {_MESH_AXIS_ALIASES})"
        )
    return tuple(out)


def parse_mesh_spec(spec: str) -> jax.sharding.Mesh:
    """'dp=2,ep=2' (aliases dp->data, ep/tp->model) -> a live Mesh."""
    pairs = mesh_spec_sizes(spec)
    return jax.make_mesh(
        tuple(s for _, s in pairs), tuple(n for n, _ in pairs)
    )


def enable_compile_cache(path: str | None) -> str | None:
    """Point jax's persistent compilation cache at ``path``.

    Cold-start compile time is a serving SLO: a staged engine compiles the
    decode tick plus O(log chunk) prefill shapes on boot, all of which are
    byte-stable for a fixed artifact + mesh, so a warm disk cache turns the
    second boot's compiles into reads.  Env hygiene mirrors the XLA_FLAGS
    convention above: an operator-set ``JAX_COMPILATION_CACHE_DIR`` wins
    when no explicit path is given, and the chosen directory is exported
    back into the environment so worker subprocesses inherit it.  Returns
    the directory in use, or None when caching stays off."""
    import os

    path = path or os.environ.get("JAX_COMPILATION_CACHE_DIR")
    if not path:
        return None
    os.makedirs(path, exist_ok=True)
    from jax.experimental.compilation_cache import compilation_cache as cc

    cc.set_cache_dir(path)
    os.environ["JAX_COMPILATION_CACHE_DIR"] = path
    return path


def preinit_mesh_flag(argv) -> None:
    """Force the host-platform device count for a ``--mesh`` run.

    Scans ``argv`` for ``--mesh SPEC`` or ``--mesh=SPEC`` and, when the
    operator did not set XLA_FLAGS themselves, sets
    ``--xla_force_host_platform_device_count`` to the mesh size.  Call
    before the first jax initialization (importing this module is safe: the
    flag is read at backend-client creation, not import).  Malformed specs
    are left for the caller's argparse to report."""
    import os

    if "XLA_FLAGS" in os.environ:
        return
    spec = None
    for i, arg in enumerate(argv):
        if arg == "--mesh" and i + 1 < len(argv):
            spec = argv[i + 1]
            break
        if arg.startswith("--mesh="):
            spec = arg[len("--mesh="):]
            break
    if spec is None:
        return
    try:
        n = 1
        for _, size in mesh_spec_sizes(spec):
            n *= size
    except ValueError:
        return
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n}"
    )
