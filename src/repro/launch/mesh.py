"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state -- dryrun.py must set XLA_FLAGS before the
first jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across 2 pods.

    The 'pod' axis is pure data parallelism (one cross-pod gradient
    all-reduce per step, DCN-friendly); 'data' is in-pod batch/FSDP; 'model'
    is tensor/expert parallelism confined to the pod's ICI domain.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over the real local devices (tests / examples)."""
    n = jax.device_count()
    return jax.make_mesh((n // model, model), ("data", "model"))
