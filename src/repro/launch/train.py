"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 20 --quant qat --w-bits 2 --group-size 16

Stateful quantization methods (see docs/TRAINING.md):

  --quant ttq   Trained Ternary Quantization: per-cluster Wp/Wn scale
                magnitudes train by gradient (forces --fmt ttq, w_bits 2)
  --quant inq   Incremental Network Quantization on a learned grid:
                magnitude partitions freeze at --inq-fractions of the run
                while the rest keeps training and the cluster grid itself
                trains by gradient (any weight format)

Both thread their learned state into ``--save-artifact DIR`` so the served
model runs on exactly the grid training converged to.

Full-config runs target the production mesh (see dryrun.py for the
compile-only path used on this CPU container); --smoke runs the reduced
config end-to-end on local devices with the same code path.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.configs.base import QuantConfig
from repro.models import build_model
from repro.training import OptConfig, TrainConfig, Trainer
from repro.training.data import DataConfig, make_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--quant", default="fp",
                    choices=["fp", "qat", "ttq", "inq"])
    ap.add_argument("--w-bits", type=int, default=2)
    ap.add_argument("--group-size", type=int, default=16)
    ap.add_argument("--fmt", default=None,
                    help="named weight format (nf4, mx, ttq, ...)")
    ap.add_argument("--inq-fractions", default="0.5,0.75,0.875,1.0",
                    help="INQ accumulative freeze fractions (comma-separated)")
    ap.add_argument("--save-artifact", default=None, metavar="DIR",
                    help="after training, quantize on the learned grid and "
                         "persist a serving artifact")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--opt-bits", type=int, default=32, choices=[8, 32])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    method = args.quant if args.quant in ("ttq", "inq") else None
    fmt = args.fmt
    w_bits = args.w_bits
    if args.quant == "ttq":
        fmt, w_bits = "ttq", 2  # ttq is a ternary-code format by definition
    mode = "qat" if method else args.quant
    qc = QuantConfig(w_bits=w_bits, group_size=args.group_size, mode=mode,
                     fmt=fmt)
    cfg = (configs.get_smoke if args.smoke else configs.get_config)(args.arch, qc)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M quant={args.quant} "
          f"w_bits={w_bits} N={args.group_size}"
          + (f" fmt={fmt}" if fmt else ""))

    dcfg = DataConfig(batch=args.batch, seq=args.seq)
    tcfg = TrainConfig(
        opt=OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                      decay_steps=args.steps, state_bits=args.opt_bits),
        microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=max(5, args.steps // 4),
    )
    # QAT: compile the policy once against the params; the plan-bound view
    # resolves precision by table lookup, and the plan rides in every
    # checkpoint so a restarted node resumes under the same precision table
    api = api.compiled(params)
    quant_state = None
    if method is not None:
        from repro.quant import init_quant_state

        fractions = tuple(
            float(f) for f in args.inq_fractions.split(",") if f
        )
        params, quant_state = init_quant_state(
            params, api.ctx.plan, method,
            fractions=fractions, total_steps=args.steps,
        )
    tr = Trainer(api.train_loss, params, tcfg, plan=api.ctx.plan,
                 quant_state=quant_state)
    if args.resume and args.ckpt_dir:
        start = tr.maybe_restore()
        restored = tr.plan
        if restored is not None and (
            api.ctx.plan is None or restored.to_json() != api.ctx.plan.to_json()
        ):
            # train under the checkpointed precision table, not the freshly
            # re-compiled one (they differ when the policy/config changed or
            # the checkpoint carries calibrated exponents)
            api = api.with_plan(restored)
            tr.rebind_loss(api.train_loss)
        print(f"resumed at step {start}")
    hist = tr.train(lambda i: make_batch(cfg, dcfg, i), args.steps)
    for i in range(0, len(hist["loss"]), max(1, len(hist["loss"]) // 10)):
        print(f"step {hist['step'][i]:5d}  loss {hist['loss'][i]:.4f}")
    print(f"final loss {hist['loss'][-1]:.4f}")

    if args.save_artifact:
        from repro.models import quantize_and_plan, save_servable

        # the state-carrying tree threads the LEARNED scales into the
        # artifact (quantize_params consumes ttq_scales / inq_scales --
        # deployment never re-fits the grid)
        qparams, plan, _ = quantize_and_plan(api, tr.params)
        path = save_servable(args.save_artifact, api, qparams, plan)
        print(f"saved serving artifact at {path}")


if __name__ == "__main__":
    main()
