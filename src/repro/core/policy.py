"""Per-layer precision policy (the paper's "C1 and BatchNorm layers" rules).

The paper pins the first conv layer to 8-bit weights, keeps FC weights
unquantized during fine-tuning, and quantizes activations everywhere.  The
policy engine generalizes this: a default precision plus ordered regex
overrides resolved against the layer's parameter path, e.g.

    PrecisionPolicy.ternary(group_size=64).resolve("blocks/3/mlp/up")

Built-in override sets encode the paper's rules mapped to LM blocks:
embedding & first block 8-bit (C1 analogue), lm_head 8-bit (FC analogue),
MoE router 8-bit (accuracy-critical control path), norms/biases fp32.

Per-call ``resolve`` is the *rule* semantics; hot paths should compile the
policy once against a parameter tree with ``PrecisionPolicy.compile`` (see
repro.quant.plan.QuantPlan), which resolves every projection site into a
static table and carries calibrated activation exponents.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple

FULL_PRECISION = 32


@dataclasses.dataclass(frozen=True)
class LayerPrecision:
    w_bits: int = 2
    act_bits: int = 8
    group_size: int = 64
    filter_size: int = 1
    refit_scale: bool = False
    # allow this site to use a calibrated static activation exponent when the
    # plan carries one (False pins the site to dynamic per-row exponents)
    static_act: bool = True
    # registered weight-format name (repro.quant.register_format); None uses
    # the default format for w_bits
    fmt: Optional[str] = None
    # run this site through the prologue/epilogue-fused kernel when the
    # backend has one (False pins the site to the unfused three-pass
    # pipeline -- the escape hatch for debugging / A-B parity runs)
    fused: bool = True

    @property
    def quantized(self) -> bool:
        return self.w_bits < 16


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    default: LayerPrecision
    # ordered (pattern, precision); first match wins
    overrides: Tuple[Tuple[str, LayerPrecision], ...] = ()

    def resolve(self, path: str) -> LayerPrecision:
        for pattern, prec in self.overrides:
            if re.search(pattern, path):
                return prec
        return self.default

    def compile(self, params, *, mode: str = "ptq", backend: str = "auto"):
        """Resolve every projection site of ``params`` once -> QuantPlan.

        ``params`` may hold concrete arrays or ShapeDtypeStructs (only tree
        structure and ndim are read).  See repro.quant.plan.compile_policy.
        """
        from repro.quant.plan import compile_policy

        return compile_policy(self, params, mode=mode, backend=backend)

    @staticmethod
    def paper_overrides(group_size: int) -> Tuple[Tuple[str, LayerPrecision], ...]:
        eight = LayerPrecision(w_bits=8, act_bits=8, group_size=group_size)
        fp = LayerPrecision(w_bits=FULL_PRECISION, act_bits=8)
        return (
            (r"(^|/)embed", eight),          # C1 analogue: input projection
            (r"(^|/)blocks/0(/|$)", eight),  # first block stays 8-bit
            (r"(^|/)lm_head", eight),        # FC analogue
            (r"router|gate_proj_router", eight),  # MoE control path
            (r"norm|scale|bias|conv1d|ssm/(A|D|dt)", fp),  # non-GEMM params
            (r"frontend", eight),            # modality stubs (VLM/audio)
        )

    @classmethod
    def ternary(cls, group_size: int = 64, filter_size: int = 1,
                refit_scale: bool = False) -> "PrecisionPolicy":
        return cls(
            default=LayerPrecision(2, 8, group_size, filter_size, refit_scale),
            overrides=cls.paper_overrides(group_size),
        )

    @classmethod
    def int4(cls, group_size: int = 64) -> "PrecisionPolicy":
        return cls(
            default=LayerPrecision(4, 8, group_size),
            overrides=cls.paper_overrides(group_size),
        )

    @classmethod
    def int8(cls, group_size: int = 64) -> "PrecisionPolicy":
        return cls(
            default=LayerPrecision(8, 8, group_size),
            overrides=cls.paper_overrides(group_size),
        )

    @classmethod
    def for_format(
        cls, fmt: str, group_size: int = 64, filter_size: int = 1,
        refit_scale: bool = False,
    ) -> "PrecisionPolicy":
        """Policy whose default sites use the *named* registered format.

        The default ``LayerPrecision`` carries ``fmt`` plus the format's own
        bit-width; formats with a fixed cluster length (mx: 32) pin
        ``group_size`` to it so the compiled plan, the QTensor metadata and
        the scale tables can never disagree.
        ``filter_size``/``refit_scale`` are forwarded for formats whose
        ``weight_codes`` honor them (ternary-style encoders; nf4/mx accept
        and ignore them).  The paper's 8-bit override sites (embedding /
        first block / lm_head / router) stay on the built-in int8 format --
        they are accuracy-critical control paths, not the sub-8-bit
        experiment.
        """
        from repro.quant.formats import get_format  # lazy: formats imports kernels

        f = get_format(fmt)
        g = f.block_size or group_size
        return cls(
            default=LayerPrecision(f.bits, 8, g, filter_size, refit_scale, fmt=fmt),
            overrides=cls.paper_overrides(group_size),
        )

    @classmethod
    def full(cls) -> "PrecisionPolicy":
        return cls(default=LayerPrecision(FULL_PRECISION, FULL_PRECISION))
