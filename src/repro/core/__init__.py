"""Core contribution of the paper: cluster-based dynamic-fixed-point
quantization (ternary / 4-bit / 8-bit weights, 8-bit activations)."""
from repro.core.dfp import (
    DfpSpec,
    choose_exponent,
    dequantize,
    fake_quantize,
    qmax,
    quantize,
    quantize_tensor,
)
from repro.core.policy import FULL_PRECISION, LayerPrecision, PrecisionPolicy
from repro.core.quantizer import QTensor
from repro.core.ternary import ternarize_matrix, ternary_dequantize

# The format-registry entry points re-exported from quantizer are LAZY there
# (they live in repro.quant.formats, which imports the kernels); resolving
# them at package-import time completed the cycle
# repro.kernels -> repro.core -> repro.quant.formats -> repro.kernels and
# made `import repro.kernels` (or repro.core) fail as a first import.  Keep
# the names available but resolve them on first attribute access.
_QUANTIZER_LAZY = (
    "decode_codes",
    "dequantize_weights",
    "fake_quantize_weights",
    "quantize_weights",
)


def __getattr__(name: str):
    if name in _QUANTIZER_LAZY:
        from repro.core import quantizer

        return getattr(quantizer, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
