"""Core contribution of the paper: cluster-based dynamic-fixed-point
quantization (ternary / 4-bit / 8-bit weights, 8-bit activations)."""
from repro.core.dfp import (
    DfpSpec,
    choose_exponent,
    dequantize,
    fake_quantize,
    qmax,
    quantize,
    quantize_tensor,
)
from repro.core.policy import FULL_PRECISION, LayerPrecision, PrecisionPolicy
from repro.core.quantizer import (
    QTensor,
    decode_codes,
    dequantize_weights,
    fake_quantize_weights,
    quantize_weights,
)
from repro.core.ternary import ternarize_matrix, ternary_dequantize
