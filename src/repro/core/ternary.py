"""Algorithms 1 & 2 of the paper: hierarchical cluster-based ternarization.

Terminology (paper -> here):
  * "filter"  : the Algorithm-2 unit. For a KxK conv it is one 2-D kernel
    slice (F = K*K elements); for transformer projections it is a contiguous
    sub-block of F input features of one output channel.
  * "cluster" : N filters that accumulate into the same output feature and
    share one scaling factor alpha. The reduction segment covered by one
    alpha therefore has G = N*F elements -- the paper's "one 8-bit multiply
    per N*K^2 ternary accumulations".

Both algorithms are implemented *exactly* (no grid approximation): after a
single sort, the optimal threshold over all n candidate supports is found in
closed form with cumulative sums:

    E(alpha, I) = ||W - alpha * sign(W) 1_I||_F^2
                = sum(W^2) - 2 alpha * A(I) + |I| alpha^2 ,
    A(I) = sum_{i in I} |W_i| .

Algorithm 2 restricts I to top-t magnitudes and alpha to RMS(top-t); both are
functions of t, so argmin over t = 1..n is exact.  Algorithm 1 evaluates the
N cluster-level candidates alpha_t = RMS(top-t per-filter thresholds) against
the whole cluster with support {|W| > alpha_t} via searchsorted.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def _sorted_desc_stats(w_abs: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sort |w| descending; return (sorted, cum_abs, cum_sq) along last axis."""
    a = jnp.flip(jnp.sort(w_abs, axis=-1), axis=-1)
    return a, jnp.cumsum(a, axis=-1), jnp.cumsum(a * a, axis=-1)


def filter_threshold(w: jax.Array) -> jax.Array:
    """Algorithm 2: optimal RMS threshold of one filter (last axis = F).

    Returns alpha_{tau*} minimizing ||w - alpha * sign(w) 1_{top-t}||^2 over
    all supports t = 1..F with alpha = sqrt(sum_{top-t} w^2 / t).
    """
    a, A, S = _sorted_desc_stats(jnp.abs(w))
    t = jnp.arange(1, a.shape[-1] + 1, dtype=jnp.float32)
    total_sq = S[..., -1:]
    alpha_t = jnp.sqrt(jnp.maximum(S / t, 0.0))
    err_t = total_sq - 2.0 * alpha_t * A + t * alpha_t**2
    best = jnp.argmin(err_t, axis=-1)
    return jnp.take_along_axis(alpha_t, best[..., None], axis=-1)[..., 0]


def _cluster_candidate_error(
    cand: jax.Array, asc: jax.Array, p_abs: jax.Array, p_sq: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Error of threshold/scale ``cand`` against a sorted-ascending cluster.

    asc:   (M,) cluster |w| ascending;  p_abs/p_sq: zero-padded prefix sums.
    Support is {|w| > cand}.  Returns (err, count) for each candidate.
    """
    m = asc.shape[-1]
    idx = jnp.searchsorted(asc, cand, side="right")  # elements <= cand
    cnt = (m - idx).astype(jnp.float32)
    a_sup = p_abs[-1] - p_abs[idx]  # sum |w| over support
    total_sq = p_sq[-1]
    err = total_sq - 2.0 * cand * a_sup + cnt * cand**2
    return err, cnt


def cluster_ternarize(
    cluster: jax.Array, refit_scale: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """Algorithm 1 on one cluster of shape (N, F).

    1. Algorithm 2 per filter -> thresholds alpha_i (N,).
    2. Sort alpha desc; candidates alpha_t = sqrt(mean(top-t alpha^2)).
    3. Evaluate each candidate on the whole cluster (threshold == scale),
       pick the minimizer.
    4. (optional, beyond-paper) refit the scale to the L2-optimal
       mean(|w| | support) while keeping the chosen support.

    Returns (codes int8 in {-1,0,1} shaped like ``cluster``, alpha f32 scalar).
    """
    n, f = cluster.shape
    if f == 1:  # Algorithm 2 on a single element is exactly alpha = |w|
        alphas = jnp.abs(cluster[:, 0])
    else:
        alphas = filter_threshold(cluster)  # (N,)
    b = jnp.flip(jnp.sort(alphas))
    t = jnp.arange(1, n + 1, dtype=jnp.float32)
    cand = jnp.sqrt(jnp.maximum(jnp.cumsum(b * b) / t, 0.0))  # (N,)

    flat = jnp.abs(cluster).reshape(-1)
    asc = jnp.sort(flat)
    pad = jnp.zeros((1,), jnp.float32)
    p_abs = jnp.concatenate([pad, jnp.cumsum(asc)])
    p_sq = jnp.concatenate([pad, jnp.cumsum(asc * asc)])

    err, cnt = _cluster_candidate_error(cand, asc, p_abs, p_sq)
    best = jnp.argmin(err)
    alpha = cand[best]

    mask = jnp.abs(cluster) > alpha
    if refit_scale:
        n_sup = jnp.maximum(cnt[best], 1.0)
        a_sup = p_abs[-1] - p_abs[jnp.searchsorted(asc, alpha, side="right")]
        alpha = jnp.where(cnt[best] > 0, a_sup / n_sup, alpha)
    codes = jnp.where(mask, jnp.sign(cluster), 0.0).astype(jnp.int8)
    # All-zero cluster -> alpha 0, codes 0 (handled naturally: cand == 0).
    return codes, alpha.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_filters", "filter_size", "refit_scale"))
def ternarize_blocks(
    blocks: jax.Array, n_filters: int, filter_size: int, refit_scale: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """Vectorized Algorithm 1 over many clusters.

    blocks: (n_clusters, N*F) or (n_clusters, N, F).
    Returns (codes int8 same shape, alpha f32 (n_clusters,)).
    """
    shaped = blocks.reshape(blocks.shape[0], n_filters, filter_size)
    codes, alpha = jax.vmap(lambda c: cluster_ternarize(c, refit_scale))(shaped)
    return codes.reshape(blocks.shape), alpha


def ternarize_matrix(
    w: jax.Array, group_size: int, filter_size: int, refit_scale: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """Ternarize a (K, Nout) projection matrix with per-(k-group, out) scales.

    The K reduction axis is partitioned into groups of ``group_size`` = N*F
    elements; each (group, output-channel) block is one paper-cluster with
    its own alpha.  Returns:
      codes : int8 (K, Nout) in {-1, 0, 1}
      alpha : f32  (K // group_size, Nout)
    """
    k, nout = w.shape
    if k % group_size:
        raise ValueError(f"K={k} not divisible by group_size={group_size}")
    if group_size % filter_size:
        raise ValueError(f"group={group_size} not divisible by filter={filter_size}")
    n_filters = group_size // filter_size
    n_groups = k // group_size
    # (K, Nout) -> (n_groups, group, Nout) -> (n_groups, Nout, group)
    blocks = w.reshape(n_groups, group_size, nout).transpose(0, 2, 1)
    codes, alpha = ternarize_blocks(
        blocks.reshape(n_groups * nout, group_size), n_filters, filter_size, refit_scale
    )
    codes = codes.reshape(n_groups, nout, group_size).transpose(0, 2, 1)
    return codes.reshape(k, nout), alpha.reshape(n_groups, nout)


def ternary_dequantize(codes: jax.Array, alpha: jax.Array, group_size: int) -> jax.Array:
    """Inverse of ternarize_matrix: (K, Nout) f32 reconstruction."""
    k, nout = codes.shape
    c = codes.reshape(k // group_size, group_size, nout).astype(jnp.float32)
    return (c * alpha[:, None, :]).reshape(k, nout)
