"""Unified cluster quantizer: QTensor representation, packing, 2t/4/8-bit.

A quantized projection weight is stored as a ``QTensor``:

  * ``packed``  -- ternary codes packed 16-per-uint32 (2-bit two's
    complement), int4 packed 8-per-uint32, or raw int8 mantissas.
  * ``scale_m`` -- per-(k-group, out-channel) scale mantissas, int8.  This is
    the paper's cluster alpha, re-quantized to 8 bits (Algorithm 1, step 9).
  * ``scale_e`` -- one shared power-of-two exponent (int32 scalar): together
    (scale_m, scale_e) form the dynamic-fixed-point scale table.

Dequantized value of block (g, o):  decode(packed) * scale_m[g,o] * 2**scale_e.

Layouts are chosen for the TPU kernels: ``packed`` is laid out along the
reduction axis K first -- a (tile_k x tile_n) weight tile is a contiguous
(tile_k/16 x tile_n) window of uint32 words, an 8x HBM-traffic reduction vs
bf16 (the TPU-native realization of the paper's 16x compute/power claim).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dfp, ternary

TERNARY_PER_WORD = 16  # 2-bit codes per uint32
INT4_PER_WORD = 8


@dataclasses.dataclass
class QTensor:
    """Quantized 2-D weight (K, N) with per-(k-group, out) DFP scales."""

    packed: jax.Array  # see module docstring
    scale_m: jax.Array  # int8 (K // group_size, N)
    scale_e: jax.Array  # int32 scalar
    bits: int = dataclasses.field(metadata=dict(static=True), default=2)
    group_size: int = dataclasses.field(metadata=dict(static=True), default=64)
    shape: Tuple[int, int] = dataclasses.field(
        metadata=dict(static=True), default=(0, 0)
    )

    @property
    def k(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    @property
    def n_groups(self) -> int:
        return self.shape[0] // self.group_size


jax.tree_util.register_dataclass(
    QTensor,
    data_fields=["packed", "scale_m", "scale_e"],
    meta_fields=["bits", "group_size", "shape"],
)


# ---------------------------------------------------------------------------
# Bit packing (2-bit ternary, 4-bit) along the K axis.
# ---------------------------------------------------------------------------
def pack2(codes: jax.Array) -> jax.Array:
    """(K, N) int8 in {-1,0,1} -> (K/16, N) uint32 (2-bit two's complement)."""
    k, n = codes.shape
    assert k % TERNARY_PER_WORD == 0, k
    c = (codes.astype(jnp.int32) & 3).astype(jnp.uint32)
    c = c.reshape(k // TERNARY_PER_WORD, TERNARY_PER_WORD, n)
    word = jnp.zeros((k // TERNARY_PER_WORD, n), jnp.uint32)
    for i in range(TERNARY_PER_WORD):
        word = word | (c[:, i, :] << (2 * i))
    return word


def unpack2(packed: jax.Array, k: int) -> jax.Array:
    """Inverse of pack2 -> (K, N) int8 in {-1,0,1}."""
    lanes = []
    for i in range(TERNARY_PER_WORD):
        c = (packed >> (2 * i)) & jnp.uint32(3)
        lanes.append((((c + 1) & 3).astype(jnp.int8) - 1))
    out = jnp.stack(lanes, axis=1)  # (K/16, 16, N)
    return out.reshape(k, packed.shape[1])


def pack4(q: jax.Array) -> jax.Array:
    """(K, N) int8 in [-7, 7] -> (K/8, N) uint32 (4-bit two's complement)."""
    k, n = q.shape
    assert k % INT4_PER_WORD == 0, k
    c = (q.astype(jnp.int32) & 0xF).astype(jnp.uint32)
    c = c.reshape(k // INT4_PER_WORD, INT4_PER_WORD, n)
    word = jnp.zeros((k // INT4_PER_WORD, n), jnp.uint32)
    for i in range(INT4_PER_WORD):
        word = word | (c[:, i, :] << (4 * i))
    return word


def unpack4(packed: jax.Array, k: int) -> jax.Array:
    """Inverse of pack4 -> (K, N) int8 in [-8, 7]."""
    lanes = []
    for i in range(INT4_PER_WORD):
        c = ((packed >> (4 * i)) & jnp.uint32(0xF)).astype(jnp.int8)
        lanes.append(jnp.where(c >= 8, c - 16, c))
    out = jnp.stack(lanes, axis=1)
    return out.reshape(k, packed.shape[1])


# ---------------------------------------------------------------------------
# Scale-table DFP re-quantization (Algorithm 1, step 9).
# ---------------------------------------------------------------------------
def quantize_scales(alpha: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """f32 alpha table -> (int8 mantissa, shared int32 exponent)."""
    e = dfp.choose_exponent(jnp.max(jnp.abs(alpha)), bits=8)
    m = dfp.quantize(alpha, e, bits=8)
    return m, e


def dequantize_scales(scale_m: jax.Array, scale_e: jax.Array) -> jax.Array:
    return dfp.dequantize(scale_m, scale_e)


# ---------------------------------------------------------------------------
# Weight quantization entry points.
# ---------------------------------------------------------------------------
def quantize_weights(
    w: jax.Array,
    bits: int,
    group_size: int,
    filter_size: int = 1,
    refit_scale: bool = False,
) -> QTensor:
    """Quantize a (K, N) projection with the paper's cluster scheme.

    bits=2 runs Algorithms 1&2 (hierarchical ternarization); bits in {4, 8}
    use per-cluster dynamic-fixed-point mantissas with max-abs scaling.  In
    every case the scale table itself is re-quantized to 8-bit DFP so the
    whole pipeline stays sub-8-bit.
    """
    k, n = w.shape
    w = w.astype(jnp.float32)
    if bits == 2:
        codes, alpha = ternary.ternarize_matrix(w, group_size, filter_size, refit_scale)
        scale_m, scale_e = quantize_scales(alpha)
        return QTensor(pack2(codes), scale_m, scale_e, 2, group_size, (k, n))
    if bits in (4, 8):
        blocks = w.reshape(k // group_size, group_size, n)
        max_abs = jnp.max(jnp.abs(blocks), axis=1)  # (groups, N)
        alpha = max_abs / dfp.qmax(bits)
        scale_m, scale_e = quantize_scales(alpha)
        scale = dequantize_scales(scale_m, scale_e)[:, None, :]
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(blocks / safe), -dfp.qmax(bits), dfp.qmax(bits))
        q = q.astype(jnp.int8).reshape(k, n)
        packed = pack4(q) if bits == 4 else q
        return QTensor(packed, scale_m, scale_e, bits, group_size, (k, n))
    raise ValueError(f"unsupported weight bits: {bits}")


def decode_codes(qt: QTensor) -> jax.Array:
    """Integer mantissas (K, N) int8 of a QTensor."""
    if qt.bits == 2:
        return unpack2(qt.packed, qt.k)
    if qt.bits == 4:
        return unpack4(qt.packed, qt.k)
    return qt.packed  # int8 raw


def dequantize_weights(qt: QTensor) -> jax.Array:
    """f32 (K, N) reconstruction."""
    codes = decode_codes(qt).astype(jnp.float32)
    scale = dequantize_scales(qt.scale_m, qt.scale_e)  # (groups, N)
    c = codes.reshape(qt.n_groups, qt.group_size, qt.n)
    return (c * scale[:, None, :]).reshape(qt.k, qt.n)


def fake_quantize_weights(
    w: jax.Array, bits: int, group_size: int, filter_size: int = 1,
    refit_scale: bool = False,
) -> jax.Array:
    """quantize -> dequantize (QAT forward / error measurement)."""
    return dequantize_weights(
        quantize_weights(w, bits, group_size, filter_size, refit_scale)
    )


def weight_quantization_error(w, bits, group_size, filter_size=1) -> jax.Array:
    wq = fake_quantize_weights(w, bits, group_size, filter_size)
    return jnp.sum((w - wq) ** 2)
