"""QTensor container + bit-packing primitives (base layer of repro.quant).

A quantized projection weight is stored as a ``QTensor``:

  * ``packed``  -- ternary codes packed 16-per-uint32 (2-bit two's
    complement), int4 packed 8-per-uint32, or raw int8 mantissas.
  * ``scale_m`` -- per-(k-group, out-channel) scale mantissas, int8.  This is
    the paper's cluster alpha, re-quantized to 8 bits (Algorithm 1, step 9).
  * ``scale_e`` -- one shared power-of-two exponent (int32 scalar): together
    (scale_m, scale_e) form the dynamic-fixed-point scale table.

Dequantized value of block (g, o):  decode(packed) * scale_m[g,o] * 2**scale_e.

Layouts are chosen for the TPU kernels: ``packed`` is laid out along the
reduction axis K first -- a (tile_k x tile_n) weight tile is a contiguous
(tile_k/16 x tile_n) window of uint32 words, an 8x HBM-traffic reduction vs
bf16 (the TPU-native realization of the paper's 16x compute/power claim).

*How* values are encoded for a given bit-width is owned by the format
registry (``repro.quant.formats``); the bits-generic entry points
(``quantize_weights``, ``decode_codes``, ``dequantize_weights``,
``fake_quantize_weights``, ``weight_quantization_error``) live there and are
re-exported here lazily for compatibility.

Migration note (old -> new):

    from repro.core.quantizer import quantize_weights, QTensor
        -> from repro.quant import quantize_weights, QTensor
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import dfp

TERNARY_PER_WORD = 16  # 2-bit codes per uint32
INT4_PER_WORD = 8


@dataclasses.dataclass
class QTensor:
    """Quantized 2-D weight (K, N) with per-(k-group, out) DFP scales."""

    packed: jax.Array  # see module docstring
    scale_m: jax.Array  # int8 (K // group_size, N)
    scale_e: jax.Array  # int32 scalar
    bits: int = dataclasses.field(metadata=dict(static=True), default=2)
    group_size: int = dataclasses.field(metadata=dict(static=True), default=64)
    shape: Tuple[int, int] = dataclasses.field(
        metadata=dict(static=True), default=(0, 0)
    )
    # registered format name; "" means "look up by bits" (built-in formats)
    fmt: str = dataclasses.field(metadata=dict(static=True), default="")

    @property
    def k(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    @property
    def n_groups(self) -> int:
        return self.shape[0] // self.group_size


jax.tree_util.register_dataclass(
    QTensor,
    data_fields=["packed", "scale_m", "scale_e"],
    meta_fields=["bits", "group_size", "shape", "fmt"],
)


# ---------------------------------------------------------------------------
# Bit packing (2-bit ternary, 4-bit) along the K axis.
# ---------------------------------------------------------------------------
def pack2(codes: jax.Array) -> jax.Array:
    """(K, N) int8 in {-1,0,1} -> (K/16, N) uint32 (2-bit two's complement)."""
    k, n = codes.shape
    assert k % TERNARY_PER_WORD == 0, k
    c = (codes.astype(jnp.int32) & 3).astype(jnp.uint32)
    c = c.reshape(k // TERNARY_PER_WORD, TERNARY_PER_WORD, n)
    word = jnp.zeros((k // TERNARY_PER_WORD, n), jnp.uint32)
    for i in range(TERNARY_PER_WORD):
        word = word | (c[:, i, :] << (2 * i))
    return word


def unpack2(packed: jax.Array, k: int) -> jax.Array:
    """Inverse of pack2 -> (K, N) int8 in {-1,0,1}."""
    lanes = []
    for i in range(TERNARY_PER_WORD):
        c = (packed >> (2 * i)) & jnp.uint32(3)
        lanes.append((((c + 1) & 3).astype(jnp.int8) - 1))
    out = jnp.stack(lanes, axis=1)  # (K/16, 16, N)
    return out.reshape(k, packed.shape[1])


# NormalFloat-4 lookup table (QLoRA, Dettmers et al. 2023): the 16 quantiles
# of a standard normal, normalized to [-1, 1].  Stored here on the int8 DFP
# grid (round(v * 127)) so an nf4 weight decodes to ordinary int8 mantissas:
# dequant = NF4_LUT_I8[code] * (absmax / 127), which means the per-cluster
# scale table and every integer matmul path (kernels, ref oracle, xla_int8)
# consume nf4 exactly like any other format -- the LUT is the only new piece.
NF4_PER_WORD = 8  # 4-bit codes per uint32, packed along K like int4
NF4_LUT_I8 = (
    -127, -88, -67, -50, -36, -23, -12, 0,
    10, 20, 31, 43, 56, 71, 92, 127,
)


def pack4(q: jax.Array) -> jax.Array:
    """(K, N) int8 in the symmetric range [-7, 7] -> (K/8, N) uint32.

    The DFP pipeline is symmetric (``dfp.qmax(4)`` == 7): -8 is excluded so
    negation is closed, and the int4 format clips mantissas to +/-7 before
    packing.  The range contract is asserted on concrete inputs; under
    tracing the caller is trusted (the built-in encoders always clip first).
    """
    k, n = q.shape
    assert k % INT4_PER_WORD == 0, k
    if not isinstance(q, jax.core.Tracer):
        assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= dfp.qmax(4), (
            "pack4 expects symmetric int4 mantissas in [-7, 7]"
        )
    c = (q.astype(jnp.int32) & 0xF).astype(jnp.uint32)
    c = c.reshape(k // INT4_PER_WORD, INT4_PER_WORD, n)
    word = jnp.zeros((k // INT4_PER_WORD, n), jnp.uint32)
    for i in range(INT4_PER_WORD):
        word = word | (c[:, i, :] << (4 * i))
    return word


def unpack4(packed: jax.Array, k: int) -> jax.Array:
    """Inverse of pack4 -> (K, N) int8 in the symmetric range [-7, 7].

    (The 4-bit two's-complement code 0b1000 would decode to -8, but pack4's
    range contract means it is never produced; the sign-extension below is
    kept total so arbitrary words still decode without wrapping.)
    """
    lanes = []
    for i in range(INT4_PER_WORD):
        c = ((packed >> (4 * i)) & jnp.uint32(0xF)).astype(jnp.int8)
        lanes.append(jnp.where(c >= 8, c - 16, c))
    out = jnp.stack(lanes, axis=1)
    return out.reshape(k, packed.shape[1])


def pack4u(codes: jax.Array) -> jax.Array:
    """(K, N) int8 UNSIGNED 4-bit codes in [0, 15] -> (K/8, N) uint32.

    The lookup-table companion of ``pack4``: nf4 codes are LUT *indices*,
    not two's-complement mantissas, so the fields pack without sign handling.
    The range contract ([0, 15]) is asserted on concrete inputs; under
    tracing the caller is trusted (the nf4 encoder emits argmin indices,
    which are in range by construction)."""
    k, n = codes.shape
    assert k % NF4_PER_WORD == 0, k
    if not isinstance(codes, jax.core.Tracer):
        lo, hi = int(jnp.min(codes)), int(jnp.max(codes))
        assert 0 <= lo and hi <= 15, (
            f"pack4u expects unsigned 4-bit codes in [0, 15], got [{lo}, {hi}]"
        )
    c = (codes.astype(jnp.int32) & 0xF).astype(jnp.uint32)
    c = c.reshape(k // NF4_PER_WORD, NF4_PER_WORD, n)
    word = jnp.zeros((k // NF4_PER_WORD, n), jnp.uint32)
    for i in range(NF4_PER_WORD):
        word = word | (c[:, i, :] << (4 * i))
    return word


def unpack4u(packed: jax.Array, k: int) -> jax.Array:
    """Inverse of pack4u -> (K, N) int8 codes in [0, 15]."""
    lanes = []
    for i in range(NF4_PER_WORD):
        lanes.append(((packed >> (4 * i)) & jnp.uint32(0xF)).astype(jnp.int8))
    out = jnp.stack(lanes, axis=1)
    return out.reshape(k, packed.shape[1])


def nf4_lut_decode(codes: jax.Array) -> jax.Array:
    """LUT indices [0, 15] -> int8 mantissas on the NF4_LUT_I8 grid."""
    lut = jnp.asarray(NF4_LUT_I8, jnp.int8)
    return jnp.take(lut, codes.astype(jnp.int32), axis=0)


# ---------------------------------------------------------------------------
# Scale-table DFP re-quantization (Algorithm 1, step 9).
# ---------------------------------------------------------------------------
def quantize_scales(alpha: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """f32 alpha table -> (int8 mantissa, shared int32 exponent)."""
    e = dfp.choose_exponent(jnp.max(jnp.abs(alpha)), bits=8)
    m = dfp.quantize(alpha, e, bits=8)
    return m, e


def dequantize_scales(scale_m: jax.Array, scale_e: jax.Array) -> jax.Array:
    return dfp.dequantize(scale_m, scale_e)


# ---------------------------------------------------------------------------
# Lazy re-exports of the format-registry-driven entry points (repro.quant).
# Resolved on first attribute access so this base module never imports the
# registry (which imports the kernels) at module scope.
# ---------------------------------------------------------------------------
_FORMAT_API = (
    "quantize_weights",
    "decode_codes",
    "dequantize_weights",
    "fake_quantize_weights",
    "weight_quantization_error",
)


def __getattr__(name: str):
    if name in _FORMAT_API:
        from repro.quant import formats

        return getattr(formats, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
