"""Straight-through estimators for the paper's low-precision training (Sec. 4).

Forward pass uses ternary/4-bit fake-quantized weights and 8-bit activations;
gradients flow to the FP32 master copy unchanged (weights) or clipped to the
representable range (activations).

Weight STE is a ``jax.custom_vjp`` whose backward is identity: autodiff never
traces inside Algorithm 1 (sorts / searchsorted are piecewise-constant anyway,
and keeping them out of the AD graph also keeps the backward HLO small).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import calibration, dfp, quantizer


def ste(x: jax.Array, quantized: jax.Array) -> jax.Array:
    """Value of ``quantized``, gradient of ``x``."""
    return x + jax.lax.stop_gradient(quantized - x)


@functools.lru_cache(maxsize=None)
def _weight_ste_fn(bits: int, group_size: int, filter_size: int, refit: bool,
                   fmt):
    @jax.custom_vjp
    def fq(w):
        return quantizer.fake_quantize_weights(
            w, bits, group_size, filter_size, refit, fmt=fmt
        )

    def fwd(w):
        return fq(w), None

    def bwd(_, g):  # straight-through: identity gradient to the master copy
        return (g,)

    fq.defvjp(fwd, bwd)
    return fq


def weights_ste(
    w: jax.Array, bits: int, group_size: int, filter_size: int = 1,
    refit_scale: bool = False, fmt: str = None,
) -> jax.Array:
    """``fmt`` selects a named registered format (nf4, mx, ...) so the QAT
    forward fake-quantizes on the SAME grid PTQ will deploy on -- resolving
    by bits alone would silently train against the wrong (uniform) grid for
    formats that share a width with a built-in."""
    if bits >= 16:  # full precision passthrough
        return w
    return _weight_ste_fn(bits, group_size, filter_size, refit_scale, fmt)(w)


def ternary_weights_ste(
    w: jax.Array, group_size: int, filter_size: int = 1, refit_scale: bool = False
) -> jax.Array:
    """Sec. 4 forward: Algorithm-1 ternarized weights, identity gradient."""
    return weights_ste(w, 2, group_size, filter_size, refit_scale)


def act_ste(x: jax.Array, bits: int = 8, per_row: bool = False) -> jax.Array:
    """8-bit DFP activation fake-quant with *clipped* STE: gradient is zero
    outside the representable range (the clip carries the gradient), identity
    inside (rounding is straight-through)."""
    if bits >= 16:
        return x
    max_abs = jnp.max(jnp.abs(jax.lax.stop_gradient(x)))
    e = dfp.choose_exponent(max_abs, bits)
    r = dfp.qmax(bits) * dfp.exp2i(e)
    xc = jnp.clip(x, -r, r)
    return ste(xc, calibration.fake_quantize_act(xc, bits, per_row))
