"""Straight-through estimators for the paper's low-precision training (Sec. 4).

Forward pass uses ternary/4-bit fake-quantized weights and 8-bit activations;
gradients flow to the FP32 master copy unchanged (weights) or clipped to the
representable range (activations).

Weight STE is a ``jax.custom_vjp`` whose backward is identity: autodiff never
traces inside Algorithm 1 (sorts / searchsorted are piecewise-constant anyway,
and keeping them out of the AD graph also keeps the backward HLO small).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import calibration, dfp, quantizer


def ste(x: jax.Array, quantized: jax.Array) -> jax.Array:
    """Value of ``quantized``, gradient of ``x``."""
    return x + jax.lax.stop_gradient(quantized - x)


@functools.lru_cache(maxsize=None)
def _weight_ste_fn(bits: int, group_size: int, filter_size: int, refit: bool,
                   fmt):
    @jax.custom_vjp
    def fq(w):
        return quantizer.fake_quantize_weights(
            w, bits, group_size, filter_size, refit, fmt=fmt
        )

    def fwd(w):
        return fq(w), None

    def bwd(_, g):  # straight-through: identity gradient to the master copy
        return (g,)

    fq.defvjp(fwd, bwd)
    return fq


def weights_ste(
    w: jax.Array, bits: int, group_size: int, filter_size: int = 1,
    refit_scale: bool = False, fmt: str = None,
) -> jax.Array:
    """``fmt`` selects a named registered format (nf4, mx, ...) so the QAT
    forward fake-quantizes on the SAME grid PTQ will deploy on -- resolving
    by bits alone would silently train against the wrong (uniform) grid for
    formats that share a width with a built-in."""
    if bits >= 16:  # full precision passthrough
        return w
    return _weight_ste_fn(bits, group_size, filter_size, refit_scale, fmt)(w)


def ternary_weights_ste(
    w: jax.Array, group_size: int, filter_size: int = 1,
    refit_scale: bool = False, fmt: str = None,
) -> jax.Array:
    """Sec. 4 forward: Algorithm-1 ternarized weights, identity gradient.
    ``fmt`` threads through to the registry exactly like ``weights_ste`` so a
    registered ternary-width format trains on its own grid, not the default."""
    return weights_ste(w, 2, group_size, filter_size, refit_scale, fmt=fmt)


@functools.lru_cache(maxsize=None)
def _ttq_ste_fn(group_size: int, threshold: float):
    @jax.custom_vjp
    def fq(w, wpn):
        wq, _ = _ttq_apply(w, wpn, group_size, threshold)
        return wq

    def fwd(w, wpn):
        wq, res = _ttq_apply(w, wpn, group_size, threshold)
        return wq, res

    def bwd(res, g):
        pos, neg, sq, sgn = res
        k, n = g.shape
        gb = g.reshape(k // group_size, group_size, n)
        pb = pos.reshape(k // group_size, group_size, n)
        nb = neg.reshape(k // group_size, group_size, n)
        # TTQ rule (arxiv 1612.01064 eq. 5-6): scale grads are the partition
        # sums; the latent-weight grad is scaled by the cluster magnitude on
        # its partition and identity in the deadzone.
        dwp = jnp.sum(gb * pb, axis=1)  # (G, N)
        dwn = -jnp.sum(gb * nb, axis=1)
        dwpn = jnp.stack([dwp, dwn], axis=0) * sgn  # chain through |wpn|
        dw = gb * (pb * sq[0][:, None, :] + nb * sq[1][:, None, :]
                   + (1.0 - pb - nb))
        return dw.reshape(k, n), dwpn

    fq.defvjp(fwd, bwd)
    return fq


def _ttq_apply(w, wpn, group_size, threshold):
    """Shared forward: ternary codes from the master weights, cluster
    magnitudes from the trained Wp/Wn — fake-quantized through the SAME DFP
    scale-table path deployment uses, so the training grid is the serving
    grid bit for bit."""
    from repro.quant.formats import ttq_partition  # lazy: avoids cycle

    k, n = w.shape
    codes = jax.lax.stop_gradient(
        ttq_partition(w, group_size, threshold).astype(jnp.float32)
    )
    cb = codes.reshape(k // group_size, group_size, n)
    pos = (cb > 0).astype(jnp.float32)
    neg = (cb < 0).astype(jnp.float32)
    mag = jnp.abs(jax.lax.stop_gradient(wpn))  # (2, G, N)
    sm, se = quantizer.quantize_scales(mag.reshape(-1, n))
    sq = quantizer.dequantize_scales(sm, se).reshape(mag.shape)
    wq = pos * sq[0][:, None, :] - neg * sq[1][:, None, :]
    res = (pos.reshape(k, n), neg.reshape(k, n), sq,
           jnp.sign(jax.lax.stop_gradient(wpn)))
    return wq.reshape(k, n), res


def ttq_ste(w: jax.Array, wpn: jax.Array, group_size: int,
            threshold: float = None) -> jax.Array:
    """Trained Ternary Quantization forward/backward (arxiv 1612.01064).

    w   : (K, N) fp32 master weights — partitioned into {-1, 0, +1} codes
          per cluster with the relative threshold.
    wpn : (2, G, N) trained scale magnitudes — wpn[0] is Wp, wpn[1] is Wn.
    Returns the fake-quantized (K, N) weights; gradients flow to BOTH inputs
    under the sign-partitioned TTQ rule.
    """
    from repro.quant.formats import TTQ_THRESHOLD

    t = TTQ_THRESHOLD if threshold is None else threshold
    return _ttq_ste_fn(group_size, float(t))(w, wpn)


def inq_freeze(w: jax.Array, mask: jax.Array,
               live: jax.Array = None) -> jax.Array:
    """INQ (arxiv 1702.03044), paper-original forward: frozen coordinates
    (mask > 0) carry their already-quantized value with NO gradient; the rest
    trains through ``live`` (defaults to the raw fp weights).  The QAT layer
    path uses ``inq_ste`` instead -- the learned-grid variant below -- but
    this primitive stays as the building block for the paper's recipe."""
    live = w if live is None else live
    return jnp.where(mask > 0, jax.lax.stop_gradient(w), live)


@functools.lru_cache(maxsize=None)
def _inq_ste_fn(bits: int, group_size: int, filter_size: int, refit: bool,
                fmt):
    from repro.quant.formats import dequantize_weights, quantize_weights

    def apply(w, s):
        """Fake-quantize ``w`` onto the externally-supplied cluster grid
        ``s`` through the SAME registry path deployment uses
        (``quantize_weights(scales=...)``), so codes and values match the
        served artifact bit for bit."""
        mag = jnp.abs(s)
        qt = quantize_weights(
            w, bits, group_size, filter_size, refit, fmt=fmt, scales=mag
        )
        deq = dequantize_weights(qt).astype(jnp.float32)
        sq = quantizer.dequantize_scales(qt.scale_m, qt.scale_e)
        safe = jnp.where(sq > 0, sq, 1.0)
        k, n = w.shape
        codes = (deq.reshape(k // group_size, group_size, n)
                 / safe[:, None, :]).reshape(k, n)
        return deq, codes

    @jax.custom_vjp
    def fq(w, mask, s):
        deq, _ = apply(w, s)
        return deq

    def fwd(w, mask, s):
        deq, codes = apply(w, s)
        return deq, (mask, codes, jnp.sign(s))

    def bwd(res, g):
        mask, codes, sgn = res
        k, n = g.shape
        # live coords: identity STE to the master weights; frozen: zero
        dw = g * (1.0 - (mask > 0).astype(jnp.float32))
        # learned-grid rule (TTQ generalized to any code set): the scale
        # gradient of each cluster is the code-weighted gradient sum over
        # ALL its coordinates -- frozen codes keep steering the grid
        ds = jnp.sum(
            (g * codes).reshape(k // group_size, group_size, n), axis=1
        ) * sgn  # chain through |s|
        return dw, jnp.zeros_like(mask), ds

    fq.defvjp(fwd, bwd)
    return fq


def inq_ste(w: jax.Array, mask: jax.Array, scales: jax.Array, bits: int,
            group_size: int, filter_size: int = 1, refit_scale: bool = False,
            fmt: str = None) -> jax.Array:
    """Learned-grid INQ forward/backward (arxiv 1702.03044 + trained scales).

    The whole tensor fake-quantizes onto the TRAINED cluster grid ``scales``
    (codes re-derived from ``w / s`` every step, exactly how deployment
    derives them), so the grid itself keeps adapting by gradient while INQ
    events progressively stop ``w`` updates via ``mask``.  This is the
    honest synthesis of the two papers this module implements: INQ freezes
    codes, TTQ trains magnitudes -- a plain re-fit grid (QAT) gets neither.

    w      : (K, N) fp32 master weights
    mask   : (K, N) f32, 1.0 = frozen (no gradient to that coordinate)
    scales : (G, N) f32 trainable cluster scales (``inq_mask``'s sibling
             ``inq_scales`` leaf)
    """
    return _inq_ste_fn(bits, group_size, filter_size, refit_scale, fmt)(
        w, mask, scales
    )


def act_ste(x: jax.Array, bits: int = 8, per_row: bool = False,
            exponent: int = None) -> jax.Array:
    """8-bit DFP activation fake-quant with *clipped* STE: gradient is zero
    outside the representable range (the clip carries the gradient), identity
    inside (rounding is straight-through).

    With the default dynamic exponent the clip never binds (the range is fit
    to max|x| every call); pass a static ``exponent`` — e.g. a calibrated
    per-site exponent from the deployment plan — to train against a FIXED
    range whose clip gradient is real."""
    if bits >= 16:
        return x
    if exponent is None:
        max_abs = jnp.max(jnp.abs(jax.lax.stop_gradient(x)))
        e = dfp.choose_exponent(max_abs, bits)
    else:
        e = jnp.asarray(exponent, jnp.int32)
    r = dfp.qmax(bits) * dfp.exp2i(e)
    xc = jnp.clip(x, -r, r)
    if exponent is None:
        q = calibration.fake_quantize_act(xc, bits, per_row)
    else:
        q = dfp.dequantize(dfp.quantize(xc, e, bits), e)
    return ste(xc, q)
