"""Operation accounting: reproduces the paper's Sec. 3.3 performance model.

With cluster size N, each reduction segment of N*K^2 ternary accumulations
costs exactly one 8-bit scale multiplication.  The fraction of baseline
multiplications replaced by accumulations in one conv is therefore

    replaced(conv) = 1 - 1 / (N * K^2)

and for a network it is the MAC-weighted average.  We provide
  * the exact ResNet-101 inventory (to check the paper's ~85% @ N=4 and
    ~98% @ N=64 claims),
  * the paper's own "50% of convs are 3x3" approximation, and
  * the transformer-GEMM analogue (K^2 == 1, segment = group_size), used by
    the per-arch benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    cin: int
    cout: int
    k: int
    hw: int  # output spatial extent (H == W)

    @property
    def macs(self) -> int:
        return self.hw * self.hw * self.cout * self.cin * self.k * self.k


@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """One projection GEMM: reduction K, output N, ``calls`` per token."""

    name: str
    k: int
    n: int
    calls: float = 1.0
    weight_quantized: bool = True

    @property
    def macs_per_token(self) -> float:
        return self.k * self.n * self.calls


def conv_replaced_fraction(spec: ConvSpec, cluster: int) -> float:
    return 1.0 - 1.0 / (cluster * spec.k * spec.k)


def network_replaced_fraction(specs: Sequence[ConvSpec], cluster: int) -> float:
    total = sum(s.macs for s in specs)
    repl = sum(s.macs * conv_replaced_fraction(s, cluster) for s in specs)
    return repl / total


def paper_approximation(cluster: int) -> float:
    """Sec. 3.3: 'roughly 50% of the convolutions are 3x3 and the rest 1x1'."""
    return 0.5 * (1 - 1 / (cluster * 9)) + 0.5 * (1 - 1 / cluster)


def resnet101_specs(image: int = 224) -> List[ConvSpec]:
    """Exact conv inventory of ResNet-101 (bottleneck v1, ImageNet)."""
    specs = [ConvSpec(3, 64, 7, image // 2)]  # conv1 (pinned to 8-bit by policy)
    stage_cfg = [  # (blocks, width, out, spatial)
        (3, 64, 256, image // 4),
        (4, 128, 512, image // 8),
        (23, 256, 1024, image // 16),
        (3, 512, 2048, image // 32),
    ]
    cin = 64
    for blocks, width, cout, hw in stage_cfg:
        for b in range(blocks):
            specs.append(ConvSpec(cin if b == 0 else cout, width, 1, hw))
            specs.append(ConvSpec(width, width, 3, hw))
            specs.append(ConvSpec(width, cout, 1, hw))
            if b == 0:  # projection shortcut
                specs.append(ConvSpec(cin, cout, 1, hw))
        cin = cout
    return specs


def gemm_replaced_fraction(group_size: int) -> float:
    """Transformer projection: K^2==1, segment length == group_size."""
    return 1.0 - 1.0 / group_size


def network_gemm_stats(
    gemms: Sequence[GemmSpec], group_size: int
) -> Tuple[float, float, float]:
    """Returns (total MACs/token, replaced fraction over weight GEMMs,
    replaced fraction over ALL MACs incl. attention int8 GEMMs)."""
    total = sum(g.macs_per_token for g in gemms)
    wq = [g for g in gemms if g.weight_quantized]
    wq_total = sum(g.macs_per_token for g in wq)
    repl = wq_total * gemm_replaced_fraction(group_size)
    return total, (repl / wq_total if wq_total else 0.0), repl / total


def weight_bytes(
    gemms: Sequence[GemmSpec], w_bits: int, group_size: int, scale_bits: int = 8
) -> float:
    """HBM bytes to stream all quantized weights once (decode-phase cost):
    packed mantissas + per-(group, out) scale mantissas + exponents."""
    total = 0.0
    for g in gemms:
        if not g.weight_quantized:
            continue
        mant = g.k * g.n * w_bits / 8.0
        scales = (g.k / group_size) * g.n * scale_bits / 8.0
        total += (mant + scales) * max(g.calls, 1.0 if g.calls >= 1 else g.calls)
    return total
