"""Activation calibration: the paper's 8-bit DFP activations + BN-recompute
analogue.

The paper profiles activations to pick per-tensor shared exponents (dynamic
fixed point), and *recomputes BatchNorm statistics* after quantization to
compensate for the variance shift.  Modern LM blocks use RMSNorm without
running statistics, so the analogue implemented here is:

  1. ``Observer`` state records per-site max|x| (and mean square) over
     calibration batches; ``finalize`` turns them into shared exponents.
  2. ``recalibrate_gamma`` rescales a norm's gain by the ratio of
     full-precision to quantized activation RMS at the same site -- the same
     first-moment correction BN re-estimation performs.

Observer state is a plain dict pytree: {site: {"max_abs": f32, "msq": f32,
"count": f32}} so it jits, shards and checkpoints like any other state.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import dfp

ObserverState = Dict[str, Dict[str, jax.Array]]


def init_observer() -> ObserverState:
    return {}


def observe(state: ObserverState, site: str, x: jax.Array) -> ObserverState:
    """Record one batch at ``site`` (functional update)."""
    entry = state.get(
        site,
        {
            "max_abs": jnp.zeros((), jnp.float32),
            "msq": jnp.zeros((), jnp.float32),
            "count": jnp.zeros((), jnp.float32),
        },
    )
    new = {
        "max_abs": jnp.maximum(entry["max_abs"], jnp.max(jnp.abs(x))),
        "msq": entry["msq"] + jnp.mean(jnp.square(x.astype(jnp.float32))),
        "count": entry["count"] + 1.0,
    }
    out = dict(state)
    out[site] = new
    return out


def finalize(state: ObserverState, bits: int = 8) -> Dict[str, jax.Array]:
    """Per-site shared exponents from recorded ranges."""
    return {
        site: dfp.choose_exponent(entry["max_abs"], bits)
        for site, entry in state.items()
    }


def quantize_act(x: jax.Array, e: jax.Array, bits: int = 8) -> jax.Array:
    """Static (calibrated-exponent) activation quantization -> int8."""
    return dfp.quantize(x, e, bits)


def dynamic_quantize_act(x: jax.Array, bits: int = 8, per_row: bool = False):
    """Per-batch dynamic quantization (no calibration pass needed).

    per_row=True keeps one exponent per leading-axis row (per-token): tighter
    ranges for long-context decode where token norms drift.
    Returns (mantissa int8, exponent int32).
    """
    axis = tuple(range(1, x.ndim)) if per_row else None
    if axis is None:
        max_abs = jnp.max(jnp.abs(x))
    else:
        max_abs = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    e = dfp.choose_exponent(max_abs, bits)
    return dfp.quantize(x, e, bits), e


def fake_quantize_act(x: jax.Array, bits: int = 8, per_row: bool = False) -> jax.Array:
    q, e = dynamic_quantize_act(x, bits, per_row)
    return dfp.dequantize(q, e)


def recalibrate_gamma(
    gamma: jax.Array, rms_fp: jax.Array, rms_q: jax.Array, eps: float = 1e-6
) -> jax.Array:
    """BN-recompute analogue: rescale norm gain so the quantized activation
    RMS matches the full-precision one at the same site.

    ``rms_fp``/``rms_q`` are true root-mean-squares (what
    ``rms_from_observer`` returns), so the correction is their plain ratio:
    scaling activations by c scales their RMS by c, and the gain must absorb
    exactly rms_fp / rms_q to undo the shift.  (A previous revision took
    sqrt of the ratio here while ``rms_from_observer`` returned the *mean
    square* -- internally consistent, but any caller passing a true RMS got
    a half-strength correction.  Both ends now speak RMS.)
    """
    return gamma * (rms_fp + eps) / (rms_q + eps)


def rms_from_observer(state: ObserverState, site: str) -> jax.Array:
    """True RMS at ``site``: sqrt of the batch-averaged mean square."""
    entry = state[site]
    return jnp.sqrt(entry["msq"] / jnp.maximum(entry["count"], 1.0))
