"""Dynamic Fixed Point (DFP) representation.

The paper represents every quantity in the integer pipeline as a block of
integer mantissas sharing a single power-of-two exponent ("fractional
length"):   x  ≈  q * 2**e,   q ∈ [-(2**(b-1)-1), 2**(b-1)-1].

We keep the exponent as a plain int32 (one per tensor / per cluster axis) and
mantissas as int8 (b<=8) regardless of nominal bit-width; sub-8-bit mantissas
are range-limited and packed separately (see quantizer.py).

All functions are pure jnp and jit/vmap-safe.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# Symmetric integer range for b-bit two's complement, excluding -2**(b-1) so
# that negation is closed (the paper's fixed-point pipeline is symmetric).
def qmax(bits: int) -> int:
    return 2 ** (bits - 1) - 1


def exp2i(e) -> jax.Array:
    """Exact ``2.0**e`` for integer-valued exponents, by bit construction.

    ``jnp.exp2`` lowers to a polynomial approximation on some backends
    (XLA:CPU's vectorizer is 1 ulp off even at integer arguments) -- fatal
    for DFP, where every scale is *by definition* an exact power of two.
    Builds the f32 directly from the exponent field instead.  Accepts int or
    integer-valued float arrays; exponents clamp to the normal-f32 range
    [-126, 127] (DFP exponents in this codebase stay well inside it).
    """
    ei = jnp.clip(jnp.asarray(e).astype(jnp.int32), -126, 127)
    return jax.lax.bitcast_convert_type((ei + 127) << 23, jnp.float32)


def choose_exponent(max_abs: jax.Array, bits: int) -> jax.Array:
    """Smallest power-of-two exponent e with max_abs <= qmax(bits) * 2**e.

    e = ceil(log2(max_abs / qmax)).  max_abs == 0 maps to e = 0.
    Returns int32 with the same shape as ``max_abs``.
    """
    m = jnp.asarray(max_abs, jnp.float32)
    safe = jnp.maximum(m, jnp.finfo(jnp.float32).tiny)
    e = jnp.ceil(jnp.log2(safe / qmax(bits))).astype(jnp.int32)
    return jnp.where(m > 0, e, jnp.zeros_like(e))


def quantize(x: jax.Array, e: jax.Array, bits: int) -> jax.Array:
    """Round-to-nearest-even mantissas for exponent ``e`` (broadcasts)."""
    scale = exp2i(-jnp.asarray(e).astype(jnp.int32))
    q = jnp.clip(jnp.round(x * scale), -qmax(bits), qmax(bits))
    return q.astype(jnp.int8 if bits <= 8 else jnp.int32)


def dequantize(q: jax.Array, e: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * exp2i(e)


def quantize_tensor(x: jax.Array, bits: int, axis: Optional[tuple] = None):
    """Per-tensor (axis=None) or per-axis DFP quantization.

    Returns (mantissa, exponent).  ``axis`` lists the *reduced* axes, i.e.
    the exponent is shared across them and kept per remaining axes.
    """
    if axis is None:
        max_abs = jnp.max(jnp.abs(x))
    else:
        max_abs = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    e = choose_exponent(max_abs, bits)
    return quantize(x, e, bits), e


def fake_quantize(x: jax.Array, bits: int, axis: Optional[tuple] = None) -> jax.Array:
    """quantize->dequantize in one step (QAT forward, eval error metrics)."""
    q, e = quantize_tensor(x, bits, axis)
    return dequantize(q, e)


@dataclasses.dataclass(frozen=True)
class DfpSpec:
    """Static description of a DFP tensor (used by policy / kernels)."""

    bits: int = 8
    # exponent granularity: 'tensor' | 'channel' (last axis) | 'row' (first)
    granularity: str = "tensor"

    def exponent_axes(self, ndim: int) -> Optional[tuple]:
        if self.granularity == "tensor":
            return None
        if self.granularity == "channel":
            return tuple(range(ndim - 1))
        if self.granularity == "row":
            return tuple(range(1, ndim))
        raise ValueError(self.granularity)


def quantization_error(x: jax.Array, bits: int, axis: Optional[tuple] = None) -> jax.Array:
    """||x - dequant(quant(x))||_F^2 — the paper's loss metric."""
    return jnp.sum((x - fake_quantize(x, bits, axis)) ** 2)
