"""Distribution: sharding rules and collective helpers."""
from repro.parallel.sharding import (
    batch_shardings,
    cache_shardings,
    param_shardings,
    param_spec,
    qtensor_shardings,
    qtensor_spec,
)
