"""Sharding rules: path-pattern -> PartitionSpec over the production mesh.

Two regimes:

  * mode="train": 2-D FSDP x TP sharding.  Projection weights shard the
    contraction dim over 'data' (ZeRO-3 style, all-gathered per layer inside
    the layer scan, which XLA overlaps with the previous layer's compute)
    and the output dim over 'model' (Megatron pairing: qkv/up N-sharded,
    wo/down K-sharded so no resharding between the paired GEMMs).
    Optimizer state inherits the same specs.

  * mode="serve": pure TP over 'model'; weights replicated over 'data'
    (each data row serves independent requests => zero weight collectives
    per decode step, the right trade for a bandwidth-bound phase).  QTensor
    fields (packed mantissas + scale tables) shard exactly like the dense
    weight they replace; cluster scale tables never straddle shards because
    group_size divides the per-shard K.

Every axis assignment is divisibility-checked against the mesh, falling back
to replication (e.g. 8 KV heads on a 16-wide model axis -> replicated, as
Megatron does).  The MoE expert axis shards over 'model' when divisible
(expert parallelism), else experts stay replicated and the per-expert FFN
dims shard instead.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# projection name -> (contraction-dim role, output-dim role)
_N_SHARDED = ("wq", "wk", "wv", "up", "gate", "in_proj", "bc_proj", "dt_proj", "lm_head")
_K_SHARDED = ("wo", "down", "out_proj", "x_proj")


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "name"):
            parts.append(str(e.name))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
    return "/".join(parts)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _fit(mesh: Mesh, dim: int, axis: Optional[str]) -> Optional[str]:
    """axis if it exists and divides dim, else None (replicate)."""
    if axis is None or axis not in mesh.shape:
        return None
    return axis if dim % mesh.shape[axis] == 0 else None


def _proj_spec(path: str, shape, mesh: Mesh, mode: str) -> P:
    """Spec for a projection leaf ('w', 'packed' or 'scale_m'): the last two
    dims are (K-like, N); leading dims are layer/expert stacks."""
    k_dim, n_dim = shape[-2], shape[-1]
    name_hit = lambda names: any(re.search(rf"(^|/){n}(/|$)", path) for n in names)
    if name_hit(_K_SHARDED):
        tp_on_k = True
    elif name_hit(_N_SHARDED):
        tp_on_k = False
    else:
        tp_on_k = False

    if mode == "serve":
        fsdp = None
    else:
        fsdp = "data"

    if tp_on_k:
        k_ax = _fit(mesh, k_dim, "model")
        n_ax = _fit(mesh, n_dim, fsdp)
    else:
        k_ax = _fit(mesh, k_dim, fsdp)
        n_ax = _fit(mesh, n_dim, "model")

    lead: list = [None] * (len(shape) - 2)
    # expert stacks: shard the expert axis over 'model' when divisible (EP)
    if "experts" in path and len(shape) >= 3:
        e_dim = shape[-3]
        ep = _fit(mesh, e_dim, "model")
        if ep is not None:
            lead[-1] = ep
            # model axis consumed by EP -> drop TP on the inner dims
            if k_ax == "model":
                k_ax = None
            if n_ax == "model":
                n_ax = None
    return P(*lead, k_ax, n_ax)


def _vector_spec(path: str, shape, mesh: Mesh) -> P:
    """1-D-ish params (norm scales, biases, conv, A_log...): replicate."""
    return P(*([None] * len(shape)))


def param_spec(path: str, leaf, mesh: Mesh, mode: str) -> P:
    shape = leaf.shape
    if re.search(r"(^|/)(table)$", path):  # embedding (V, d): vocab over model
        v_ax = _fit(mesh, shape[0], "model")
        d_ax = _fit(mesh, shape[1], "data") if mode == "train" else None
        return P(v_ax, d_ax)
    if re.search(r"(^|/)(enc_pos|dec_pos)$", path):
        return P(None, None)
    if path.endswith("/w") or path.endswith("/packed") or path.endswith("/scale_m"):
        if len(shape) >= 2:
            return _proj_spec(path, shape, mesh, mode)
    if path.endswith("/scale_e") or leaf.ndim == 0:
        return P()
    return _vector_spec(path, shape, mesh)


def param_shardings(params_shapes: Any, mesh: Mesh, mode: str = "train"):
    """Pytree of NamedSharding matching ``params_shapes`` (from eval_shape)."""

    def spec(path, leaf):
        return NamedSharding(mesh, param_spec(_path_str(path), leaf, mesh, mode))

    return jax.tree_util.tree_map_with_path(spec, params_shapes)


def opt_shardings(opt_shapes: Any, mesh: Mesh, mode: str = "train"):
    """Optimizer-state shardings: moments inherit the owning param's spec
    (ZeRO: m/v sharded exactly like the weight); per-row exponents drop the
    last axis; the step counter is replicated."""

    def spec(path, leaf):
        p = _path_str(path)
        if p == "step":
            return NamedSharding(mesh, P())
        # paths look like m/<param path>/q | m/<param path>/e | m/<param path>
        parts = p.split("/")
        core = "/".join(parts[1:])
        if core.endswith("/q"):
            base = param_spec(core[:-2], leaf, mesh, mode)
            return NamedSharding(mesh, base)
        if core.endswith("/e"):
            # exponent: same leading spec, last axis (size 1) replicated
            fake = jax.ShapeDtypeStruct(leaf.shape[:-1] + (1,), leaf.dtype)
            base = param_spec(core[:-2], fake, mesh, mode)
            return NamedSharding(mesh, P(*(list(base)[: leaf.ndim - 1] + [None])))
        return NamedSharding(mesh, param_spec(core, leaf, mesh, mode))

    return jax.tree_util.tree_map_with_path(spec, opt_shapes)


# ---------------------------------------------------------------------------
# Activation sharding constraints (perf lever; see EXPERIMENTS.md Sec. Perf)
# ---------------------------------------------------------------------------
# Model code is mesh-agnostic; launchers install the ambient mesh here and
# `constrain` becomes a with_sharding_constraint with divisibility checks.
# Logical axes: "batch" -> (pod, data);  "seq"/"feat"/"expert" -> model.
_ACT_MESH: list = [None]

# Perf iteration C4 toggle (see EXPERIMENTS.md): flash-decoding-style
# sequence sharding for GQA caches whose head count does not divide TP.
KV_SEQ_SHARD: list = [True]


def set_activation_mesh(mesh: Optional[Mesh]) -> None:
    _ACT_MESH[0] = mesh


def constrain(x, logical_axes) -> Any:
    """Apply a sharding constraint if an activation mesh is installed.

    logical_axes: tuple like ("batch", "seq", None); axes that do not divide
    the corresponding dim fall back to replicated.
    """
    mesh = _ACT_MESH[0]
    if mesh is None:
        return x
    names = []
    for dim, ax in zip(x.shape, logical_axes):
        if ax == "batch":
            cand = batch_axes(mesh)
            if cand is not None:
                total = 1
                for a in cand:
                    total *= mesh.shape[a]
                cand = cand if dim % total == 0 else None
            names.append(cand)
        elif ax in ("seq", "feat", "expert", "heads"):
            names.append(_fit(mesh, dim, "model"))
        else:
            names.append(None)
    names += [None] * (x.ndim - len(names))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*names)))


# ---------------------------------------------------------------------------
# Data / cache shardings
# ---------------------------------------------------------------------------
def batch_axes(mesh: Mesh):
    """Logical batch axis = all data-parallel mesh axes."""
    names = [n for n in ("pod", "data") if n in mesh.shape]
    return tuple(names) if names else None


def batch_shardings(batch_shapes: Any, mesh: Mesh):
    """Shard the leading (batch) axis of every input over pod+data."""
    baxes = batch_axes(mesh)

    def spec(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        if p.endswith("positions") and len(shape) == 3:  # (3, B, S)
            return NamedSharding(mesh, P(None, baxes, None))
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        b_dim = shape[0]
        ax = baxes
        if ax is not None:
            total = 1
            for a in ax:
                total *= mesh.shape[a]
            if b_dim % total != 0:
                ax = None
        return NamedSharding(mesh, P(ax, *([None] * (len(shape) - 1))))

    return jax.tree_util.tree_map_with_path(spec, batch_shapes)


def cache_shardings(cache_shapes: Any, mesh: Mesh):
    """KV caches (L, B, S, Kh, hd) and SSM states (L, B, ...): batch over
    pod+data, kv-heads over model when divisible."""
    baxes = batch_axes(mesh)

    def divisible(dim):
        if baxes is None:
            return False
        total = 1
        for a in baxes:
            total *= mesh.shape[a]
        return dim % total == 0

    def spec(path, leaf):
        shape = leaf.shape
        p = _path_str(path)
        if p.endswith("enc_out") and len(shape) == 3:  # (B, T, d)
            return NamedSharding(mesh, P(baxes if divisible(shape[0]) else None, None, None))
        if len(shape) == 5:  # (L, B, S, Kh, hd)
            bax = baxes if divisible(shape[1]) else None
            # batch=1 long-context: shard the sequence over the data axes
            sax = None if bax else (baxes if divisible(shape[2]) else None)
            kh = _fit(mesh, shape[3], "model")
            # GQA caches whose kv-head count does not divide the TP width:
            # shard the SEQUENCE over 'model' (flash-decoding style: scores
            # and PV partials reduce across shards; the cache itself never
            # moves).  Sharding hd instead makes the partitioner all-gather
            # the converted f32 cache -- 1 GiB/step on qwen1.5 x decode_32k
            # (Perf iteration C4).
            s_model = None
            if KV_SEQ_SHARD[0] and kh is None and sax is None:
                s_model = _fit(mesh, shape[2], "model")
            hd = None if (kh or s_model) else _fit(mesh, shape[4], "model")
            return NamedSharding(mesh, P(None, bax, s_model or sax, kh, hd))
        if len(shape) >= 2:
            # stacked ssm states (L, B, ...): feature axis over model if possible
            bax = baxes if divisible(shape[1]) else None
            rest = [None] * (len(shape) - 2)
            if len(shape) >= 3:
                rest[0] = _fit(mesh, shape[2], "model")
            return NamedSharding(mesh, P(None, bax, *rest))
        return NamedSharding(mesh, P(*([None] * len(shape))))

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)
