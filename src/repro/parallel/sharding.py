"""Sharding rules: path-pattern -> PartitionSpec over the production mesh.

Two regimes:

  * mode="train": 2-D FSDP x TP sharding.  Projection weights shard the
    contraction dim over 'data' (ZeRO-3 style, all-gathered per layer inside
    the layer scan, which XLA overlaps with the previous layer's compute)
    and the output dim over 'model' (Megatron pairing: qkv/up N-sharded,
    wo/down K-sharded so no resharding between the paired GEMMs).
    Optimizer state inherits the same specs.

  * mode="serve": pure TP over 'model'; weights replicated over 'data'
    (each data row serves independent requests => zero weight collectives
    per decode step, the right trade for a bandwidth-bound phase).  QTensor
    fields (packed mantissas + scale tables) shard exactly like the dense
    weight they replace; cluster scale tables never straddle shards because
    group_size divides the per-shard K.

Every axis assignment is divisibility-checked against the mesh, falling back
to replication (e.g. 8 KV heads on a 16-wide model axis -> replicated, as
Megatron does).  The MoE expert axis shards over 'model' when divisible
(expert parallelism), else experts stay replicated and the per-expert FFN
dims shard instead.

QTensor leaves are first-class: ``param_spec`` dispatches on the *logical*
(K, N) shape a QTensor carries -- not the packed payload shape, whose K dim
is divided by the words-per-uint32 packing factor (16 for ternary, 8 for
int4 and nf4, 1 for raw-int8 storage: int8 and mx) -- and
``qtensor_shardings`` expands the one logical decision into consistent
per-field specs: the packed payload inherits the weight spec (packing
preserves which dim is which), the scale table follows its cluster
(K/group) axis (mx: the 32-element block axis), and the shared exponent
replicates.  A K assignment is taken only when the mesh axis divides the
logical K *and* the packed K *and* the scale-table K -- otherwise the whole
QTensor falls back together, so payload and scales can never disagree about
their layout.  Everything is derived from the QTensor's own shapes, so a
newly registered format (nf4, mx) shards correctly with no rule changes.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.quantizer import QTensor

# projection name -> (contraction-dim role, output-dim role)
_N_SHARDED = ("wq", "wk", "wv", "up", "gate", "in_proj", "bc_proj", "dt_proj", "lm_head")
_K_SHARDED = ("wo", "down", "out_proj", "x_proj")


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "name"):
            parts.append(str(e.name))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
    return "/".join(parts)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _fit(mesh: Mesh, dim: int, axis: Optional[str]) -> Optional[str]:
    """axis if it exists and divides dim, else None (replicate)."""
    if axis is None or axis not in mesh.shape:
        return None
    return axis if dim % mesh.shape[axis] == 0 else None


def _fit_all(mesh: Mesh, dims, axis: Optional[str]) -> Optional[str]:
    """axis if it divides EVERY dim in ``dims`` (a logical dim plus its packed
    and scale-table projections), else None -- the QTensor fields fall back to
    replication together rather than disagreeing about their layout."""
    if axis is None or axis not in mesh.shape:
        return None
    a = mesh.shape[axis]
    return axis if all(d % a == 0 for d in dims) else None


def _proj_spec(path: str, shape, mesh: Mesh, mode: str, k_dims=None) -> P:
    """Spec for a projection leaf ('w', 'packed' or 'scale_m'): the last two
    dims are (K-like, N); leading dims are layer/expert stacks.

    ``k_dims``: extra dims that must also divide for a K-axis assignment to
    hold (a QTensor's packed K/words and scale-table K/group rows)."""
    k_dim, n_dim = shape[-2], shape[-1]
    name_hit = lambda names: any(re.search(rf"(^|/){n}(/|$)", path) for n in names)
    if name_hit(_K_SHARDED):
        tp_on_k = True
    elif name_hit(_N_SHARDED):
        tp_on_k = False
    else:
        tp_on_k = False

    if mode == "serve":
        fsdp = None
    else:
        fsdp = "data"

    k_all = (k_dim,) + tuple(k_dims or ())
    if tp_on_k:
        k_ax = _fit_all(mesh, k_all, "model")
        n_ax = _fit(mesh, n_dim, fsdp)
    else:
        k_ax = _fit_all(mesh, k_all, fsdp)
        n_ax = _fit(mesh, n_dim, "model")

    lead: list = [None] * (len(shape) - 2)
    # expert stacks: shard the expert axis over 'model' when divisible (EP)
    if "experts" in path and len(shape) >= 3:
        e_dim = shape[-3]
        ep = _fit(mesh, e_dim, "model")
        if ep is not None:
            lead[-1] = ep
            # model axis consumed by EP -> drop TP on the inner dims
            if k_ax == "model":
                k_ax = None
            if n_ax == "model":
                n_ax = None
    return P(*lead, k_ax, n_ax)


def _vector_spec(path: str, shape, mesh: Mesh) -> P:
    """1-D-ish params (norm scales, biases, conv, A_log...): replicate."""
    return P(*([None] * len(shape)))


def _qt_logical_shape(qt: QTensor) -> Tuple[int, ...]:
    """Full logical shape of a (possibly stacked) QTensor: the packed
    payload's leading layer/expert stack dims + the logical (K, N)."""
    return tuple(qt.packed.shape[:-2]) + tuple(qt.shape)


def _qt_words_per_k(qt: QTensor) -> int:
    """K rows per packed payload row (16 ternary, 8 int4/nf4, 1 for raw
    int8 storage: int8 and mx) -- derived from the payload shape itself so
    registered formats need no table here."""
    return max(1, qt.k // qt.packed.shape[-2])


def qtensor_spec(path: str, qt: QTensor, mesh: Mesh, mode: str) -> P:
    """Logical-weight spec for a QTensor leaf.

    The decision runs on the shape the QTensor *represents* (stack dims +
    (K, N)), not the packed payload shape, with the extra constraint that a
    K-axis assignment must also divide the packed (K/words) and scale-table
    (K/group) projections of K -- int4 payloads halve K, ternary payloads
    divide it by 16, and the scale table divides it by group_size, so a
    divisibility check against any single field's shape is wrong for the
    other two."""
    shape = _qt_logical_shape(qt)
    k = qt.k
    k_dims = (k // _qt_words_per_k(qt), k // qt.group_size)
    return _proj_spec(path, shape, mesh, mode, k_dims=k_dims)


def qtensor_field_shardings(
    path: str, qt: QTensor, mesh: Mesh, mode: str
) -> QTensor:
    """Expand one logical QTensor spec into consistent per-field shardings.

    Returns a QTensor whose data fields hold NamedShardings (same static
    meta, so it is treedef-compatible with the value tree for device_put /
    jit in_shardings): the packed payload inherits the weight spec verbatim
    (packing preserves dim identity), the scale table follows its cluster
    (K/group) axis, and the shared exponent replicates."""
    spec = qtensor_spec(path, qt, mesh, mode)
    return QTensor(
        packed=NamedSharding(mesh, spec),
        scale_m=NamedSharding(mesh, spec),
        scale_e=NamedSharding(mesh, P()),
        bits=qt.bits, group_size=qt.group_size, shape=tuple(qt.shape),
        fmt=qt.fmt,
    )


def _is_qtensor(leaf) -> bool:
    return isinstance(leaf, QTensor)


def param_spec(path: str, leaf, mesh: Mesh, mode: str) -> P:
    if isinstance(leaf, QTensor):
        return qtensor_spec(path, leaf, mesh, mode)
    shape = leaf.shape
    if re.search(r"(^|/)(table)$", path):  # embedding (V, d): vocab over model
        v_ax = _fit(mesh, shape[0], "model")
        d_ax = _fit(mesh, shape[1], "data") if mode == "train" else None
        return P(v_ax, d_ax)
    if re.search(r"(^|/)(enc_pos|dec_pos)$", path):
        return P(None, None)
    if path.endswith("/w") or path.endswith("/packed") or path.endswith("/scale_m"):
        if len(shape) >= 2:
            return _proj_spec(path, shape, mesh, mode)
    if path.endswith("/scale_e") or leaf.ndim == 0:
        return P()
    return _vector_spec(path, shape, mesh)


def param_shardings(params_shapes: Any, mesh: Mesh, mode: str = "train"):
    """Pytree of NamedSharding matching ``params_shapes`` (from eval_shape).

    QTensor nodes are treated whole: the logical-shape decision is made once
    per site and expanded into per-field shardings, so the packed payload
    and its scale table always agree (flattening them into independent
    leaves let their divisibility checks diverge)."""

    def spec(path, leaf):
        p = _path_str(path)
        if isinstance(leaf, QTensor):
            return qtensor_field_shardings(p, leaf, mesh, mode)
        return NamedSharding(mesh, param_spec(p, leaf, mesh, mode))

    return jax.tree_util.tree_map_with_path(
        spec, params_shapes, is_leaf=_is_qtensor
    )


def qtensor_shardings(
    qparams: Any, mesh: Mesh, plan: Any = None, mode: str = "serve"
):
    """Shardings for a quantized (PTQ) param tree under ``mesh``.

    The serving-side face of ``param_shardings``: QTensor leaves get
    consistent payload/scale-table shardings from their logical shape, plain
    leaves follow the ordinary rules.  ``plan`` (a compiled QuantPlan) is
    accepted so callers can thread per-site layout overrides through one
    place; the built-in rules currently derive everything they need from the
    QTensor itself."""
    del plan  # reserved for per-site layout overrides
    return param_shardings(qparams, mesh, mode)


def opt_shardings(opt_shapes: Any, mesh: Mesh, mode: str = "train"):
    """Optimizer-state shardings: moments inherit the owning param's spec
    (ZeRO: m/v sharded exactly like the weight); per-row exponents drop the
    last axis; the step counter is replicated."""

    def spec(path, leaf):
        p = _path_str(path)
        if p == "step":
            return NamedSharding(mesh, P())
        # paths look like m/<param path>/q | m/<param path>/e | m/<param path>
        parts = p.split("/")
        core = "/".join(parts[1:])
        if core.endswith("/q"):
            base = param_spec(core[:-2], leaf, mesh, mode)
            return NamedSharding(mesh, base)
        if core.endswith("/e"):
            # exponent: same leading spec, last axis (size 1) replicated
            fake = jax.ShapeDtypeStruct(leaf.shape[:-1] + (1,), leaf.dtype)
            base = param_spec(core[:-2], fake, mesh, mode)
            return NamedSharding(mesh, P(*(list(base)[: leaf.ndim - 1] + [None])))
        return NamedSharding(mesh, param_spec(core, leaf, mesh, mode))

    return jax.tree_util.tree_map_with_path(spec, opt_shapes)


# ---------------------------------------------------------------------------
# Activation sharding constraints (perf lever; see EXPERIMENTS.md Sec. Perf)
# ---------------------------------------------------------------------------
# Model code is mesh-agnostic; launchers install the ambient mesh here and
# `constrain` becomes a with_sharding_constraint with divisibility checks.
# Logical axes: "batch" -> (pod, data);  "seq"/"feat"/"expert" -> model.
_ACT_MESH: list = [None]

# Perf iteration C4 toggle (see EXPERIMENTS.md): flash-decoding-style
# sequence sharding for GQA caches whose head count does not divide TP.
KV_SEQ_SHARD: list = [True]


def set_activation_mesh(mesh: Optional[Mesh]) -> None:
    _ACT_MESH[0] = mesh


def constrain(x, logical_axes) -> Any:
    """Apply a sharding constraint if an activation mesh is installed.

    logical_axes: tuple like ("batch", "seq", None); axes that do not divide
    the corresponding dim fall back to replicated.
    """
    mesh = _ACT_MESH[0]
    if mesh is None:
        return x
    names = []
    for dim, ax in zip(x.shape, logical_axes):
        if ax == "batch":
            cand = batch_axes(mesh)
            if cand is not None:
                total = 1
                for a in cand:
                    total *= mesh.shape[a]
                cand = cand if dim % total == 0 else None
            names.append(cand)
        elif ax in ("seq", "feat", "expert", "heads"):
            names.append(_fit(mesh, dim, "model"))
        else:
            names.append(None)
    names += [None] * (x.ndim - len(names))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*names)))


# ---------------------------------------------------------------------------
# Data / cache shardings
# ---------------------------------------------------------------------------
def batch_axes(mesh: Mesh):
    """Logical batch axis = all data-parallel mesh axes."""
    names = [n for n in ("pod", "data") if n in mesh.shape]
    return tuple(names) if names else None


def batch_shardings(batch_shapes: Any, mesh: Mesh):
    """Shard the leading (batch) axis of every input over pod+data."""
    baxes = batch_axes(mesh)

    def spec(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        if p.endswith("positions") and len(shape) == 3:  # (3, B, S)
            return NamedSharding(mesh, P(None, baxes, None))
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        b_dim = shape[0]
        ax = baxes
        if ax is not None:
            total = 1
            for a in ax:
                total *= mesh.shape[a]
            if b_dim % total != 0:
                ax = None
        return NamedSharding(mesh, P(ax, *([None] * (len(shape) - 1))))

    return jax.tree_util.tree_map_with_path(spec, batch_shapes)


def cache_shardings(cache_shapes: Any, mesh: Mesh):
    """KV caches (L, B, S, Kh, hd) and SSM states (L, B, ...): batch over
    pod+data, kv-heads over model when divisible."""
    baxes = batch_axes(mesh)

    def divisible(dim):
        if baxes is None:
            return False
        total = 1
        for a in baxes:
            total *= mesh.shape[a]
        return dim % total == 0

    def spec(path, leaf):
        shape = leaf.shape
        p = _path_str(path)
        if p.endswith("enc_out") and len(shape) == 3:  # (B, T, d)
            return NamedSharding(mesh, P(baxes if divisible(shape[0]) else None, None, None))
        if (p.endswith("ke") or p.endswith("ve")) and len(shape) == 5:
            # exponent planes (L, B, S|S/32, Kh, 1): follow the mantissa
            # buffer on batch + kv-heads, keep seq replicated (tiny leaves;
            # kv_mx's S/32 seq axis rarely divides the data axes anyway)
            bax = baxes if divisible(shape[1]) else None
            kh = _fit(mesh, shape[3], "model")
            return NamedSharding(mesh, P(None, bax, None, kh, None))
        if len(shape) == 5:  # (L, B, S, Kh, hd)
            bax = baxes if divisible(shape[1]) else None
            # batch=1 long-context: shard the sequence over the data axes
            sax = None if bax else (baxes if divisible(shape[2]) else None)
            kh = _fit(mesh, shape[3], "model")
            # GQA caches whose kv-head count does not divide the TP width:
            # shard the SEQUENCE over 'model' (flash-decoding style: scores
            # and PV partials reduce across shards; the cache itself never
            # moves).  Sharding hd instead makes the partitioner all-gather
            # the converted f32 cache -- 1 GiB/step on qwen1.5 x decode_32k
            # (Perf iteration C4).
            s_model = None
            if KV_SEQ_SHARD[0] and kh is None and sax is None:
                s_model = _fit(mesh, shape[2], "model")
            hd = None if (kh or s_model) else _fit(mesh, shape[4], "model")
            return NamedSharding(mesh, P(None, bax, s_model or sax, kh, hd))
        if len(shape) >= 2:
            # stacked ssm states (L, B, ...): feature axis over model if possible
            bax = baxes if divisible(shape[1]) else None
            rest = [None] * (len(shape) - 2)
            if len(shape) >= 3:
                rest[0] = _fit(mesh, shape[2], "model")
            return NamedSharding(mesh, P(None, bax, *rest))
        return NamedSharding(mesh, P(*([None] * len(shape))))

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)
