"""Token samplers for the serving engine."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0  # 0 => greedy
    top_k: int = 0  # 0 => full distribution


def sample(key, logits: jax.Array, cfg: SamplerConfig) -> jax.Array:
    """logits (B, V) -> token ids (B,)."""
    logits = logits.astype(jnp.float32)
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        vals, _ = jax.lax.top_k(logits, cfg.top_k)
        cut = vals[..., -1:]
        logits = jnp.where(logits < cut, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
