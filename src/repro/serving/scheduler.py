"""Staged-serving scheduler: chunked-prefill planning, stage arbitration,
admission control, per-request SLO accounting.

The staged engine (``repro.serving.engine.StagedEngine``) splits serving
into three device stages -- ``prefill`` (whole-prompt chunks through a
dedicated graph), ``insert`` (donated write of the finished prefix into a
decode-cache slot) and ``generate`` (the donated one-dispatch decode tick).
Everything host-side that decides *which* stage runs next, *how* a prompt
is cut into chunks, and *what the user-visible latency was* lives here, so
it unit-tests without touching a device:

  * ``chunk_plan`` cuts an arbitrary-length prompt into a bounded set of
    chunk shapes (full ``chunk``-sized pieces + a power-of-two remainder
    decomposition), so the prefill graph compiles O(log chunk) variants
    total instead of one per prompt length.
  * ``next_action`` is the policy arbiter: decode-priority interleaves at
    most one prefill chunk between consecutive generate ticks (decode
    latency over admission latency); prefill-priority drains prefill work
    first (time-to-first-token over time-per-output-token).
  * ``PrefillTask`` tracks one in-flight prefill (request, reserved slot,
    chunk cursor, its private B=1 cache).
  * ``AdmissionConfig`` + ``admission_decision`` are the load-shedding
    policy: a request whose queue would be too deep, or whose estimated
    TTFT (``estimate_ttft_ms``) already blows its SLO/deadline, is shed AT
    SUBMIT -- a structured ``shed`` status instead of queueing work the
    engine provably cannot serve in time.
  * ``degraded_chunk`` is the overload fallback chunk size: the largest
    power of two <= chunk/2, so degraded prefill reuses already-compiled
    remainder shapes instead of adding new ones.
  * ``LatencyStats`` aggregates per-request queue-wait / TTFT / TPOT and
    reports p50/p95/p99 for ``engine.stats()`` and the serving bench.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

POLICIES = ("decode", "prefill")


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Knobs for the staged engine's stage arbitration.

    prefill_chunk: token budget one prefill dispatch may consume.  Long
        prompts are cut into pieces of at most this size, so a 10k-token
        prompt never monopolizes the engine for 10k positions' worth of
        work between two generate ticks.
    policy: "decode" runs a generate tick between any two prefill chunks
        whenever generation work exists (running requests never see more
        than one chunk of added inter-token latency); "prefill" runs all
        pending prefill work first (admissions reach their first token
        sooner, at the cost of inter-token latency for running requests).
    """

    prefill_chunk: int = 32
    policy: str = "decode"

    def __post_init__(self):
        if self.prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {self.policy!r}")


def chunk_plan(n_tokens: int, chunk: int) -> List[int]:
    """Chunk sizes for an ``n_tokens`` prompt under a ``chunk`` budget.

    Full ``chunk``-sized pieces first, then the remainder decomposed into
    descending powers of two (13 -> [8, 4, 1]).  The prefill graph is
    compiled per chunk LENGTH, so the reachable shape set is
    {chunk} U {2^i < chunk} -- O(log chunk) compiles ever, instead of one
    per distinct prompt length.
    """
    if n_tokens < 1:
        raise ValueError(f"need at least one prompt token, got {n_tokens}")
    sizes = [chunk] * (n_tokens // chunk)
    rem = n_tokens % chunk
    while rem:
        p = 1 << (rem.bit_length() - 1)  # largest power of two <= rem
        sizes.append(p)
        rem -= p
    return sizes


def degraded_chunk(chunk: int) -> int:
    """Overload-mode prefill chunk: largest power of two <= max(1, chunk/2).

    Power-of-two by construction so every degraded chunk size is already in
    the compiled remainder-shape set ({2^i < chunk}) -- entering overload
    mode never triggers a fresh prefill compile.
    """
    half = max(1, chunk // 2)
    return 1 << (half.bit_length() - 1)


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Load-shedding and deadline policy applied at ``engine.submit``.

    max_queue: shed when the queue already holds this many requests
        (``None`` disables depth shedding).
    ttft_slo_ms: shed when the estimated time to first token already
        exceeds this budget (``None`` disables SLO shedding).
    deadline_ms: default per-request deadline (a request's own
        ``deadline_ms`` wins); past it the request is EXPIRED wherever it
        is -- queued or in flight.  ``None`` = no default deadline.
    retry_backoff_ms: base of the exponential backoff a quarantined
        request waits before re-admission (doubles per retry).
    """

    max_queue: Optional[int] = None
    ttft_slo_ms: Optional[float] = None
    deadline_ms: Optional[float] = None
    retry_backoff_ms: float = 20.0


def estimate_ttft_ms(
    *,
    queued_tokens: int,
    n_queued: int,
    tick_ms: float,
    chunk: Optional[int] = None,
) -> float:
    """Crude-but-monotone TTFT estimate for a request submitted NOW.

    Counts the dispatches that must happen before its first token: every
    queued prompt's prefill work (``ceil(tokens / chunk)`` chunk dispatches
    staged, one tick per token lockstep when ``chunk`` is None) plus one
    first-token dispatch per queued request, priced at the engine's recent
    EWMA tick time.  Deliberately ignores decode interleaving -- it is an
    admission-control floor, not a simulator: if even the floor blows the
    SLO, queueing the request just manufactures a guaranteed deadline miss.
    """
    if tick_ms <= 0.0:
        return 0.0  # no dispatch history yet: admit and learn
    if chunk is not None and chunk > 0:
        prefill_dispatches = (queued_tokens + chunk - 1) // chunk
    else:
        prefill_dispatches = queued_tokens
    return (prefill_dispatches + n_queued) * tick_ms


def admission_decision(
    adm: AdmissionConfig,
    *,
    queue_depth: int,
    est_ttft_ms: float,
    deadline_ms: Optional[float] = None,
) -> Optional[str]:
    """Shed reason for a submission, or None to admit.

    A request is shed when the queue is at ``max_queue``, or when the
    estimated TTFT already exceeds the tighter of the global TTFT SLO and
    the request's own deadline.
    """
    if adm.max_queue is not None and queue_depth >= adm.max_queue:
        return (
            f"queue depth {queue_depth} >= max_queue {adm.max_queue}"
        )
    budgets = [b for b in (adm.ttft_slo_ms, deadline_ms) if b is not None]
    if budgets and est_ttft_ms > min(budgets):
        return (
            f"estimated TTFT {est_ttft_ms:.0f}ms exceeds budget "
            f"{min(budgets):.0f}ms"
        )
    return None


def next_action(
    policy: str, *, prefill_ready: bool, decode_ready: bool, last: str
) -> str:
    """Which stage the engine should dispatch next.

    prefill_ready: a prefill chunk could run (in-flight task, or a queued
        request with a free slot to reserve).
    decode_ready: at least one slot is actively generating.
    last: the previously dispatched stage ("prefill" | "generate"), used by
        decode-priority to interleave instead of starving prefill outright.
    """
    if not prefill_ready and not decode_ready:
        return "idle"
    if not prefill_ready:
        return "generate"
    if not decode_ready:
        return "prefill"
    if policy == "prefill":
        return "prefill"
    # decode-priority: generate by default, but admit one prefill chunk
    # after every generate tick so prefill still progresses under load
    # (strict alternation G P G P ... while both kinds of work exist).
    return "prefill" if last == "generate" else "generate"


@dataclasses.dataclass
class PrefillTask:
    """One in-flight chunked prefill: a request bound to a reserved slot."""

    req: Any  # Request
    slot: int
    chunks: List[int]
    cache: Any  # private B=1 prefill cache (model cache pytree)
    idx: int = 0  # next chunk to dispatch
    done_tokens: int = 0  # prompt tokens already consumed

    @property
    def complete(self) -> bool:
        return self.idx >= len(self.chunks)

    def next_chunk(self) -> tuple:
        """(start, size) of the next chunk to dispatch."""
        return self.done_tokens, self.chunks[self.idx]

    def advance(self, size: int) -> None:
        self.done_tokens += size
        self.idx += 1


class LatencyStats:
    """Per-request SLO aggregation: queue wait, TTFT, TPOT (seconds).

    ``record`` is called once per finished request; requests drained
    unfinished are never recorded (they have no final token).  TPOT is
    only defined for requests with >= 2 output tokens.
    """

    def __init__(self):
        self.queue_wait: List[float] = []
        self.ttft: List[float] = []
        self.tpot: List[float] = []

    def record(self, req) -> None:
        if req.submit_t is None:
            return  # request never went through submit() timing
        if req.prefill_start_t is not None:
            self.queue_wait.append(req.prefill_start_t - req.submit_t)
        if req.first_token_t is not None:
            self.ttft.append(req.first_token_t - req.submit_t)
            if req.finish_t is not None and len(req.output) > 1:
                self.tpot.append(
                    (req.finish_t - req.first_token_t) / (len(req.output) - 1)
                )

    @staticmethod
    def _pcts(vals: List[float]) -> Optional[Dict[str, float]]:
        if not vals:
            return None
        p50, p95, p99 = np.percentile(np.asarray(vals), [50, 95, 99])
        return {"p50": float(p50), "p95": float(p95), "p99": float(p99),
                "n": len(vals)}

    def summary(self) -> Dict[str, Optional[Dict[str, float]]]:
        """{"queue_wait"|"ttft"|"tpot": {"p50","p95","p99","n"} | None}."""
        return {
            "queue_wait": self._pcts(self.queue_wait),
            "ttft": self._pcts(self.ttft),
            "tpot": self._pcts(self.tpot),
        }
