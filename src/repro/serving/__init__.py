"""Serving substrate: lockstep + staged continuous-batching engines,
samplers, chunked-prefill scheduler, KV caches."""
from repro.serving.engine import Request, ServingEngine, StagedEngine
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.scheduler import (
    LatencyStats,
    SchedulerConfig,
    chunk_plan,
    next_action,
)
