"""Serving substrate: continuous-batching engine, samplers, KV caches."""
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import SamplerConfig, sample
