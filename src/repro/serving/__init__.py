"""Serving substrate: lockstep + staged continuous-batching engines,
samplers, chunked-prefill scheduler, KV caches, and the fault-tolerance
layer (admission control, numerical guardrails, watchdog, chaos harness)."""
from repro.serving.engine import Request, ServingEngine, StagedEngine
from repro.serving.faults import FaultInjector, FlakyIO, corrupt_payload
from repro.serving.health import (
    HealthConfig,
    OverloadController,
    TickWatchdog,
    describe_poison,
)
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.scheduler import (
    AdmissionConfig,
    LatencyStats,
    SchedulerConfig,
    admission_decision,
    chunk_plan,
    degraded_chunk,
    estimate_ttft_ms,
    next_action,
)
