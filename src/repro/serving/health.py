"""Serving-side health: numerical guardrails, tick watchdog, overload mode.

Why guardrails live in the SERVING layer (not just in tests): dynamic fixed
point deliberately runs activations on a narrow 8-bit grid under shared
power-of-two exponents (the paper's design), and fine-grained cluster
scaling multiplies the number of scale sites.  One corrupt scale, one
saturated accumulation, or one NaN-ed KV row silently poisons every token a
slot emits from then on -- and with a shared decode batch, an undetected
poisoned slot is one donated cache insert away from being recycled into the
next request.  The engine therefore checks every decode dispatch:

  * ``poison_flags`` is ONE fused reduction over the tick's logits, traced
    into the jitted decode graph -- per-slot bitflags for non-finite values
    and for magnitudes beyond the DFP saturation horizon
    (``2**sat_exponent``: past it, an 8-bit dynamic-fixed-point grid at any
    calibrated exponent the plan could carry is pure clipping).  The flags
    ride back in the SAME (2, B) device array as the sampled tokens, so
    guardrails add zero extra host syncs per tick.
  * ``TickWatchdog`` times every dispatch wall-clock and counts slow/hung
    ticks (it cannot preempt a wedged XLA dispatch from the same thread --
    it FLAGS, so operators and the chaos harness can assert on it).
  * ``OverloadController`` watches recent TPOT p95 and queue depth and
    flips the engine into degraded mode (smaller prefill chunks,
    decode-priority arbitration) with hysteresis, so an overloaded engine
    sheds latency tax instead of collapsing.

Poisoned slots are quarantined by the engine: the slot is aborted, its
cache rows scrubbed through the zero-prefix insert, and the request
re-queued with exponential backoff up to its retry budget (see
``docs/SERVING.md``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

# poison bitflags returned per slot by the fused guardrail reduction
POISON_NONE = 0
POISON_NONFINITE = 1  # NaN/Inf anywhere in the slot's logit row
POISON_SATURATED = 2  # finite but beyond the DFP saturation horizon


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Knobs for guardrails, the tick watchdog and overload degradation.

    guardrails: fold the per-slot poison check into the decode tick.  On by
        default -- it is one fused reduction and changes no tokens unless a
        slot is actually poisoned (greedy parity is regression-tested with
        it enabled).
    sat_exponent: |logit| >= 2**sat_exponent counts as DFP saturation.  The
        default (24) is far above anything a healthy smoke/serving model
        emits but far below overflow -- a corrupt shared exponent shows up
        here before it NaNs.
    tick_slow_s / tick_hang_s: wall-clock thresholds the watchdog counts
        against every dispatch (first-compile ticks will typically count as
        slow; the watchdog flags, it never kills).
    overload_tpot_ms / overload_queue: breach of either (recent TPOT p95,
        queue depth) flips the engine into overload mode; ``None`` disables
        that trigger.  Recovery needs both back under 80% of the threshold
        (hysteresis, so the mode cannot flap every tick).
    window: sliding sample window for the recent-TPOT estimate.
    """

    guardrails: bool = True
    sat_exponent: int = 24
    tick_slow_s: float = 1.0
    tick_hang_s: float = 10.0
    overload_tpot_ms: Optional[float] = None
    overload_queue: Optional[int] = None
    window: int = 32


def poison_flags(logits, sat_limit: float):
    """Per-slot poison bitflags over a (B, V) logit block -- ONE fused
    reduction, meant to be traced into the jitted decode tick.

    bit 0 (POISON_NONFINITE): any NaN/Inf in the row.
    bit 1 (POISON_SATURATED): any finite magnitude >= ``sat_limit``.
    """
    x = logits.astype(jnp.float32)
    finite = jnp.isfinite(x)
    nonfinite = jnp.any(~finite, axis=-1)
    sat = jnp.any(jnp.where(finite, jnp.abs(x), 0.0) >= sat_limit, axis=-1)
    return (
        nonfinite.astype(jnp.int32) * POISON_NONFINITE
        + sat.astype(jnp.int32) * POISON_SATURATED
    )


def describe_poison(flag: int) -> str:
    """Human-readable reason string for a poison bitflag."""
    parts = []
    if flag & POISON_NONFINITE:
        parts.append("non-finite logits")
    if flag & POISON_SATURATED:
        parts.append("DFP-saturated logits")
    return " + ".join(parts) or f"poison flag {flag}"


class TickWatchdog:
    """Wall-clock accounting of every engine dispatch.

    A hung XLA dispatch cannot be preempted from the dispatching thread, so
    the watchdog's contract is detection: it counts slow/hung ticks, keeps
    an EWMA tick time (the admission controller's TTFT estimator reads it),
    and remembers the worst tick.
    """

    def __init__(self, cfg: HealthConfig):
        self.cfg = cfg
        self.n = 0
        self.slow = 0
        self.hung = 0
        self.ewma_ms = 0.0
        self.last_ms = 0.0
        self.worst_ms = 0.0

    def observe(self, dt_s: float) -> Optional[str]:
        """Record one dispatch duration; returns "hung"/"slow"/None."""
        ms = dt_s * 1e3
        self.n += 1
        self.last_ms = ms
        self.worst_ms = max(self.worst_ms, ms)
        # EWMA seeded by the first sample; 0.2 step so one compile tick
        # doesn't dominate the TTFT estimate for long
        self.ewma_ms = ms if self.n == 1 else 0.8 * self.ewma_ms + 0.2 * ms
        if dt_s >= self.cfg.tick_hang_s:
            self.hung += 1
            return "hung"
        if dt_s >= self.cfg.tick_slow_s:
            self.slow += 1
            return "slow"
        return None

    def summary(self) -> Dict[str, float]:
        return {
            "ticks": self.n,
            "slow_ticks": self.slow,
            "hung_ticks": self.hung,
            "tick_ms_ewma": self.ewma_ms,
            "tick_ms_last": self.last_ms,
            "tick_ms_worst": self.worst_ms,
        }


class OverloadController:
    """Hysteretic overload detector driving graceful degradation.

    Enter overload when recent TPOT p95 breaches ``overload_tpot_ms`` or
    queue depth breaches ``overload_queue``; leave only when every enabled
    metric is back under 80% of its threshold.  The staged engine reads
    ``overload`` to shrink prefill chunks and force decode-priority
    arbitration (see ``StagedEngine``).
    """

    def __init__(self, cfg: HealthConfig):
        self.cfg = cfg
        self.overload = False
        self.entered = 0  # times overload mode was entered
        self._tpot_ms = deque(maxlen=cfg.window)

    def note_tpot_ms(self, ms: float) -> None:
        self._tpot_ms.append(ms)

    def tpot_p95_ms(self) -> Optional[float]:
        if not self._tpot_ms:
            return None
        return float(np.percentile(np.asarray(self._tpot_ms), 95))

    def update(self, *, queue_depth: int) -> bool:
        cfg = self.cfg
        p95 = self.tpot_p95_ms()

        def _state(scale: float) -> bool:
            breach = False
            if cfg.overload_tpot_ms is not None and p95 is not None:
                breach |= p95 > cfg.overload_tpot_ms * scale
            if cfg.overload_queue is not None:
                breach |= queue_depth > cfg.overload_queue * scale
            return breach

        if not self.overload and _state(1.0):
            self.overload = True
            self.entered += 1
        elif self.overload and not _state(0.8):
            self.overload = False
        return self.overload

    def summary(self) -> Dict[str, object]:
        return {
            "overload": self.overload,
            "overload_entered": self.entered,
            "tpot_p95_ms_recent": self.tpot_p95_ms(),
        }
