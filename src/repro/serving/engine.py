"""Batched serving engine with token-level continuous batching (Orca-style).

All ``n_slots`` step in lockstep through ONE jitted decode graph per tick:
slots still consuming their prompt feed the next prompt token (prefill and
decode share the graph -- admission never stalls running requests), slots in
generation feed their last sampled token, idle slots feed a pad token whose
output is discarded.  Per-slot cache positions use the masked-write decode
path in the attention/SSM layers.

The tick is device-resident: decode, sampling and the PRNG split live in one
jitted graph whose KV-cache operand is donated (updated in place, never
copied), so a tick is ONE dispatch and the only device->host transfer is the
(n_slots,) sampled-token fetch -- enforced at runtime by a transfer guard,
not just by convention.

This engine is the system the paper's quantized weights serve from: with PTQ
params (QTensors) the decode step streams 2-bit/4-bit packed weights -- the
bandwidth-bound phase where cluster quantization pays off most.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampler import SamplerConfig, sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(
        self,
        api,  # ModelApi
        params: Any,
        n_slots: int = 4,
        max_len: int = 256,
        sampler: SamplerConfig = SamplerConfig(),
        seed: int = 0,
    ):
        self.api = api
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.sampler = sampler
        self.cache = api.init_cache(n_slots, max_len)
        self.key = jax.random.PRNGKey(seed)

        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)  # next cache position
        self.slot_cursor = np.zeros(n_slots, np.int32)  # prompt consumption
        self.next_token = np.zeros(n_slots, np.int32)
        self.queue: List[Request] = []

        def _tick(params, tokens, pos, cache, key):
            logits, cache = api.decode(params, tokens, pos, cache)
            key, sub = jax.random.split(key)
            toks = sample(sub, logits[:, -1, :], sampler)
            return toks, key, cache

        # donate the cache: the decode step's masked writes update it in
        # place instead of copying the whole (L, B, S, ...) buffer per tick
        self._decode_step = jax.jit(_tick, donate_argnums=(3,))

    @classmethod
    def from_artifact(cls, artifact_dir: str, **kwargs) -> "ServingEngine":
        """Cold-start an engine from a packed quantized artifact.

        The decode graph serves straight from the loaded QTensor tree under
        the artifact's compiled plan -- no fp32 weights, no calibration, no
        re-quantization on boot."""
        from repro.models import load_servable  # lazy: serving stays model-agnostic

        api, qparams, _ = load_servable(artifact_dir)
        return cls(api, qparams, **kwargs)

    # -- client API --------------------------------------------------------
    def submit(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError("empty prompt")
        self.queue.append(req)

    def run(self, max_ticks: int = 1_000) -> List[Request]:
        finished: List[Request] = []
        ticks = 0
        while (self.queue or any(self.slot_req)) and ticks < max_ticks:
            finished.extend(self.step())
            ticks += 1
        return finished

    # -- engine tick -------------------------------------------------------
    def _admit(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[s] = req
                self.slot_pos[s] = 0
                self.slot_cursor[s] = 1  # token 0 goes in this tick
                self.next_token[s] = req.prompt[0]

    def step(self) -> List[Request]:
        """One lockstep tick over all slots; returns requests finished."""
        self._admit()
        if not any(self.slot_req):
            return []
        tokens = jnp.asarray(self.next_token[:, None])
        pos = jnp.asarray(self.slot_pos)
        # the guard turns "no host sync per tick" from a convention into a
        # runtime assert: any device->host readback inside the dispatch
        # (stray float(), logits fetch, ...) raises
        with jax.transfer_guard_device_to_host("disallow"):
            toks, self.key, self.cache = self._decode_step(
                self.params, tokens, pos, self.cache, self.key
            )
        sampled = np.asarray(toks)  # the ONE host sync per tick

        finished: List[Request] = []
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.slot_pos[s] += 1
            if self.slot_cursor[s] < len(req.prompt):  # still prefilling
                self.next_token[s] = req.prompt[self.slot_cursor[s]]
                self.slot_cursor[s] += 1
                continue
            tok = int(sampled[s])
            req.output.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if (
                len(req.output) >= req.max_new_tokens
                or hit_eos
                or self.slot_pos[s] >= self.max_len - 1
            ):
                req.done = True
                finished.append(req)
                self.slot_req[s] = None
                self.slot_pos[s] = 0
            else:
                self.next_token[s] = tok
        return finished

    # -- introspection ------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        return {
            "active": sum(r is not None for r in self.slot_req),
            "queued": len(self.queue),
            "positions": self.slot_pos.tolist(),
        }
