"""Serving engines over slot-based decode state: the lockstep oracle and
the staged continuous-batching engine.

``ServingEngine`` (lockstep, Orca-style): all ``n_slots`` step through ONE
jitted decode graph per tick -- slots consuming their prompt feed the next
prompt token, generating slots feed their last sampled token, idle slots
feed a pad token whose output is discarded.  Simple, and bit-exact: it is
the token-parity oracle the staged engine is tested against.  Its weakness
is structural: prefill and decode share the tick, so a P-token prompt costs
P full-batch dispatches during which its slot emits nothing.

``StagedEngine`` splits the engine into three explicit stages
(JetStream/MaxEngine-style):

  * ``prefill`` -- a dedicated jitted graph consumes a whole prompt chunk
    (B=1, S=chunk) against a private cache, chunked at a configurable token
    budget so arbitrarily long prompts never monopolize a tick; families
    without a chunk graph (ssm/hybrid/encdec) fall back to budgeted
    per-token decode prefill into the same private cache.
  * ``insert`` -- a donated in-place write of the finished prefix into the
    decode cache's reserved slot (every leaf's batch row is overwritten, so
    stale state from the slot's previous occupant cannot leak).
  * ``generate`` -- the existing donated one-dispatch decode tick over the
    slot batch.

Admission is asynchronous: the scheduler (``repro.serving.scheduler``)
interleaves prefill chunks with generate ticks under a policy knob
(decode-priority vs prefill-priority) and tracks per-request queue-wait /
TTFT / TPOT, surfaced as p50/p95/p99 through ``stats()``.

Both engines share the slot bookkeeping, the donated device-resident tick
(one host sync per tick, transfer-guard-asserted), mesh installation, and
artifact cold start.  With identical seeds and prompts the two engines
produce bit-identical greedy tokens: chunked prefill writes exactly the
K/V rows the lockstep tick would have written, and attention masks stale
positions to exact zeros.  (Stochastic sampling consumes the PRNG stream
in dispatch order, which differs by construction; parity is a greedy
contract.  MoE capacity drops depend on which tokens share a dispatch, so
parity there additionally assumes drop-free capacity -- see
docs/SERVING.md.)

This engine layer is the system the paper's quantized weights serve from:
with PTQ params (QTensors) the decode step streams 2-bit/4-bit packed
weights -- the bandwidth-bound phase where cluster quantization pays off.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import health as health_mod
from repro.serving.faults import FaultInjector
from repro.serving.health import HealthConfig, OverloadController, TickWatchdog
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.scheduler import (
    AdmissionConfig,
    LatencyStats,
    PrefillTask,
    SchedulerConfig,
    admission_decision,
    chunk_plan,
    degraded_chunk,
    estimate_ttft_ms,
    next_action,
)

# terminal request statuses: the request has left the engine for good
TERMINAL_STATUSES = ("finished", "expired", "shed", "rejected", "failed",
                     "cancelled")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # fault-tolerance contract (caller-set):
    #   deadline_ms -- wall-clock budget from submit; past it the request is
    #       expired wherever it is (queued or in flight).  None = the
    #       engine's AdmissionConfig default (which may also be None).
    #   max_retries -- how many times a fault-quarantined request may be
    #       re-queued (exponential backoff) before it is failed for good.
    deadline_ms: Optional[float] = None
    max_retries: int = 0
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # lifecycle: pending -> queued -> running -> finished, with the
    # fault-path terminals expired | shed | rejected | failed | cancelled
    status: str = "pending"
    reason: Optional[str] = None  # why shed/rejected/expired/failed/cancelled
    retries: int = 0  # quarantine retries consumed
    not_before: float = 0.0  # backoff gate: not re-admitted before this time
    admitted_tick: Optional[int] = None  # engine tick this request got a slot
    # wall-clock SLO trace (time.monotonic seconds), filled by the engine:
    # submit -> prefill_start (queue wait) -> first_token (TTFT) -> finish
    submit_t: Optional[float] = None
    prefill_start_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None

    @property
    def terminal(self) -> bool:
        return self.status in TERMINAL_STATUSES


class _EngineBase:
    """Slot/queue bookkeeping, device placement and the donated decode tick
    shared by the lockstep and staged engines."""

    def __init__(
        self,
        api,  # ModelApi
        params: Any,
        n_slots: int = 4,
        max_len: int = 256,
        sampler: SamplerConfig = SamplerConfig(),
        seed: int = 0,
        mesh: Optional[jax.sharding.Mesh] = None,
        admission: AdmissionConfig = AdmissionConfig(),
        health: HealthConfig = HealthConfig(),
        faults: Optional[FaultInjector] = None,
    ):
        from repro.parallel import sharding as rules

        self.api = api
        self.n_slots = n_slots
        self.max_len = max_len
        self.sampler = sampler
        self.mesh = mesh
        self.admission = admission
        self.health = health
        self.faults = faults
        self.watchdog = TickWatchdog(health)
        self._overload_ctl = OverloadController(health)
        # fault-tolerance event counters, surfaced via stats()["health"]
        self.events = {
            "rejected": 0, "shed": 0, "expired": 0, "cancelled": 0,
            "quarantined": 0, "retried": 0, "failed": 0,
            "faults_injected": 0,
        }
        self._tok_sharding = None
        self._pos_sharding = None
        self._cache_sharding = None
        # the activation mesh this engine's decode graph traces under: its
        # own mesh, or whatever was ambient at construction (a mesh-less
        # engine must not see another engine's mesh leak into its trace)
        self._trace_mesh = mesh if mesh is not None else rules._ACT_MESH[0]
        if mesh is not None:
            params = self._install_mesh(params)
        self.params = params
        if mesh is None:
            self.cache = api.init_cache(n_slots, max_len)
            self.key = jax.random.PRNGKey(seed)
        else:
            from jax.sharding import NamedSharding, PartitionSpec as P

            cache_shapes = jax.eval_shape(lambda: api.init_cache(n_slots, max_len))
            self._cache_sharding = rules.cache_shardings(cache_shapes, mesh)
            self.cache = jax.device_put(
                api.init_cache(n_slots, max_len), self._cache_sharding
            )
            self.key = jax.device_put(
                jax.random.PRNGKey(seed), NamedSharding(mesh, P())
            )

        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)  # next cache position
        self.slot_cursor = np.zeros(n_slots, np.int32)  # prompt consumption
        self.next_token = np.zeros(n_slots, np.int32)
        # deque: admission pops from the head every tick -- O(1) instead of
        # the O(n) list.pop(0) under deep backlogs
        self.queue: Deque[Request] = deque()
        self._tick = 0  # monotonically increasing engine tick counter
        self._clock = time.monotonic
        self._lat = LatencyStats()
        self._zero_prefix = None  # lazy B=1 zero cache (slot clearing)
        self._poison_prefix = None  # lazy B=1 NaN cache (chaos kv_corrupt)

        guardrails = health.guardrails
        sat_limit = float(2.0 ** health.sat_exponent)

        def _tick_fn(params, tokens, pos, cache, key, fault_slot, fault_val):
            logits, cache = api.decode(params, tokens, pos, cache)
            last = logits[:, -1, :].astype(jnp.float32)
            # chaos hook: overwrite ONE slot's logit row in-graph
            # (fault_slot == -1 selects nothing -- the fault-free path)
            rows = jnp.arange(last.shape[0], dtype=jnp.int32)[:, None]
            last = jnp.where(rows == fault_slot, fault_val, last)
            key, sub = jax.random.split(key)
            toks = sample(sub, last, sampler)
            # numerical guardrail: ONE fused reduction over the tick's
            # logits -> per-slot poison bitflags, stacked with the sampled
            # tokens so flags ride the existing single host sync
            if guardrails:
                flags = health_mod.poison_flags(last, sat_limit)
            else:
                flags = jnp.zeros_like(toks)
            return jnp.stack([toks, flags]), key, cache

        # donate the cache: the decode step's masked writes update it in
        # place instead of copying the whole (L, B, S, ...) buffer per tick
        self._decode_step = jax.jit(_tick_fn, donate_argnums=(3,))
        if api.insert is not None:
            jit_kw = {}
            if self._cache_sharding is not None:
                # pin the output layout so a donated sharded cache keeps the
                # serving sharding across insert dispatches
                jit_kw["out_shardings"] = self._cache_sharding
            self._insert_step = jax.jit(
                lambda cache, prefix, slot: api.insert(cache, prefix, slot),
                donate_argnums=(0,),
                **jit_kw,
            )
        else:
            self._insert_step = None

    def _install_mesh(self, params):
        """Install ``self.mesh`` as the serving layout: params onto the
        serving sharding rules, and the per-tick token/pos shardings (batch
        over data axes when divisible).  The ambient activation mesh is NOT
        mutated here -- each decode dispatch scopes it (``_dispatch``), so
        two engines with different meshes coexist in one process."""
        from repro.parallel import sharding as rules

        mesh = self.mesh
        params = jax.device_put(
            params, rules.qtensor_shardings(params, mesh, mode="serve")
        )
        # tokens (B, 1) / positions (B,) follow the one batch-sharding rule
        # (divisibility fallback included) instead of re-deriving it here
        specs = rules.batch_shardings(
            {
                "tokens": jax.ShapeDtypeStruct((self.n_slots, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((self.n_slots,), jnp.int32),
            },
            mesh,
        )
        self._tok_sharding = specs["tokens"]
        self._pos_sharding = specs["pos"]
        return params

    @classmethod
    def from_artifact(cls, artifact_dir: str, **kwargs):
        """Cold-start an engine from a packed quantized artifact.

        The decode graph serves straight from the loaded QTensor tree under
        the artifact's compiled plan -- no fp32 weights, no calibration, no
        re-quantization on boot.  With ``mesh=...`` the artifact's payloads
        (including per-host ``payload.shard{k}`` files) assemble directly
        onto their owning devices."""
        from repro.models import load_servable  # lazy: serving stays model-agnostic

        api, qparams, _ = load_servable(artifact_dir, mesh=kwargs.get("mesh"))
        return cls(api, qparams, **kwargs)

    # -- client API --------------------------------------------------------
    def submit(self, req: Request, *, strict: bool = False) -> Request:
        """Admit, reject, or shed one request; returns it with ``status``
        set (``queued`` | ``rejected`` | ``shed``).

        Malformed requests (empty prompt, prompt that cannot fit
        ``max_len``) come back ``rejected`` with a reason instead of
        raising -- one bad client must not take the serve loop down.
        ``strict=True`` restores the old raise-on-malformed behavior for
        callers that want submission bugs loud.  Load shedding
        (``AdmissionConfig``: queue depth / estimated-TTFT SLO) returns
        ``shed`` in both modes -- overload is the server's fault, not a
        client bug, so it is never an exception.
        """
        req.submit_t = self._clock()
        reject = None
        if not req.prompt:
            reject = "empty prompt"
        elif len(req.prompt) >= self.max_len:
            reject = (
                f"prompt of {len(req.prompt)} tokens cannot fit engine "
                f"max_len={self.max_len}: the slot would hit the cache cap "
                "during prefill and finish with truncated or empty output; "
                "raise max_len or truncate the prompt"
            )
        if reject is not None:
            if strict:
                raise ValueError(reject)
            req.status, req.reason = "rejected", reject
            self.events["rejected"] += 1
            return req
        if req.deadline_ms is None:
            req.deadline_ms = self.admission.deadline_ms
        shed = admission_decision(
            self.admission,
            queue_depth=len(self.queue),
            est_ttft_ms=self._est_ttft_ms(),
            deadline_ms=req.deadline_ms,
        )
        if shed is not None:
            req.status, req.reason = "shed", shed
            self.events["shed"] += 1
            return req
        req.status = "queued"
        self.queue.append(req)
        return req

    def cancel(self, uid: int) -> bool:
        """Cancel request ``uid`` wherever it is -- queued or holding a
        slot (mid-prefill included).  Returns False if no live request with
        that uid is inside the engine."""
        for i, r in enumerate(self.queue):
            if r.uid == uid:
                del self.queue[i]
                r.status, r.reason = "cancelled", "cancelled by client"
                self.events["cancelled"] += 1
                return True
        for s, r in enumerate(self.slot_req):
            if r is not None and r.uid == uid:
                self._abort_slot(s)
                r.status, r.reason = "cancelled", "cancelled by client"
                self.events["cancelled"] += 1
                return True
        return False

    def run(self, max_ticks: int = 1_000) -> List[Request]:
        """Step until idle or the tick budget expires; returns COMPLETED
        requests -- finished ones plus any that reached a terminal fault
        status (expired / failed) while running.  Check ``req.status``;
        without deadlines or faults every returned request is finished,
        exactly as before.  On budget expiry, in-flight and queued requests
        stay inside the engine -- inspect them with ``leftover()`` or pull
        them out with ``drain()``; they are never silently discarded."""
        completed: List[Request] = []
        ticks = 0
        while self._has_work() and ticks < max_ticks:
            tick0 = self._tick
            out = self.step()
            completed.extend(out)
            if self._tick == tick0 and not out and self.queue:
                # nothing dispatched and nothing completed: every queued
                # request is gated by retry backoff -- wait it out instead
                # of burning the tick budget on idle spins
                wait = min(r.not_before for r in self.queue) - self._clock()
                if wait > 0:
                    time.sleep(min(wait, 0.05))
            ticks += 1
        return completed

    def step(self) -> List[Request]:
        """One engine step: sweep deadlines, dispatch one stage/tick, feed
        the watchdog and overload controller.  Returns requests completed
        by this step (finished, expired, or failed)."""
        t0 = self._clock()
        completed = self._expire_deadlines()
        tick0 = self._tick
        completed.extend(self._step_impl())
        if self._tick != tick0:  # a real dispatch happened: time it
            self.watchdog.observe(self._clock() - t0)
        self._overload_ctl.update(queue_depth=len(self.queue))
        return completed

    def leftover(self) -> Dict[str, List[Request]]:
        """Unfinished work still inside the engine, without removing it:
        ``in_flight`` (requests holding or reserving a slot, prompt possibly
        part-consumed, output possibly part-generated) and ``queued``
        (never admitted).  All have ``done=False`` -- callers distinguish
        starved requests from finished ones by this report, not by absence
        from ``run()``'s return."""
        in_flight = [r for r in self.slot_req if r is not None]
        return {"in_flight": in_flight, "queued": list(self.queue)}

    def drain(self) -> Dict[str, List[Request]]:
        """Remove and return all unfinished requests (``leftover()`` shape),
        resetting every slot.  After ``drain()`` the engine is empty and
        reusable."""
        report = self.leftover()
        self._abort_inflight()
        for s in range(self.n_slots):
            if self.slot_req[s] is not None:
                self._reset_slot(s)
        self.queue.clear()
        return report

    # -- slot lifecycle (the ONE place slot state is reset) ----------------
    def _reset_slot(self, s: int) -> None:
        """Return slot ``s`` to the idle state: no request, position 0, pad
        next-token.  Both completion and admission go through here, so a
        dead request's ``next_token``/``slot_cursor`` can never leak into
        the next occupant (or into the idle pad rows of the shared tick)."""
        self.slot_req[s] = None
        self.slot_pos[s] = 0
        self.slot_cursor[s] = 0
        self.next_token[s] = 0

    def _occupy_slot(self, s: int, req: Request) -> None:
        """Reserve slot ``s`` for ``req``: reset host state, clear the
        slot's device cache row (stale SSM/recurrent state is NOT masked by
        positions the way stale KV rows are), and stamp admission."""
        self._reset_slot(s)
        self._clear_slot_cache(s)
        req.admitted_tick = self._tick
        req.prefill_start_t = self._clock()
        self.slot_req[s] = req

    def _clear_slot_cache(self, s: int) -> None:
        """Zero slot ``s``'s rows of the decode cache via the insert path.

        Stale KV rows are masked to exact zeros by the attention valid-mask,
        but recurrent state (ssm/hybrid families) carries the previous
        occupant unmasked -- clearing through the same ``insert`` write
        both engines use keeps slot reuse correct for every family."""
        if self._insert_step is None:
            return
        if self._zero_prefix is None:
            self._zero_prefix = self.api.init_cache(1, self.max_len)
        with self._dispatch():
            self.cache = self._insert_step(
                self.cache, self._zero_prefix, jnp.int32(s)
            )

    def _free_slot(self) -> Optional[int]:
        for s in range(self.n_slots):
            if self.slot_req[s] is None:
                return s
        return None

    def _finish(self, s: int, req: Request) -> None:
        req.done = True
        req.status = "finished"
        req.finish_t = self._clock()
        self._lat.record(req)
        if req.first_token_t is not None and len(req.output) > 1:
            self._overload_ctl.note_tpot_ms(
                (req.finish_t - req.first_token_t) / (len(req.output) - 1)
                * 1e3
            )
        self._reset_slot(s)

    def _abort_slot(self, s: int) -> None:
        """Tear one slot down mid-request (cancel / expiry / quarantine):
        host state reset AND device cache row scrubbed through the
        zero-prefix insert, so a poisoned or half-written row can never
        outlive its request."""
        self._reset_slot(s)
        self._clear_slot_cache(s)

    def _quarantine(self, s: int, req: Request, flag: int) -> Optional[Request]:
        """Contain a poisoned slot: abort it, scrub its cache, and either
        re-queue the request with exponential backoff (retry budget left)
        or fail it for good.  Returns the request when it terminated."""
        self.events["quarantined"] += 1
        self._abort_slot(s)
        reason = health_mod.describe_poison(flag)
        if req.retries < req.max_retries:
            req.retries += 1
            self.events["retried"] += 1
            backoff_s = (
                self.admission.retry_backoff_ms
                * (2 ** (req.retries - 1)) / 1e3
            )
            req.not_before = self._clock() + backoff_s
            # restart from the prompt: partial output came from (or fed
            # into) a poisoned cache and cannot be trusted
            req.output.clear()
            req.first_token_t = None
            req.status, req.reason = "queued", f"retrying after {reason}"
            self.queue.append(req)
            return None
        req.status = "failed"
        req.reason = f"{reason} (retry budget exhausted)" if req.max_retries \
            else reason
        req.finish_t = self._clock()
        self.events["failed"] += 1
        return req

    # -- deadlines / admission ---------------------------------------------
    def _deadline_passed(self, req: Request, now: float) -> bool:
        return (
            req.deadline_ms is not None
            and req.submit_t is not None
            and (now - req.submit_t) * 1e3 > req.deadline_ms
        )

    def _expire_deadlines(self) -> List[Request]:
        """Expire queued and in-flight requests past their deadline; frees
        their slots so live requests take them.  Returns the expired."""
        now = self._clock()
        expired: List[Request] = []
        if any(self._deadline_passed(r, now) for r in self.queue):
            keep: Deque[Request] = deque()
            for r in self.queue:
                if self._deadline_passed(r, now):
                    expired.append(r)
                else:
                    keep.append(r)
            self.queue = keep
        for s, r in enumerate(self.slot_req):
            if r is not None and self._deadline_passed(r, now):
                self._abort_slot(s)
                expired.append(r)
        for r in expired:
            r.status = "expired"
            r.reason = f"deadline {r.deadline_ms:.0f}ms exceeded"
            r.finish_t = now
            self.events["expired"] += 1
        return expired

    def _pop_eligible(self) -> Optional[Request]:
        """Oldest queued request not gated by retry backoff (FIFO among the
        eligible)."""
        now = self._clock()
        for i, r in enumerate(self.queue):
            if r.not_before <= now:
                del self.queue[i]
                return r
        return None

    def _est_ttft_ms(self) -> float:
        return estimate_ttft_ms(
            queued_tokens=sum(len(r.prompt) for r in self.queue),
            n_queued=len(self.queue),
            tick_ms=self.watchdog.ewma_ms,
            chunk=self._prefill_chunk_hint(),
        )

    def _prefill_chunk_hint(self) -> Optional[int]:
        """Tokens one dispatch consumes during prefill (None = one per
        tick, the lockstep model); the staged engine overrides."""
        return None

    # -- chaos -------------------------------------------------------------
    def _draw_fault(self):
        """Consume one injector decision for this dispatch.  Logit faults
        return in-graph operands (slot, value); cache/stall faults are
        applied here.  Fault-free: (-1, 0.0) -- the graph's no-op path."""
        no_fault = (jnp.int32(-1), jnp.float32(0.0))
        if self.faults is None:
            return no_fault
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        ev = self.faults.draw(self._tick, active)
        if ev is None:
            return no_fault
        self.events["faults_injected"] += 1
        victim = self.slot_req[ev.slot] if 0 <= ev.slot < self.n_slots \
            else None
        ev.uid = victim.uid if victim is not None else None
        if ev.kind in ("nan_logits", "inf_logits", "sat_logits"):
            return jnp.int32(ev.slot), jnp.float32(ev.payload)
        if ev.kind == "kv_corrupt":
            self._corrupt_slot_cache(ev.slot)
        elif ev.kind == "stall_tick":
            time.sleep(float(ev.payload))
        return no_fault

    def _corrupt_slot_cache(self, s: int) -> None:
        """Chaos: NaN-fill every float leaf of slot ``s``'s decode-cache
        row via the same donated insert the engine scrubs with."""
        if self._insert_step is None:
            return
        if self._poison_prefix is None:
            zero = self.api.init_cache(1, self.max_len)
            self._poison_prefix = jax.tree.map(
                lambda x: jnp.full_like(x, jnp.nan)
                if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating) else x,
                zero,
            )
        with self._dispatch():
            self.cache = self._insert_step(
                self.cache, self._poison_prefix, jnp.int32(s)
            )

    def _check_done(self, s: int, tok: int, req: Request) -> bool:
        hit_eos = req.eos_id is not None and tok == req.eos_id
        return (
            len(req.output) >= req.max_new_tokens
            or hit_eos
            or self.slot_pos[s] >= self.max_len - 1
        )

    # -- device plumbing ---------------------------------------------------
    def _device_operands(self):
        tokens = self.next_token[:, None]
        pos = self.slot_pos
        if self.mesh is None:
            return jnp.asarray(tokens), jnp.asarray(pos)
        return (
            jax.device_put(tokens, self._tok_sharding),
            jax.device_put(pos, self._pos_sharding),
        )

    @contextlib.contextmanager
    def _dispatch(self):
        """Scope one device dispatch: the ambient activation mesh is set to
        this engine's trace mesh (MoE dispatch constraints + the shard_map
        EP path read it at trace time) and always restored, so engines
        never leak their mesh into each other; the transfer guard turns
        "no host sync inside a dispatch" from a convention into a runtime
        assert -- any device->host readback (stray float(), logits fetch,
        ...) raises."""
        from repro.parallel import sharding as rules

        prev_mesh = rules._ACT_MESH[0]
        rules.set_activation_mesh(self._trace_mesh)
        try:
            with jax.transfer_guard_device_to_host("disallow"):
                yield
        finally:
            rules.set_activation_mesh(prev_mesh)

    # -- hooks -------------------------------------------------------------
    def _has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    def _abort_inflight(self) -> None:
        """Engine-specific teardown of partially-prefilled state (drain)."""

    def _step_impl(self) -> List[Request]:  # pragma: no cover - abstract
        raise NotImplementedError

    # -- introspection ------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        return {
            "active": sum(r is not None for r in self.slot_req),
            "queued": len(self.queue),  # queue depth (requests awaiting a slot)
            "tick": self._tick,
            "admitted_tick": [
                r.admitted_tick if r is not None else None
                for r in self.slot_req
            ],
            "positions": self.slot_pos.tolist(),
            "mesh": None if self.mesh is None else dict(self.mesh.shape),
            # per-request SLO percentiles over FINISHED requests (seconds):
            # queue_wait (submit -> slot), ttft (submit -> first token),
            # tpot (per output token after the first); None until recorded
            "latency": self._lat.summary(),
            # fault-tolerance: watchdog tick timing, overload mode, and the
            # shed/expired/quarantine/retry event counters
            "health": {
                **self.watchdog.summary(),
                **self._overload_ctl.summary(),
                "events": dict(self.events),
                "faults": None if self.faults is None
                else self.faults.summary(),
            },
        }

    @property
    def overload(self) -> bool:
        """Is the engine currently in degraded (overload) mode?"""
        return self._overload_ctl.overload


class ServingEngine(_EngineBase):
    """Lockstep tick loop (admission between ticks, prefill and decode in
    one shared graph).  Kept as the bit-exact oracle for ``StagedEngine``
    and as the simplest correct engine."""

    # -- engine tick -------------------------------------------------------
    def _admit(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                req = self._pop_eligible()
                if req is None:  # whole queue gated by retry backoff
                    return
                self._occupy_slot(s, req)
                self.slot_cursor[s] = 1  # token 0 goes in this tick
                self.next_token[s] = req.prompt[0]

    def _step_impl(self) -> List[Request]:
        """One lockstep tick over all slots; returns requests completed."""
        self._admit()
        if not any(r is not None for r in self.slot_req):
            return []
        self._tick += 1
        fault_slot, fault_val = self._draw_fault()
        tokens, pos = self._device_operands()
        with self._dispatch():
            out, self.key, self.cache = self._decode_step(
                self.params, tokens, pos, self.cache, self.key,
                fault_slot, fault_val,
            )
        out = np.asarray(out)  # the ONE host sync per tick
        sampled, flags = out[0], out[1]

        completed: List[Request] = []
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if flags[s]:  # guardrail tripped: contain before consuming
                dead = self._quarantine(s, req, int(flags[s]))
                if dead is not None:
                    completed.append(dead)
                continue
            self.slot_pos[s] += 1
            if self.slot_cursor[s] < len(req.prompt):  # still prefilling
                self.next_token[s] = req.prompt[self.slot_cursor[s]]
                self.slot_cursor[s] += 1
                continue
            tok = int(sampled[s])
            if not req.output:
                req.first_token_t = self._clock()
            req.output.append(tok)
            if self._check_done(s, tok, req):
                completed.append(req)
                self._finish(s, req)
            else:
                self.next_token[s] = tok
        return completed


class StagedEngine(_EngineBase):
    """Staged continuous batching: prefill / insert / generate stages with
    asynchronous admission, chunked prefill and per-request SLO stats.

    Each ``step()`` dispatches exactly ONE stage -- a prefill chunk or a
    generate tick -- chosen by the scheduler policy, so a long prompt costs
    its running co-residents at most one chunk of extra latency between
    ticks instead of stalling the batch for the whole prompt."""

    def __init__(
        self,
        api,
        params: Any,
        *,
        sched: SchedulerConfig = SchedulerConfig(),
        **kwargs,
    ):
        super().__init__(api, params, **kwargs)
        if self.api.insert is None:
            raise ValueError(
                f"model family {api.cfg.family!r} exposes no per-slot cache "
                "insertion (ModelApi.insert); the staged engine cannot move "
                "a finished prefill into the decode cache"
            )
        if sched.prefill_chunk >= self.max_len:
            sched = dataclasses.replace(sched, prefill_chunk=self.max_len - 1)
        self.sched = sched
        self._pf: Optional[PrefillTask] = None
        self._last_action = "generate"
        self.counts = {"prefill_chunks": 0, "generate_ticks": 0, "inserts": 0}
        if api.prefill_chunk is not None:
            self._prefill_step = jax.jit(
                lambda p, t, start, c: api.prefill_chunk(p, t, start, c),
                donate_argnums=(3,),
            )
        else:
            # fallback chunked prefill: budgeted per-token decode into the
            # private B=1 cache (recurrent families have no chunk graph)
            self._prefill_step = None
            self._pf_decode = jax.jit(
                lambda p, t, pos, c: api.decode(p, t, pos, c),
                donate_argnums=(3,),
            )

        guardrails = self.health.guardrails
        sat_limit = float(2.0 ** self.health.sat_exponent)

        def _first_token(key, logits):
            key, sub = jax.random.split(key)
            last = logits[:, -1, :].astype(jnp.float32)
            toks = sample(sub, last, self.sampler)
            # same fused guardrail as the decode tick: a poisoned prefill
            # must be caught before its first token is served
            if guardrails:
                flags = health_mod.poison_flags(last, sat_limit)
            else:
                flags = jnp.zeros_like(toks)
            return jnp.stack([toks, flags]), key

        self._first_token = jax.jit(_first_token)

    # -- scheduling --------------------------------------------------------
    def _decode_ready(self) -> bool:
        """Any slot actively generating (occupied and not merely reserved
        by the in-flight prefill)?"""
        reserved = self._pf.slot if self._pf is not None else None
        return any(
            r is not None and s != reserved for s, r in enumerate(self.slot_req)
        )

    def _effective_chunk(self) -> int:
        """Prefill chunk budget for NEW tasks: the configured chunk, or the
        degraded power-of-two half under overload (already in the compiled
        remainder-shape set, so degradation never compiles)."""
        chunk = self.sched.prefill_chunk
        return degraded_chunk(chunk) if self._overload_ctl.overload else chunk

    def _prefill_chunk_hint(self) -> Optional[int]:
        return self._effective_chunk()

    def _start_prefill(self) -> None:
        """Reserve a slot and open a PrefillTask for the queue head."""
        if self._pf is not None or not self.queue:
            return
        s = self._free_slot()
        if s is None:
            return
        req = self._pop_eligible()
        if req is None:  # whole queue gated by retry backoff
            return
        self._occupy_slot(s, req)
        self._pf = PrefillTask(
            req=req,
            slot=s,
            chunks=chunk_plan(len(req.prompt), self._effective_chunk()),
            cache=self.api.init_cache(1, self.max_len),
        )

    def _abort_inflight(self) -> None:
        self._pf = None

    def _abort_slot(self, s: int) -> None:
        # slot may be reserved by the in-flight prefill (cancel / expiry /
        # quarantine mid-prefill): drop the task with it
        if self._pf is not None and self._pf.slot == s:
            self._pf = None
        super()._abort_slot(s)

    def _step_impl(self) -> List[Request]:
        """Dispatch one stage (prefill chunk | generate tick); returns
        requests completed by this dispatch."""
        self._start_prefill()
        # graceful degradation: under overload, protect running requests'
        # TPOT -- force decode-priority regardless of the configured policy
        policy = "decode" if self._overload_ctl.overload else self.sched.policy
        action = next_action(
            policy,
            prefill_ready=self._pf is not None,
            decode_ready=self._decode_ready(),
            last=self._last_action,
        )
        if action == "idle":
            return []
        self._tick += 1
        self._last_action = action
        if action == "prefill":
            return self._prefill_dispatch()
        return self._generate_dispatch()

    # -- stages ------------------------------------------------------------
    def _prefill_dispatch(self) -> List[Request]:
        pf = self._pf
        start, size = pf.next_chunk()
        req = pf.req
        chunk_toks = np.asarray([req.prompt[start : start + size]], np.int32)
        out_dev = None
        with self._dispatch():
            if self._prefill_step is not None:
                logits, pf.cache = self._prefill_step(
                    self.params, jnp.asarray(chunk_toks), jnp.int32(start),
                    pf.cache,
                )
            else:
                for j in range(size):
                    logits, pf.cache = self._pf_decode(
                        self.params, jnp.asarray(chunk_toks[:, j : j + 1]),
                        jnp.int32(start + j), pf.cache,
                    )
            pf.advance(size)
            self.counts["prefill_chunks"] += 1
            if pf.complete:
                # first generated token comes from the final chunk's logits;
                # the finished prefix moves into the reserved decode slot
                out_dev, self.key = self._first_token(self.key, logits)
                self.cache = self._insert_step(
                    self.cache, pf.cache, jnp.int32(pf.slot)
                )
                self.counts["inserts"] += 1
        if out_dev is None:
            return []
        out = np.asarray(out_dev)  # the one host sync
        tok, flag = int(out[0, 0]), int(out[1, 0])
        s = pf.slot
        self._pf = None
        if flag:  # poisoned prefill: contain before serving its first token
            dead = self._quarantine(s, req, flag)
            return [] if dead is None else [dead]
        self.slot_pos[s] = pf.done_tokens  # == len(prompt): next write pos
        req.first_token_t = self._clock()
        req.output.append(tok)
        if self._check_done(s, tok, req):
            self._finish(s, req)
            return [req]
        self.next_token[s] = tok
        return []

    def _generate_dispatch(self) -> List[Request]:
        fault_slot, fault_val = self._draw_fault()
        tokens, pos = self._device_operands()
        with self._dispatch():
            out, self.key, self.cache = self._decode_step(
                self.params, tokens, pos, self.cache, self.key,
                fault_slot, fault_val,
            )
        out = np.asarray(out)  # the ONE host sync per tick
        sampled, flags = out[0], out[1]
        self.counts["generate_ticks"] += 1

        completed: List[Request] = []
        reserved = self._pf.slot if self._pf is not None else None
        for s, req in enumerate(self.slot_req):
            if req is None or s == reserved:
                continue  # idle or mid-prefill: pad row, output discarded
            if flags[s]:  # guardrail tripped: contain before consuming
                dead = self._quarantine(s, req, int(flags[s]))
                if dead is not None:
                    completed.append(dead)
                continue
            self.slot_pos[s] += 1
            tok = int(sampled[s])
            req.output.append(tok)
            if self._check_done(s, tok, req):
                completed.append(req)
                self._finish(s, req)
            else:
                self.next_token[s] = tok
        return completed

    # -- introspection ------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        out = super().stats()
        pf = self._pf
        out.update(
            engine="staged",
            policy=self.sched.policy,
            prefill_chunk=self.sched.prefill_chunk,
            counts=dict(self.counts),
            inflight_prefill=None if pf is None else {
                "uid": pf.req.uid,
                "slot": pf.slot,
                "done_tokens": pf.done_tokens,
                "total_tokens": len(pf.req.prompt),
            },
        )
        return out
