"""Batched serving engine with token-level continuous batching (Orca-style).

All ``n_slots`` step in lockstep through ONE jitted decode graph per tick:
slots still consuming their prompt feed the next prompt token (prefill and
decode share the graph -- admission never stalls running requests), slots in
generation feed their last sampled token, idle slots feed a pad token whose
output is discarded.  Per-slot cache positions use the masked-write decode
path in the attention/SSM layers.

The tick is device-resident: decode, sampling and the PRNG split live in one
jitted graph whose KV-cache operand is donated (updated in place, never
copied), so a tick is ONE dispatch and the only device->host transfer is the
(n_slots,) sampled-token fetch -- enforced at runtime by a transfer guard,
not just by convention.

With a ``mesh`` the whole tick runs under NamedSharding: params (QTensor
payload/scale leaves included) are placed by the serving sharding rules
(``repro.parallel.qtensor_shardings``), the donated KV cache is sharded by
``cache_shardings`` (batch over data axes, heads/seq over model), per-tick
tokens are fed straight onto their batch sharding, and the engine installs
the mesh as the ambient activation mesh so MoE dispatch and the shard_map
expert-parallel FFN see it at trace time.  The engine composes with
mesh-aware artifacts: ``from_artifact(dir, mesh=...)`` cold-starts from
per-host shards with no single-host global tree.

This engine is the system the paper's quantized weights serve from: with PTQ
params (QTensors) the decode step streams 2-bit/4-bit packed weights -- the
bandwidth-bound phase where cluster quantization pays off most.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampler import SamplerConfig, sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    admitted_tick: Optional[int] = None  # engine tick this request got a slot


class ServingEngine:
    def __init__(
        self,
        api,  # ModelApi
        params: Any,
        n_slots: int = 4,
        max_len: int = 256,
        sampler: SamplerConfig = SamplerConfig(),
        seed: int = 0,
        mesh: Optional[jax.sharding.Mesh] = None,
    ):
        from repro.parallel import sharding as rules

        self.api = api
        self.n_slots = n_slots
        self.max_len = max_len
        self.sampler = sampler
        self.mesh = mesh
        self._tok_sharding = None
        self._pos_sharding = None
        # the activation mesh this engine's decode graph traces under: its
        # own mesh, or whatever was ambient at construction (a mesh-less
        # engine must not see another engine's mesh leak into its trace)
        self._trace_mesh = mesh if mesh is not None else rules._ACT_MESH[0]
        if mesh is not None:
            params = self._install_mesh(params)
        self.params = params
        if mesh is None:
            self.cache = api.init_cache(n_slots, max_len)
            self.key = jax.random.PRNGKey(seed)
        else:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.parallel import sharding as rules

            cache_shapes = jax.eval_shape(lambda: api.init_cache(n_slots, max_len))
            self.cache = jax.device_put(
                api.init_cache(n_slots, max_len),
                rules.cache_shardings(cache_shapes, mesh),
            )
            self.key = jax.device_put(
                jax.random.PRNGKey(seed), NamedSharding(mesh, P())
            )

        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)  # next cache position
        self.slot_cursor = np.zeros(n_slots, np.int32)  # prompt consumption
        self.next_token = np.zeros(n_slots, np.int32)
        # deque: admission pops from the head every tick -- O(1) instead of
        # the O(n) list.pop(0) under deep backlogs
        self.queue: Deque[Request] = deque()
        self._tick = 0  # monotonically increasing engine tick counter

        def _tick_fn(params, tokens, pos, cache, key):
            logits, cache = api.decode(params, tokens, pos, cache)
            key, sub = jax.random.split(key)
            toks = sample(sub, logits[:, -1, :], sampler)
            return toks, key, cache

        # donate the cache: the decode step's masked writes update it in
        # place instead of copying the whole (L, B, S, ...) buffer per tick
        self._decode_step = jax.jit(_tick_fn, donate_argnums=(3,))

    def _install_mesh(self, params):
        """Install ``self.mesh`` as the serving layout: params onto the
        serving sharding rules, and the per-tick token/pos shardings (batch
        over data axes when divisible).  The ambient activation mesh is NOT
        mutated here -- each decode dispatch scopes it (``step``), so two
        engines with different meshes coexist in one process."""
        from repro.parallel import sharding as rules

        mesh = self.mesh
        params = jax.device_put(
            params, rules.qtensor_shardings(params, mesh, mode="serve")
        )
        # tokens (B, 1) / positions (B,) follow the one batch-sharding rule
        # (divisibility fallback included) instead of re-deriving it here
        specs = rules.batch_shardings(
            {
                "tokens": jax.ShapeDtypeStruct((self.n_slots, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((self.n_slots,), jnp.int32),
            },
            mesh,
        )
        self._tok_sharding = specs["tokens"]
        self._pos_sharding = specs["pos"]
        return params

    @classmethod
    def from_artifact(cls, artifact_dir: str, **kwargs) -> "ServingEngine":
        """Cold-start an engine from a packed quantized artifact.

        The decode graph serves straight from the loaded QTensor tree under
        the artifact's compiled plan -- no fp32 weights, no calibration, no
        re-quantization on boot.  With ``mesh=...`` the artifact's payloads
        (including per-host ``payload.shard{k}`` files) assemble directly
        onto their owning devices."""
        from repro.models import load_servable  # lazy: serving stays model-agnostic

        api, qparams, _ = load_servable(artifact_dir, mesh=kwargs.get("mesh"))
        return cls(api, qparams, **kwargs)

    # -- client API --------------------------------------------------------
    def submit(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError("empty prompt")
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens cannot fit engine "
                f"max_len={self.max_len}: the slot would hit the cache cap "
                "during prefill and finish with truncated or empty output; "
                "raise max_len or truncate the prompt"
            )
        self.queue.append(req)

    def run(self, max_ticks: int = 1_000) -> List[Request]:
        finished: List[Request] = []
        ticks = 0
        while (self.queue or any(self.slot_req)) and ticks < max_ticks:
            finished.extend(self.step())
            ticks += 1
        return finished

    # -- engine tick -------------------------------------------------------
    def _admit(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.popleft()
                req.admitted_tick = self._tick
                self.slot_req[s] = req
                self.slot_pos[s] = 0
                self.slot_cursor[s] = 1  # token 0 goes in this tick
                self.next_token[s] = req.prompt[0]

    def _device_operands(self):
        tokens = self.next_token[:, None]
        pos = self.slot_pos
        if self.mesh is None:
            return jnp.asarray(tokens), jnp.asarray(pos)
        return (
            jax.device_put(tokens, self._tok_sharding),
            jax.device_put(pos, self._pos_sharding),
        )

    def step(self) -> List[Request]:
        """One lockstep tick over all slots; returns requests finished."""
        self._admit()
        if not any(self.slot_req):
            return []
        self._tick += 1
        tokens, pos = self._device_operands()
        from repro.parallel import sharding as rules

        # scope the ambient activation mesh to this dispatch: the first call
        # traces the decode graph (MoE dispatch constraints + the shard_map
        # EP path read the mesh at trace time) and the previous value is
        # always restored, so engines never leak their mesh into each other
        prev_mesh = rules._ACT_MESH[0]
        rules.set_activation_mesh(self._trace_mesh)
        try:
            # the guard turns "no host sync per tick" from a convention into
            # a runtime assert: any device->host readback inside the dispatch
            # (stray float(), logits fetch, ...) raises
            with jax.transfer_guard_device_to_host("disallow"):
                toks, self.key, self.cache = self._decode_step(
                    self.params, tokens, pos, self.cache, self.key
                )
        finally:
            rules.set_activation_mesh(prev_mesh)
        sampled = np.asarray(toks)  # the ONE host sync per tick

        finished: List[Request] = []
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.slot_pos[s] += 1
            if self.slot_cursor[s] < len(req.prompt):  # still prefilling
                self.next_token[s] = req.prompt[self.slot_cursor[s]]
                self.slot_cursor[s] += 1
                continue
            tok = int(sampled[s])
            req.output.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if (
                len(req.output) >= req.max_new_tokens
                or hit_eos
                or self.slot_pos[s] >= self.max_len - 1
            ):
                req.done = True
                finished.append(req)
                self.slot_req[s] = None
                self.slot_pos[s] = 0
            else:
                self.next_token[s] = tok
        return finished

    # -- introspection ------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        return {
            "active": sum(r is not None for r in self.slot_req),
            "queued": len(self.queue),  # queue depth (requests awaiting a slot)
            "tick": self._tick,
            "admitted_tick": [
                r.admitted_tick if r is not None else None
                for r in self.slot_req
            ],
            "positions": self.slot_pos.tolist(),
            "mesh": None if self.mesh is None else dict(self.mesh.shape),
        }
