"""Chaos harness: seeded, deterministic fault injection for serving.

The point of the harness is a provable containment story: for every fault
kind the engine claims to survive, CI injects it and asserts that exactly
the afflicted request fails (or retries), while every other concurrent
request finishes bit-identical to a fault-free run and the engine keeps
serving.  Determinism is load-bearing -- the injector owns a seeded
generator and a one-shot arming queue, never wall-clock, so a chaos run
replays exactly.

Engine-tick fault kinds (consumed by ``FaultInjector.draw`` once per
decode dispatch; see ``_EngineBase._draw_fault``):

  * ``nan_logits`` / ``inf_logits`` / ``sat_logits`` -- overwrite one
    slot's logit row in-graph with NaN / Inf / a finite value beyond the
    DFP saturation horizon.  Exercises all guardrail bits.
  * ``kv_corrupt`` -- NaN-fill every float leaf of one slot's decode-cache
    row (via the same donated insert the engine uses to scrub), modeling a
    corrupted KV block; the next tick's guardrail must catch it.
  * ``stall_tick`` -- host-side sleep before the dispatch, modeling a hung
    device tick; the watchdog must flag it and tokens must be unaffected.

Artifact-load fault kinds (applied around ``load_artifact``):

  * ``FlakyIO`` -- an io-fault hook for ``repro.training.checkpoint`` that
    raises ``OSError`` on the first N payload reads, modeling a transient
    filesystem flake; the loader's retry-with-backoff must absorb it.
  * ``corrupt_payload`` -- flips bytes inside a payload file (an integrity
    fault, NOT transient): verification must fail closed, never retry it
    into service.

CLI: ``repro.launch.serve --chaos "rate=0.01,kinds=nan_logits|kv_corrupt,
seed=0"`` injects at a sustained rate; ``benchmarks/bench_serving.py
--chaos --smoke`` is the CI containment matrix.
"""
from __future__ import annotations

import dataclasses
import os
from collections import deque
from typing import List, Optional, Sequence

import numpy as np

# kinds drawn per engine dispatch
TICK_FAULT_KINDS = (
    "nan_logits",
    "inf_logits",
    "sat_logits",
    "kv_corrupt",
    "stall_tick",
)
# kinds exercised around artifact load (not drawn per tick)
ARTIFACT_FAULT_KINDS = ("io_flake", "shard_corrupt")

_DEFAULT_PAYLOAD = {
    "nan_logits": float("nan"),
    "inf_logits": float("inf"),
    "sat_logits": float(2.0 ** 30),  # finite, but past any sane DFP horizon
}


@dataclasses.dataclass
class FaultEvent:
    """One injected fault: what, where, with which payload.

    ``tick`` and ``uid`` are stamped when the event fires (engine tick it
    hit, uid of the request occupying the target slot) so a chaos run's log
    names its victims exactly.
    """

    kind: str
    slot: int = 0
    payload: Optional[float] = None
    tick: Optional[int] = None
    uid: Optional[int] = None


class FaultInjector:
    """Deterministic fault source for the serving engines.

    Two modes, composable:

      * armed one-shots: ``arm(kind, slot=...)`` queues exactly one fault
        for the next decode dispatch -- what the chaos-matrix tests use to
        hit a known victim at a known point.
      * seeded rate: with ``rate`` > 0, each dispatch draws from a private
        ``np.random.Generator(seed)``; with probability ``rate`` one fault
        of a random ``kinds`` entry hits a random ACTIVE slot.  The draw
        sequence depends only on (seed, dispatch ordinal), never on wall
        clock, so a fixed submission order replays identically.

    ``log`` records every fired event (kind, slot, tick, victim uid) --
    the containment assertions read it to learn who was afflicted.
    """

    def __init__(
        self,
        *,
        rate: float = 0.0,
        kinds: Sequence[str] = ("nan_logits",),
        seed: int = 0,
        stall_s: float = 0.25,
    ):
        for k in kinds:
            if k not in TICK_FAULT_KINDS:
                raise ValueError(
                    f"unknown tick fault kind {k!r}; known: {TICK_FAULT_KINDS}"
                )
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        self.rate = rate
        self.kinds = tuple(kinds)
        self.stall_s = stall_s
        self._rng = np.random.default_rng(seed)
        self._armed: deque = deque()
        self.log: List[FaultEvent] = []

    def arm(self, kind: str, slot: int = 0, payload: Optional[float] = None):
        """Queue a one-shot fault for the next decode dispatch."""
        if kind not in TICK_FAULT_KINDS:
            raise ValueError(
                f"unknown tick fault kind {kind!r}; known: {TICK_FAULT_KINDS}"
            )
        self._armed.append(FaultEvent(kind=kind, slot=slot, payload=payload))
        return self

    def draw(self, tick: int, active_slots: Sequence[int]) -> Optional[FaultEvent]:
        """One injection decision for the dispatch at ``tick``.

        Armed one-shots fire first (regardless of activity); the seeded
        rate only targets slots that actually hold a request -- injecting
        into an empty slot proves nothing.
        """
        ev: Optional[FaultEvent] = None
        if self._armed:
            ev = self._armed.popleft()
        elif self.rate > 0.0 and active_slots:
            # one generator call per dispatch whether or not a fault fires,
            # so the decision sequence is a pure function of the ordinal
            u = self._rng.random()
            if u < self.rate:
                kind = self.kinds[int(self._rng.integers(len(self.kinds)))]
                slot = active_slots[
                    int(self._rng.integers(len(active_slots)))
                ]
                ev = FaultEvent(kind=kind, slot=int(slot))
        if ev is None:
            return None
        if ev.payload is None:
            ev.payload = _DEFAULT_PAYLOAD.get(ev.kind, self.stall_s)
        ev.tick = tick
        self.log.append(ev)
        return ev

    @classmethod
    def from_spec(cls, spec: str) -> "FaultInjector":
        """Parse a CLI spec: ``rate=0.01,kinds=nan_logits|kv_corrupt,seed=0,
        stall=0.25``.  Unknown keys raise."""
        kw = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            k, _, v = part.partition("=")
            if k == "rate":
                kw["rate"] = float(v)
            elif k == "kinds":
                kw["kinds"] = tuple(filter(None, v.split("|")))
            elif k == "seed":
                kw["seed"] = int(v)
            elif k == "stall":
                kw["stall_s"] = float(v)
            else:
                raise ValueError(
                    f"unknown --chaos key {k!r} (known: rate, kinds, seed, stall)"
                )
        return cls(**kw)

    def summary(self) -> dict:
        by_kind: dict = {}
        for ev in self.log:
            by_kind[ev.kind] = by_kind.get(ev.kind, 0) + 1
        return {"injected": len(self.log), "by_kind": by_kind}


# ---------------------------------------------------------------------------
# Artifact-load faults.
# ---------------------------------------------------------------------------
class FlakyIO:
    """Transient-IO fault hook for ``checkpoint.io_fault_hook``.

    Raises ``OSError`` on the first ``n_failures`` reads whose path contains
    ``match`` (empty matches everything), then passes everything through --
    the model of a filesystem flake that heals on retry.  ``raised`` counts
    injected failures so tests can assert the retry loop actually absorbed
    them rather than never hitting them.
    """

    def __init__(self, n_failures: int, match: str = ""):
        self.remaining = n_failures
        self.match = match
        self.raised = 0

    def __call__(self, path: str) -> None:
        if self.remaining > 0 and self.match in os.path.basename(path):
            self.remaining -= 1
            self.raised += 1
            raise OSError(f"injected transient IO failure reading {path}")


def corrupt_payload(step_dir: str, seed: int = 0) -> str:
    """Flip bytes inside one payload file of a checkpoint step directory.

    Deterministic victim choice (sorted file list + seeded offset).  This
    is an INTEGRITY fault: the sha256 gate must fail the whole step closed
    (fall back to an older intact step or raise) -- retrying it would serve
    corrupt weights.  Returns the corrupted file's path.
    """
    victims = sorted(
        f for f in os.listdir(step_dir)
        if f.endswith(".npy")
    )
    if not victims:
        raise ValueError(f"no payload files under {step_dir}")
    rng = np.random.default_rng(seed)
    target = os.path.join(step_dir, victims[int(rng.integers(len(victims)))])
    size = os.path.getsize(target)
    with open(target, "r+b") as f:
        f.seek(int(rng.integers(max(1, size))))
        f.write(b"\xde\xad\xbe\xef")
    return target
