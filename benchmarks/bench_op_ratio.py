"""Paper Sec. 3.3 performance model: fraction of multiplications replaced by
8-bit accumulations, and the HBM weight-compression it buys.

Validates the paper's two headline numbers on the exact ResNet-101 conv
inventory (85% @ N=4, 98% @ N=64), the paper's own 50/50 3x3-1x1
approximation, and extends the table to all ten assigned LM architectures
(transformer projections: K^2 == 1, segment = group_size).
"""
from __future__ import annotations

from benchmarks.common import arch_gemms
from repro import configs
from repro.core import stats


def run(csv=print):
    specs = stats.resnet101_specs()
    for n in (4, 8, 16, 32, 64):
        exact = stats.network_replaced_fraction(specs, n)
        approx = stats.paper_approximation(n)
        csv(f"op_ratio/resnet101_N{n},0,exact={exact:.4f};paper_approx={approx:.4f}")

    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        gemms = arch_gemms(cfg)
        for n in (4, 64, 128):
            total, wq_frac, all_frac = stats.network_gemm_stats(gemms, n)
            csv(
                f"op_ratio/{arch}_N{n},0,"
                f"macs_per_tok={total:.3e};replaced_wq={wq_frac:.4f};"
                f"replaced_all={all_frac:.4f}"
            )
        # decode-phase HBM traffic for weights (the TPU payoff, DESIGN 2.1)
        bf16 = stats.weight_bytes(gemms, 16, 64, scale_bits=0)
        for bits in (2, 4, 8):
            b = stats.weight_bytes(gemms, bits, 64)
            csv(
                f"op_ratio/{arch}_wbytes_{bits}w,0,"
                f"bytes_per_tok={b:.3e};compression_vs_bf16={bf16 / b:.2f}x"
            )


if __name__ == "__main__":
    run()
