"""Paper Sec. 4 / Fig. 2: low-precision fine-tuning with pre-initialized
weights recovers the accuracy lost by aggressive (large-N ternary) PTQ.

Recipe is the paper's: initialize from the full-precision model, ternary
forward (Algorithm 1 via STE), fp32 master weights/gradients, reduced lr
(1e-4 scale), few epochs.  Expected shape: qat-final < ptq (recovery).
"""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import eval_loss_and_top1, tiny_lm, train_fp_baseline
from repro.configs.base import QuantConfig
from repro.models import build_model, quantize_and_plan
from repro.training import OptConfig, TrainConfig, Trainer
from repro.training.data import DataConfig, make_batch


def run(csv=print, qat_steps: int = 120):
    cfg, api, params, dcfg, _ = train_fp_baseline(steps=150)
    fp_loss, fp_top1 = eval_loss_and_top1(api, params, cfg, dcfg)
    csv(f"finetune/fp,0,loss={fp_loss:.4f};top1={fp_top1:.4f}")

    n = 64  # the cluster size the paper says NEEDS retraining
    qc = QuantConfig(w_bits=2, group_size=n, mode="ptq", backend="xla")
    qcfg = dataclasses.replace(tiny_lm(), quant=qc)
    qparams, _plan, qapi = quantize_and_plan(build_model(qcfg), params)
    ptq_loss, ptq_top1 = eval_loss_and_top1(qapi, qparams, qcfg, dcfg)
    csv(f"finetune/ptq_2w_N{n},0,loss={ptq_loss:.4f};top1={ptq_top1:.4f}")

    # Sec. 4: pre-initialized QAT, ternary forward, fp32 master, low lr
    qat_cfg = dataclasses.replace(
        tiny_lm(), quant=QuantConfig(w_bits=2, group_size=n, mode="qat")
    )
    qat_api = build_model(qat_cfg)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-4, warmup_steps=0, decay_steps=qat_steps,
                                     weight_decay=0.0))
    tr = Trainer(qat_api.train_loss, params, tcfg)  # pre-initialized!
    hist = tr.train(lambda i: make_batch(cfg, dcfg, 500 + i), qat_steps)
    for i in range(0, qat_steps, max(1, qat_steps // 8)):
        csv(f"finetune/qat_curve_step{i},0,loss={hist['loss'][i]:.4f}")

    # evaluate the fine-tuned model under the SAME ternary PTQ
    ft_q, _plan, _ = quantize_and_plan(qapi, tr.params)
    qat_loss, qat_top1 = eval_loss_and_top1(qapi, ft_q, qcfg, dcfg)
    csv(
        f"finetune/qat_final_2w_N{n},0,"
        f"loss={qat_loss:.4f};top1={qat_top1:.4f};"
        f"recovered={ptq_loss - qat_loss:+.4f}"
    )
    return {"fp": fp_loss, "ptq": ptq_loss, "qat": qat_loss}


if __name__ == "__main__":
    run()
