"""Paper Sec. 4 / Fig. 2: low-precision retraining recovers the accuracy
lost by aggressive (large-N ternary) PTQ -- extended to the paper's lineage
of *stateful* methods (docs/TRAINING.md):

  ptq   one-shot quantization of the fp baseline (no retraining)
  qat   Sec.-4 recipe: pre-initialized, fake-quant forward, fp32 master,
        re-fit grid at deployment
  ttq   Trained Ternary Quantization (arxiv 1612.01064): per-cluster Wp/Wn
        scale magnitudes train by gradient; deployed on the LEARNED grid
  inq   Incremental Network Quantization (arxiv 1702.03044) on a LEARNED
        grid: magnitude partitions frozen at schedule fractions while the
        rest keeps training and the shared cluster grid trains by gradient
        throughout; deployed on the learned grid

Cells: ternary N=64 (the cluster size the paper says NEEDS retraining;
ttq applies) and int4 N=64 (ttq is ternary-only, skipped).

``--smoke`` runs the ternary cell at reduced steps and asserts the recovery
DIRECTION only (each retrained method beats one-shot PTQ loss) -- exact
values vary by machine, direction does not, so the CI step cannot flap.
``--json PATH`` writes the trajectory rows (how the committed
``benchmarks/BENCH_finetune.json`` is made; also ``run.py --finetune-json``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from benchmarks.common import eval_loss_and_top1, tiny_lm, train_fp_baseline
from repro.configs.base import QuantConfig
from repro.models import build_model, quantize_and_plan
from repro.quant import init_quant_state
from repro.training import OptConfig, TrainConfig, Trainer
from repro.training.data import make_batch

FT_LR = 1e-4  # the paper's reduced fine-tuning lr scale


def _eval_ptq(params, cfg, dcfg, *, n, w_bits, fmt=None):
    """PTQ-quantize ``params`` (consuming any trained quantization state
    riding in the tree -- repro.quant.state) and eval on held-out batches."""
    qc = QuantConfig(w_bits=w_bits, group_size=n, mode="ptq", backend="xla",
                     fmt=fmt)
    qcfg = dataclasses.replace(tiny_lm(), quant=qc)
    qparams, _plan, qapi = quantize_and_plan(build_model(qcfg), params)
    loss, top1 = eval_loss_and_top1(qapi, qparams, qcfg, dcfg)
    return loss, top1


def _finetune(method, params, cfg, dcfg, *, n, w_bits, fmt, steps):
    """Fine-tune pre-initialized ``params`` under one retraining method and
    return the trained tree (state leaves included for ttq/inq)."""
    qat_fmt = "ttq" if method == "ttq" else fmt
    qat_cfg = dataclasses.replace(
        tiny_lm(),
        quant=QuantConfig(w_bits=w_bits, group_size=n, mode="qat",
                          fmt=qat_fmt),
    )
    qat_api = build_model(qat_cfg).compiled(params)
    p0, quant_state = params, None
    if method in ("ttq", "inq"):
        p0, quant_state = init_quant_state(
            params, qat_api.ctx.plan, method, total_steps=steps
        )
    tcfg = TrainConfig(opt=OptConfig(lr=FT_LR, warmup_steps=0,
                                     decay_steps=steps, weight_decay=0.0))
    tr = Trainer(qat_api.train_loss, p0, tcfg, plan=qat_api.ctx.plan,
                 quant_state=quant_state)
    tr.train(lambda i: make_batch(cfg, dcfg, 500 + i), steps)
    return tr.params


def run(csv=print, qat_steps: int = 120, fp_steps: int = 150,
        smoke: bool = False, json_path: str = None):
    """Accuracy-vs-method trajectory.  Returns the row list."""
    if smoke:
        fp_steps, qat_steps = 100, 60
    cfg, api, params, dcfg, _ = train_fp_baseline(steps=fp_steps)
    fp_loss, fp_top1 = eval_loss_and_top1(api, params, cfg, dcfg)
    csv(f"finetune/fp,0,loss={fp_loss:.4f};top1={fp_top1:.4f}")

    n = 64  # the cluster size the paper says NEEDS retraining
    cells = [("ternary_N64", 2), ("int4_N64", 4)]
    if smoke:
        cells = cells[:1]
    rows = [{"cell": "fp", "method": "fp", "loss": fp_loss, "top1": fp_top1,
             "recovered": 0.0}]
    for cell, w_bits in cells:
        ptq_loss, ptq_top1 = _eval_ptq(params, cfg, dcfg, n=n, w_bits=w_bits)
        csv(f"finetune/{cell}/ptq,0,loss={ptq_loss:.4f};top1={ptq_top1:.4f}")
        rows.append({"cell": cell, "method": "ptq", "loss": ptq_loss,
                     "top1": ptq_top1, "recovered": 0.0})
        methods = ["qat", "ttq", "inq"] if w_bits == 2 else ["qat", "inq"]
        for method in methods:
            ft = _finetune(method, params, cfg, dcfg,
                           n=n, w_bits=w_bits, fmt=None, steps=qat_steps)
            # ttq/inq deploy on their LEARNED grids (quantize_params
            # consumes the trained scale leaves riding in the tree)
            loss, top1 = _eval_ptq(
                ft, cfg, dcfg, n=n, w_bits=w_bits,
                fmt="ttq" if method == "ttq" else None,
            )
            rec = ptq_loss - loss
            csv(f"finetune/{cell}/{method},0,"
                f"loss={loss:.4f};top1={top1:.4f};recovered={rec:+.4f}")
            rows.append({"cell": cell, "method": method, "loss": loss,
                         "top1": top1, "recovered": rec})
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1)
        csv(f"finetune/json,0,wrote={json_path}")
    if smoke:
        by = {r["method"]: r["loss"] for r in rows
              if r["cell"] == "ternary_N64"}
        for method in ("qat", "ttq", "inq"):
            assert by[method] < by["ptq"], (
                f"{method} loss {by[method]:.4f} did not recover vs "
                f"one-shot ptq {by['ptq']:.4f}"
            )
        csv("finetune/smoke,0,ok=recovery direction holds for qat/ttq/inq")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="ternary cell only at reduced steps; assert the "
                         "recovery direction (retrained < ptq loss)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the trajectory rows as JSON (the committed "
                         "benchmarks/BENCH_finetune.json baseline)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(smoke=args.smoke, json_path=args.json)
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
