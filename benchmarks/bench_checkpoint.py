"""Artifact persistence bench: packed QTensor+plan artifact vs fp32 checkpoint.

Measures the deployment claim behind the artifact lifecycle: the on-disk
packed artifact (2-bit ternary / 4-bit weights, 8-bit DFP scale tables,
plan JSON) versus the fp32 training checkpoint of the same model --

  * size on disk (the artifact is the unit of deployment: >= 4x smaller,
    ~10x+ for ternary on projection-dominated models),
  * save and restore wall time (cold-start cost for a serving process).

Rows: ckpt_fp32_save / artifact_save_b{2,4} report wall us with the on-disk
MB as the derived column; *_restore rows report wall us with the fp32/packed
size ratio as derived.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import jax

from repro.configs.base import ArchConfig, QuantConfig
from repro.models import build_model, load_servable, quantize_and_plan, save_servable
from repro.training import checkpoint as ck
from repro.training.checkpoint import dir_bytes


def _bench_cfg(w_bits: int) -> ArchConfig:
    """Projection-dominated dense LM (embedding small relative to blocks),
    so the measured ratio reflects what real-scale archs see."""
    return ArchConfig(
        name="bench-ckpt-lm", family="dense",
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, d_ff=1024,
        vocab=512, head_dim=64, remat=False, dtype="float32",
        quant=QuantConfig(w_bits=w_bits, group_size=64, mode="ptq", backend="xla"),
    )


def run(csv=print) -> None:
    params = build_model(_bench_cfg(2)).init(jax.random.PRNGKey(0))
    jax.block_until_ready(params)
    root = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        fp_dir = os.path.join(root, "fp32")
        t0 = time.perf_counter()
        ck.save(fp_dir, 0, params)
        t_save = time.perf_counter() - t0
        fp_bytes = dir_bytes(fp_dir)
        csv(f"ckpt_fp32_save,{t_save * 1e6:.0f},{fp_bytes / 1e6:.2f}MB")

        template = jax.eval_shape(lambda: params)
        t0 = time.perf_counter()
        step, tree = ck.restore_latest(fp_dir, template)
        jax.block_until_ready(tree)
        t_restore = time.perf_counter() - t0
        assert step == 0
        csv(f"ckpt_fp32_restore,{t_restore * 1e6:.0f},1.0x")

        for bits in (2, 4):
            api = build_model(_bench_cfg(bits))
            qparams, plan, qapi = quantize_and_plan(api, params)
            jax.block_until_ready(qparams)
            q_dir = os.path.join(root, f"artifact_b{bits}")
            t0 = time.perf_counter()
            save_servable(q_dir, qapi, qparams, plan)
            t_save = time.perf_counter() - t0
            q_bytes = dir_bytes(q_dir)
            csv(f"artifact_save_b{bits},{t_save * 1e6:.0f},{q_bytes / 1e6:.2f}MB")

            t0 = time.perf_counter()
            _, loaded, _ = load_servable(q_dir)
            jax.block_until_ready(loaded)
            t_restore = time.perf_counter() - t0
            ratio = fp_bytes / q_bytes
            csv(f"artifact_restore_b{bits},{t_restore * 1e6:.0f},{ratio:.1f}x")
            # the deployment claim: packed artifact >= 4x smaller than fp32
            assert ratio >= 4.0, f"artifact only {ratio:.1f}x smaller"
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    run()
