"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Mapping to the paper:
  * bench_quant_error   -> Fig. 1 + Sec. 3 accuracy claims (PTQ sweep)
  * bench_op_ratio      -> Sec. 3.3 performance model (85% / 98% numbers)
  * bench_finetune      -> Fig. 2 + Sec. 4 (pre-initialized QAT recovery)
  * bench_cluster_hier  -> Sec. 3.1 hierarchical-search ablation
  * bench_kernels       -> kernel microbench + HBM compression (Sec. 3.3 /
                           DESIGN 2.1 TPU adaptation)
  * bench_dispatch      -> repro.quant dispatch overhead (registry vs the
                           legacy string ladder; plan table vs regex resolve)
  * bench_checkpoint    -> packed artifact vs fp32 checkpoint: on-disk size
                           and save/restore wall time (artifact lifecycle)
  * bench_decode        -> fused decode pipeline: tokens/sec per format x
                           {fused,unfused,xla}, HBM passes per dense site,
                           ragged-batch recompile count (BENCH trajectory;
                           standalone --json for the full table)
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        bench_checkpoint,
        bench_cluster_hier,
        bench_decode,
        bench_dispatch,
        bench_finetune,
        bench_kernels,
        bench_op_ratio,
        bench_quant_error,
    )

    print("name,us_per_call,derived")
    for mod in (
        bench_op_ratio,
        bench_dispatch,
        bench_checkpoint,
        bench_decode,
        bench_cluster_hier,
        bench_kernels,
        bench_quant_error,
        bench_finetune,
    ):
        t0 = time.time()
        mod.run(csv=print)
        print(
            f"_meta/{mod.__name__.split('.')[-1]}_wall_s,"
            f"{(time.time() - t0) * 1e6:.0f},ok",
            flush=True,
        )


if __name__ == "__main__":
    sys.exit(main())
