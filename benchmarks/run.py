"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Mapping to the paper:
  * bench_quant_error   -> Fig. 1 + Sec. 3 accuracy claims (PTQ sweep)
  * bench_op_ratio      -> Sec. 3.3 performance model (85% / 98% numbers)
  * bench_finetune      -> Fig. 2 + Sec. 4 (pre-initialized QAT recovery),
                           extended to the stateful methods (ttq, inq) --
                           ``--finetune-json`` writes the committed
                           ``benchmarks/BENCH_finetune.json`` baseline
  * bench_cluster_hier  -> Sec. 3.1 hierarchical-search ablation
  * bench_kernels       -> kernel microbench + HBM compression (Sec. 3.3 /
                           DESIGN 2.1 TPU adaptation)
  * bench_dispatch      -> repro.quant dispatch overhead (registry vs the
                           legacy string ladder; plan table vs regex resolve)
  * bench_checkpoint    -> packed artifact vs fp32 checkpoint: on-disk size
                           and save/restore wall time (artifact lifecycle)
  * bench_decode        -> fused decode pipeline: tokens/sec per format x
                           {fused,unfused,xla} with a mesh axis (per-device
                           tokens/sec), HBM passes per dense site,
                           ragged-batch recompile count (BENCH trajectory;
                           standalone --json for the full table)
  * bench_serving       -> staged vs lockstep engines under open-loop
                           Poisson load: sustained tok/s + TTFT/TPOT p95
                           for ternary + int8, greedy parity asserted
                           (``--serving-json`` writes the committed
                           ``benchmarks/BENCH_serving.json`` baseline)

BENCH trajectory tooling:

  * ``--json PATH``  runs the decode benchmark alone and writes its table
    (how ``benchmarks/BENCH_decode.json``, the committed baseline, is made)
  * ``--check [PATH]`` runs the decode benchmark and FAILS (exit 1) if any
    (format, mode, mesh) cell's decode tokens/sec regressed more than 20%
    vs the committed baseline (default ``benchmarks/BENCH_decode.json``),
    judged on absolute AND run-normalized tokens/sec together so neither
    machine-wide drift nor single-cell jitter alone trips the gate
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_decode.json")
REGRESSION_FRAC = 0.20  # fail --check beyond 20% tokens/sec loss


def resolve_baseline(path: str = None, runner_class: str = None) -> str:
    """Baseline path for --check, split per runner class when one exists.

    Shared-runner wall clocks are bimodal across runner CLASSES (a hosted
    CI container and the dev box are different machines wearing the same
    gate), so a flapping gate splits its baseline: commit
    ``BENCH_decode.<class>.json`` next to the default and select it with
    ``--runner-class <class>`` or ``BENCH_RUNNER_CLASS=<class>``.  Falls
    back to the shared default when no per-class file exists, so the split
    is opt-in per class and nothing breaks when classes agree."""
    if path and path != BASELINE:
        return path  # an explicit baseline always wins
    runner_class = runner_class or os.environ.get("BENCH_RUNNER_CLASS")
    if runner_class:
        split = os.path.join(
            os.path.dirname(BASELINE),
            f"BENCH_decode.{runner_class}.json",
        )
        if os.path.exists(split):
            return split
    return path or BASELINE


def _row_key(row: dict):
    return (row.get("format"), row.get("mode"), row.get("mesh", "1"))


def _row_tput(row: dict):
    """The cell's gated throughput: decode tok/s, or prefill-chunk tok/s
    for the prefill-over-packed-cache cells.  None = not a gated cell
    (e.g. the jaxpr-evidence rows)."""
    return row.get("decode_tok_per_s", row.get("prefill_tok_per_s"))


def _geomean(vals):
    import math

    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def check_decode(
    rows: list, baseline_path: str = BASELINE, normalized_only: bool = False
) -> list:
    """Cells regressing >20% decode tokens/sec vs the committed baseline.

    Two independent noise modes exist on shared CI containers: machine-wide
    drift (every cell slower -- absolute comparison flakes) and single-cell
    jitter (one interpret-mode cell hiccups -- comparison normalized by the
    run's geometric mean flakes, because the mean itself moves).  A REAL
    regression -- one path broke (fusion lost, a new reshard in the decode
    graph) on a machine that is not uniformly slower -- shows in BOTH
    signals, so a cell fails only when its absolute tokens/sec AND its
    run-normalized tokens/sec each drop more than 20%.

    ``normalized_only`` drops the absolute comparison: the right mode when
    the checking machine is a DIFFERENT class from the one that produced
    the baseline (e.g. a hosted CI runner vs the dev box), where every
    absolute number shifts together and only the cells' relative structure
    is comparable."""
    with open(baseline_path) as f:
        base = {
            _row_key(r): r for r in json.load(f)
            if "format" in r and _row_tput(r) is not None
        }
    cur = {
        _row_key(r): r for r in rows
        if "format" in r and _row_tput(r) is not None
    }
    common = sorted(set(base) & set(cur))
    if not common:
        raise ValueError(
            f"no common (format, mode, mesh) cells between the current run "
            f"{sorted(cur)} and baseline {baseline_path!r} {sorted(base)}: "
            "the gate would pass vacuously -- regenerate the baseline with "
            "matching cells (run.py --json [--mesh SPEC])"
        )
    base_mean = _geomean([_row_tput(base[k]) for k in common])
    cur_mean = _geomean([_row_tput(cur[k]) for k in common])
    bad = []
    for k in common:
        abs_base = _row_tput(base[k])
        abs_cur = _row_tput(cur[k])
        rel_base = abs_base / base_mean
        rel_cur = abs_cur / cur_mean
        lost = 1.0 - REGRESSION_FRAC
        abs_regressed = normalized_only or abs_cur < abs_base * lost
        if abs_regressed and rel_cur < rel_base * lost:
            bad.append({
                "cell": k,
                "baseline_tok_s": abs_base,
                "current_tok_s": abs_cur,
                "baseline_rel": rel_base,
                "current_rel": rel_cur,
            })
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="run the decode benchmark only and write its JSON "
                         "table (the BENCH trajectory baseline)")
    ap.add_argument("--check", nargs="?", const=BASELINE, default=None,
                    metavar="BASELINE",
                    help="run the decode benchmark and fail on a >20%% "
                         "tokens/sec regression vs the baseline JSON")
    ap.add_argument("--check-normalized-only", action="store_true",
                    help="with --check: compare only run-normalized "
                         "tokens/sec (skip the absolute signal) -- for "
                         "checking on a different machine class than the "
                         "one that produced the baseline (hosted CI "
                         "runners vs the dev box)")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="run/check the decode cells sharded (e.g. "
                         "'dp=2,ep=2'); baseline cells are keyed on the "
                         "mesh spec, so sharded baselines gate the sharded "
                         "engine")
    ap.add_argument("--runner-class", default=None, metavar="NAME",
                    help="with --check: prefer a per-runner-class baseline "
                         "benchmarks/BENCH_decode.NAME.json when one is "
                         "committed (else the shared default) -- the "
                         "anti-flap split for gates spanning machine "
                         "classes; BENCH_RUNNER_CLASS env works too")
    ap.add_argument("--serving-json", default=None, metavar="PATH",
                    help="run the serving benchmark only (staged vs "
                         "lockstep under Poisson load) and write its JSON "
                         "table -- how benchmarks/BENCH_serving.json is "
                         "made")
    ap.add_argument("--finetune-json", default=None, metavar="PATH",
                    help="run the fine-tune benchmark only (ptq/qat/ttq/inq "
                         "accuracy trajectory) and write its JSON table -- "
                         "how benchmarks/BENCH_finetune.json is made")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_checkpoint,
        bench_cluster_hier,
        bench_decode,
        bench_dispatch,
        bench_finetune,
        bench_kernels,
        bench_op_ratio,
        bench_quant_error,
        bench_serving,
    )

    if args.serving_json:
        print("name,us_per_call,derived")
        bench_serving.run(csv=print, json_path=args.serving_json)
        return 0

    if args.finetune_json:
        print("name,us_per_call,derived")
        bench_finetune.run(csv=print, json_path=args.finetune_json)
        return 0

    if args.json or args.check:
        print("name,us_per_call,derived")
        rows = bench_decode.run(
            csv=print, json_path=args.json, mesh_spec=args.mesh
        )
        if args.check:
            norm_only = args.check_normalized_only
            baseline = resolve_baseline(args.check, args.runner_class)
            if baseline != args.check:
                print(f"using per-runner-class baseline {baseline}",
                      flush=True)
            bad = check_decode(rows, baseline, normalized_only=norm_only)
            if bad:
                # persistent-regression filter: wall-clock cells on shared
                # containers are bimodal, so a flagged cell must regress in
                # a SECOND independent run too before the gate fails
                print(
                    f"{len(bad)} cell(s) flagged; re-running once to rule "
                    "out container noise",
                    flush=True,
                )
                flagged = {b["cell"] for b in bad}
                rows2 = bench_decode.run(csv=print, mesh_spec=args.mesh)
                bad = [
                    b for b in check_decode(
                        rows2, baseline, normalized_only=norm_only
                    )
                    if b["cell"] in flagged
                ]
            if bad:
                for b in bad:
                    print(
                        f"REGRESSION {b['cell']}: "
                        f"{b['current_tok_s']:.1f} tok/s vs baseline "
                        f"{b['baseline_tok_s']:.1f} "
                        f"(normalized {b['current_rel']:.2f} vs "
                        f"{b['baseline_rel']:.2f}; >"
                        f"{REGRESSION_FRAC:.0%} loss)",
                        flush=True,
                    )
                return 1
            print(f"decode check ok vs {baseline}", flush=True)
        return 0

    print("name,us_per_call,derived")
    for mod in (
        bench_op_ratio,
        bench_dispatch,
        bench_checkpoint,
        bench_decode,
        bench_cluster_hier,
        bench_kernels,
        bench_serving,
        bench_quant_error,
        bench_finetune,
    ):
        t0 = time.time()
        mod.run(csv=print)
        print(
            f"_meta/{mod.__name__.split('.')[-1]}_wall_s,"
            f"{(time.time() - t0) * 1e6:.0f},ok",
            flush=True,
        )
    return 0


if __name__ == "__main__":
    # forced host devices for --mesh must be set before jax initializes
    from repro.launch.mesh import preinit_mesh_flag

    preinit_mesh_flag(sys.argv)
    sys.exit(main())
