"""Shared benchmark helpers: tiny trainable LM + per-arch GEMM inventories."""
from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, QuantConfig
from repro.core.stats import GemmSpec
from repro.models import build_model
from repro.models.ssm import d_inner
from repro.training import OptConfig, TrainConfig, Trainer
from repro.training.data import DataConfig, make_batch


def tiny_lm(quant: QuantConfig | None = None, group_size: int = 16) -> ArchConfig:
    """Small-but-trainable dense LM for the accuracy-proxy experiments."""
    return ArchConfig(
        name="bench-lm", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=512, head_dim=32, remat=False, dtype="float32",
        quant=quant or QuantConfig(mode="fp", group_size=group_size),
    )


def train_fp_baseline(steps: int = 150, seed: int = 0):
    """Returns (cfg, api, trained params, data config, final loss)."""
    cfg = tiny_lm()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    dcfg = DataConfig(batch=16, seq=64, seed=seed, structure=0.9)
    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=20, decay_steps=steps))
    tr = Trainer(api.train_loss, params, tcfg)
    hist = tr.train(lambda i: make_batch(cfg, dcfg, i), steps)
    return cfg, api, tr.params, dcfg, hist


def eval_loss_and_top1(api, params, cfg, dcfg, n_batches: int = 4, seed: int = 10_000):
    """Eval CE + next-token top-1 on held-out synthetic batches."""
    tot_loss, tot_hit, tot_n = 0.0, 0.0, 0
    for i in range(n_batches):
        batch = make_batch(cfg, dcfg, seed + i)
        tot_loss += float(api.train_loss(params, batch))
        logits = api.forward(params, batch)
        pred = jnp.argmax(logits[..., : cfg.vocab], axis=-1)
        tot_hit += float(jnp.mean(pred == batch["labels"]))
        tot_n += 1
    return tot_loss / tot_n, tot_hit / tot_n


def arch_gemms(cfg: ArchConfig) -> List[GemmSpec]:
    """Per-token GEMM inventory for one assigned architecture."""
    d = cfg.d_model
    hd = cfg.hd() if cfg.n_heads else 0
    gemms: List[GemmSpec] = []
    if cfg.n_heads:
        gemms += [
            GemmSpec("wq", d, cfg.n_heads * hd, cfg.n_layers),
            GemmSpec("wk", d, cfg.n_kv_heads * hd, cfg.n_layers),
            GemmSpec("wv", d, cfg.n_kv_heads * hd, cfg.n_layers),
            GemmSpec("wo", cfg.n_heads * hd, d, cfg.n_layers),
        ]
    if cfg.n_experts:
        active = cfg.top_k
        gemms += [
            GemmSpec("router", d, cfg.n_experts, cfg.n_layers, weight_quantized=False),
            GemmSpec("moe_gate", d, cfg.d_ff, cfg.n_layers * active),
            GemmSpec("moe_up", d, cfg.d_ff, cfg.n_layers * active),
            GemmSpec("moe_down", cfg.d_ff, d, cfg.n_layers * active),
        ]
        if cfg.moe_dense_residual:
            gemms += [
                GemmSpec("res_gate", d, cfg.d_ff, cfg.n_layers),
                GemmSpec("res_up", d, cfg.d_ff, cfg.n_layers),
                GemmSpec("res_down", cfg.d_ff, d, cfg.n_layers),
            ]
    elif cfg.d_ff:
        n_mlp = cfg.n_layers if cfg.family != "hybrid" else max(
            1, cfg.n_layers // max(cfg.shared_attn_period, 1)
        )
        gemms += [
            GemmSpec("gate", d, cfg.d_ff, n_mlp),
            GemmSpec("up", d, cfg.d_ff, n_mlp),
            GemmSpec("down", cfg.d_ff, d, n_mlp),
        ]
    if cfg.family in ("ssm", "hybrid"):
        di = d_inner(cfg)
        n_ssm = cfg.n_layers
        gemms += [
            GemmSpec("ssm_in", d, 2 * di, n_ssm),
            GemmSpec("ssm_out", di, d, n_ssm),
        ]
        if cfg.ssm_version == 1:
            rank = max(1, -(-d // 16))
            gemms += [
                GemmSpec("x_proj", di, rank + 2 * cfg.ssm_state, n_ssm),
                GemmSpec("dt_proj", rank, di, n_ssm),
            ]
        else:
            gemms += [GemmSpec("bc_proj", d, 2 * cfg.ssm_state, n_ssm)]
    gemms.append(GemmSpec("lm_head", d, cfg.padded_vocab, 1, weight_quantized=False))
    return gemms


def timed(fn, *args, reps: int = 5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us
