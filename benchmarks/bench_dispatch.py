"""Dispatch-overhead microbench for the repro.quant redesign.

Two hot-path dispatch mechanisms changed in the unified API:

  * qmatmul backend dispatch: registered strategy lookup vs the legacy
    in-line string-compare ladder (reconstructed here, calling the same
    strategy functions, so the measured delta is dispatch only).
  * per-site precision resolution: compiled QuantPlan table lookup vs the
    legacy per-call ``PrecisionPolicy.resolve`` regex scan.

Eager-mode microbenchmarks on tiny shapes: the matmul itself is small so
Python-side dispatch is a visible fraction of the call.  (Inside jit both
costs are trace-time only; serving's eager decode tick pays them per call.)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import PrecisionPolicy
from repro.quant import backends, quantize_weights
from repro.quant.backends import get_backend, resolve_backend


def _legacy_ladder(name: str):
    """The pre-registry dispatch shape: one string compare per backend."""
    if name == "auto":
        name = "xla"
    if name == "xla":
        return backends._xla_backend
    if name == "xla_int8":
        return backends._xla_int8_backend
    if name == "ref":
        return backends._ref_backend
    if name == "pallas":
        return backends._pallas_backend
    raise ValueError(name)


def _time_loop(fn, reps: int) -> float:
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(csv=print):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    qt = quantize_weights(
        jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32)), 8, 16
    )

    # resolution-only overhead (no numerics in the loop)
    reps = 20_000
    us = _time_loop(lambda: get_backend(resolve_backend("xla_int8")), reps)
    csv(f"dispatch/backend_registry_lookup,{us:.3f},reps={reps}")
    us = _time_loop(lambda: _legacy_ladder("xla_int8"), reps)
    csv(f"dispatch/backend_string_ladder,{us:.3f},reps={reps}")

    pol = PrecisionPolicy.ternary(64)
    params = {"blocks": {"attn": {"wq": {"w": x}}, "mlp": {"up": {"w": x}}},
              "lm_head": {"w": x}}
    plan = pol.compile(params)
    path = "blocks/mlp/up"
    us = _time_loop(lambda: plan.resolve(path), reps)
    csv(f"dispatch/plan_table_resolve,{us:.3f},reps={reps}")
    us = _time_loop(lambda: pol.resolve(path), reps)
    csv(f"dispatch/policy_regex_resolve,{us:.3f},reps={reps}")

    # end-to-end eager qmatmul (dispatch + numerics) through both mechanisms
    def qmm_registry():
        out = backends.qmatmul(x, qt, backend="xla_int8")
        jax.block_until_ready(out)

    def qmm_ladder():
        xm = x.reshape(-1, x.shape[-1])
        xq, xe = backends.quantize_activations(xm, 8)
        out = _legacy_ladder("xla_int8")(xq, xe, qt)
        jax.block_until_ready(out)

    qmm_registry(), qmm_ladder()  # warm caches
    reps = 50
    us = _time_loop(qmm_registry, reps)
    csv(f"dispatch/qmatmul_eager_registry,{us:.1f},reps={reps}")
    us = _time_loop(qmm_ladder, reps)
    csv(f"dispatch/qmatmul_eager_ladder,{us:.1f},reps={reps}")


if __name__ == "__main__":
    run()
