"""Serving throughput for the fused quantized decode pipeline (BENCH traj).

Cells: {ternary, int4, int8, nf4, mx} x {fused, unfused, xla}, measuring

  * decode tokens/sec  -- one device-resident decode tick (donated cache,
    argmax in-graph) over an ``n_slots`` batch,
  * prefill tokens/sec -- one forward over a (B, S) prompt batch,
  * HBM-visible passes per dense site -- jaxpr equations materializing a
    full-size tensor for one ``qdense`` projection.  The fused path is ONE
    pallas_call; the unfused path stages int8 mantissas, the raw matmul
    output and the scaled/bias output through HBM separately.  (XLA may
    later fuse elementwise stages, but the kernel-boundary buffers are
    structural -- this is the count of *guaranteed* materializations.)
  * ragged-batch recompiles after warmup (power-of-two bucketing: 0),
  * KV-format long-context cells ({kv_bf16, kv_int8, kv_mx} at a
    KV_BENCH_LEN cache): packed cache bytes, bits/value, traffic reduction
    vs bf16, achieved GB/s/device vs the HBM roofline (docs/KV_CACHE.md).

Wall-clock on the CPU container is regression tracking, not the perf claim
(pallas cells run in interpret mode off-TPU; the op-count and recompile
columns are platform-independent).  ``--json out.json`` dumps the table for
the BENCH trajectory; run.py prints the CSV rows.

Every row records a ``mesh`` axis (the spec string, "1" when unsharded) a
``devices`` count, and per-device tokens/sec; ``--mesh dp=2,ep=2`` runs the
decode/prefill cells under NamedSharding on a forced host-device mesh so
the BENCH trajectory tracks the sharded engine too.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import tiny_lm
from repro.configs.base import QuantConfig
from repro.models import build_model, quantize_and_plan
from repro.quant import qdense, quantize_weights

# format name -> (w_bits, QuantConfig.fmt): the paper's three plus the two
# sub-8-bit block formats (nf4 rides int4's width, mx rides int8's; both are
# selected by NAME through the plan, never by bits).  Widths come from the
# registry so the table cannot drift from the formats themselves.
from repro.quant import get_format

FORMATS = {
    name: (get_format(name).bits, name if named else None)
    for name, named in (
        ("ternary", False), ("int4", False), ("int8", False),
        ("nf4", True), ("mx", True),
    )
}
MODES = ("fused", "unfused", "xla")


def _with_fused(plan, fused: bool):
    """Copy of ``plan`` with every site's fused knob forced to ``fused``."""
    return dataclasses.replace(
        plan,
        site_precisions=tuple(
            dataclasses.replace(p, fused=fused) for p in plan.site_precisions
        ),
    )


def _mode_api(api, plan, mode: str):
    if mode == "xla":
        return api.with_plan(dataclasses.replace(plan, backend="xla"))
    plan = _with_fused(plan, mode == "fused")
    return api.with_plan(dataclasses.replace(plan, backend="pallas"))


def _timed_steps(fn, reps: int) -> float:
    """Median per-call seconds over ``reps`` individually-timed calls.

    The median (vs the mean of one batched loop) keeps a single GC pause or
    scheduler hiccup from polluting a cell -- interpret-mode cells on the
    shared CPU container otherwise jitter 25%+ run to run, which is what
    the --check regression gate has to see through."""
    fn()  # compile / warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def count_hbm_passes(fn, *args, min_elems: int) -> int:
    """Jaxpr equations whose output materializes >= ``min_elems`` elements.

    Reshapes are excluded (metadata-only).  For a fused qdense site this is
    exactly the pallas_call; each extra equation in the unfused pipeline is
    a tensor XLA must hold between kernel boundaries.
    """
    jaxpr = jax.make_jaxpr(fn)(*args)
    n = 0
    for eqn in jaxpr.jaxpr.eqns:
        if eqn.primitive.name in ("reshape", "broadcast_in_dim"):
            continue
        if any(int(np.prod(v.aval.shape or (1,))) >= min_elems for v in eqn.outvars):
            n += 1
    return n


def count_float_materializations(fn, *args, min_elems: int) -> int:
    """Float tensors (incl. bf16) >= ``min_elems`` materialized ANYWHERE in
    the jaxpr -- recursing into inner jaxprs (scan/cond/pjit and, in
    interpret mode, pallas_call bodies), unlike ``count_hbm_passes`` which
    sees only top-level kernel-boundary buffers.  This is the
    cache-materialization detector: set ``min_elems`` to one full unpacked
    cache leaf and the oracle read path counts its bf16/f32 casts while a
    flash read path, whose in-VMEM tiles are block-sized, counts zero.
    Reshapes/broadcasts are excluded (metadata-only)."""
    closed = jax.make_jaxpr(fn)(*args)

    def subs(v):
        if hasattr(v, "eqns"):  # raw Jaxpr
            yield v
        elif hasattr(v, "jaxpr"):  # ClosedJaxpr
            yield v.jaxpr
        elif isinstance(v, (tuple, list)):
            for x in v:
                yield from subs(x)

    def walk(jx):
        n = 0
        for eqn in jx.eqns:
            if eqn.primitive.name not in ("reshape", "broadcast_in_dim"):
                for v in eqn.outvars:
                    dt = getattr(v.aval, "dtype", None)
                    sh = getattr(v.aval, "shape", None)
                    if (dt is not None and sh is not None
                            and jnp.issubdtype(dt, jnp.floating)
                            and int(np.prod(sh or (1,))) >= min_elems):
                        n += 1
                        break
            for pv in eqn.params.values():
                for sub in subs(pv):
                    n += walk(sub)
        return n

    return walk(closed.jaxpr)


def _bench_site(bits: int, fmt: str = None) -> Dict[str, int]:
    m, k, n, g = 8, 256, 256, 64
    x = jnp.ones((m, k), jnp.float32)
    qt = quantize_weights(jnp.ones((k, n), jnp.float32), bits, g, fmt=fmt)
    min_elems = m * min(k, n)
    return {
        "fused": count_hbm_passes(
            lambda a: qdense(a, qt, backend="pallas"), x, min_elems=min_elems
        ),
        "unfused": count_hbm_passes(
            lambda a: qdense(a, qt, backend="pallas", fused=False),
            x, min_elems=min_elems,
        ),
    }


def _bench_model(bits: int, mode: str, slots: int, seq: int, reps: int,
                 mesh=None, fmt: str = None):
    cfg = tiny_lm(QuantConfig(w_bits=bits, group_size=16, mode="ptq", fmt=fmt))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    qparams, plan, qapi = quantize_and_plan(api, params)
    mapi = _mode_api(qapi, plan, mode)

    cache = mapi.init_cache(slots, 32)
    tok = jnp.zeros((slots, 1), jnp.int32)
    from repro.parallel import sharding as rules

    prev_mesh = rules._ACT_MESH[0]
    try:
        if mesh is not None:
            rules.set_activation_mesh(mesh)
            qparams = jax.device_put(
                qparams, rules.qtensor_shardings(qparams, mesh)
            )
            cache_sh = rules.cache_shardings(
                jax.tree.map(
                    lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), cache
                ),
                mesh,
            )
            cache = jax.device_put(cache, cache_sh)
            tok = jax.device_put(
                tok, rules.batch_shardings({"t": tok}, mesh)["t"]
            )
        step = jax.jit(
            lambda p, t, pos, c: (
                lambda lg, nc: (jnp.argmax(lg[:, -1, :], -1).astype(jnp.int32), nc)
            )(*mapi.decode(p, t, pos, c)),
            donate_argnums=(3,),
        )
        state = {"c": cache, "i": 0}

        def tick():
            toks, state["c"] = step(
                qparams, tok, jnp.full((slots,), state["i"] % 24, jnp.int32),
                state["c"],
            )
            state["i"] += 1
            return toks

        decode_s = _timed_steps(tick, reps)

        fwd = jax.jit(lambda p, t: mapi.forward(p, {"tokens": t}))
        prompts = jnp.zeros((slots, seq), jnp.int32)
        prefill_s = _timed_steps(
            lambda: fwd(qparams, prompts), max(1, reps // 2)
        )
    finally:  # a failing cell must not leak the global activation mesh
        rules.set_activation_mesh(prev_mesh)

    devices = 1 if mesh is None else mesh.devices.size
    return {
        "decode_tok_per_s": slots / decode_s,
        "decode_step_us": decode_s * 1e6,
        "prefill_tok_per_s": slots * seq / prefill_s,
        "decode_tok_per_s_per_device": slots / decode_s / devices,
        "prefill_tok_per_s_per_device": slots * seq / prefill_s / devices,
    }


KV_FORMATS = ("kv_bf16", "kv_int8", "kv_mx")
KV_BENCH_LEN = 2048  # long-context cell: cache reads dominate decode HBM


def _bench_kv_cache(reps: int, mesh=None, mesh_tag: str = "1") -> List[Dict]:
    """Per-KV-format long-context decode cells.

    One B=1 slot against a KV_BENCH_LEN cache (the regime where cache
    traffic, not weights, bounds the tick).  Columns:

      * kv_cache_bytes / kv_bits_per_value -- the packed read set,
      * cache_reduction_vs_bf16 -- the traffic claim (kv_int8 ~1.94x:
        2hd/(hd+1) with hd=32, the per-token exponent column is the
        asymptotic-2x overhead; kv_mx ~3.99x),
      * achieved_gb_s_per_device vs roofline_gb_s -- cache bytes the tick
        actually streamed against the HBM ceiling.  Meaningful on TPU;
        on the CPU container wall-clock is regression tracking only, the
        bytes columns are platform-independent.

    Ticks run the XLA fold-the-scales path (the portable oracle); the
    Pallas flash-decode kernel is parity-gated in CI (interpret mode) and
    claims its traffic via the same bytes columns.
    """
    from repro.models import kv_cache
    from repro.roofline.analysis import HBM_BW

    slots, reps = 1, max(3, reps // 3)
    rows: List[Dict] = []
    bf16_bytes = None
    for fmt in KV_FORMATS:
        cfg = tiny_lm(QuantConfig(w_bits=8, group_size=16, mode="ptq"))
        cfg = dataclasses.replace(cfg, kv_fmt=fmt)
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        qparams, plan, qapi = quantize_and_plan(api, params)
        cache = qapi.init_cache(slots, KV_BENCH_LEN)
        cbytes = kv_cache.cache_bytes(cache)
        if bf16_bytes is None:
            bf16_bytes = cbytes  # kv_bf16 runs first
        n_values = (2 * cfg.n_layers * slots * KV_BENCH_LEN
                    * cfg.n_kv_heads * cfg.hd())
        tok = jnp.zeros((slots, 1), jnp.int32)
        step = jax.jit(
            lambda p, t, pos, c, _api=qapi: (
                lambda lg, nc: (jnp.argmax(lg[:, -1, :], -1).astype(jnp.int32), nc)
            )(*_api.decode(p, t, pos, c)),
            donate_argnums=(3,),
        )
        state = {"c": cache, "i": KV_BENCH_LEN // 2}

        def tick():
            toks, state["c"] = step(
                qparams, tok,
                jnp.full((slots,), state["i"] % (KV_BENCH_LEN - 1), jnp.int32),
                state["c"],
            )
            state["i"] += 1
            return toks

        decode_s = _timed_steps(tick, reps)
        devices = 1 if mesh is None else mesh.devices.size
        rows.append({
            "format": fmt, "mode": "cache",
            "mesh": mesh_tag, "devices": devices,
            "seq_len": KV_BENCH_LEN,
            "decode_tok_per_s": slots / decode_s,
            "decode_step_us": decode_s * 1e6,
            "kv_cache_bytes": cbytes,
            "kv_bits_per_value": cbytes * 8 / n_values,
            "cache_reduction_vs_bf16": bf16_bytes / cbytes,
            "achieved_gb_s_per_device": cbytes / decode_s / 1e9 / devices,
            "roofline_gb_s": HBM_BW / 1e9,
        })
    return rows


def _prefill_read_materializations(fmt: str) -> Dict[str, int]:
    """Full-cache float materializations on the S>1 cache-attend READ path.

    Isolates the two read formulations over one identical packed cache
    (write path excluded -- kv_mx's running-max rescale materializes a
    full buffer on WRITE in both paths, which is not the claim here):

      * oracle -- ``attend_view`` + ``_attend_dense``: the integer/bf16
        codes cast to a full (B,T,Kh,hd) float tensor per leaf,
      * flash  -- ``flash_attend``: packed leaves stream through
        block-sized VMEM tiles; nothing cache-sized is ever float.

    The threshold is exactly one unpacked cache leaf, so flash == 0 IS the
    one-HBM-pass / no-bf16-materialization acceptance claim."""
    from repro.kernels.flash_prefill import flash_attend
    from repro.models import attention as attn, kv_cache

    b, t, kh, g, hd, s, start = 1, 256, 2, 2, 16, 8, 192

    class _Cfg:
        kv_bits = 16
        n_kv_heads = kh
        kv_fmt = fmt

        @staticmethod
        def hd():
            return hd

    rng = np.random.default_rng(0)
    cache = kv_cache.init_cache(_Cfg, (b,), t)
    hist = jnp.asarray(rng.normal(size=(b, start + s, kh, hd)), jnp.float32)
    cache, valid = kv_cache.write(fmt, cache, hist, hist, jnp.int32(0))
    q = jnp.asarray(rng.normal(size=(b, s, kh, g, hd)), jnp.float32)
    q_pos = start + jnp.arange(s)

    def oracle(c, qq):
        ck, cv, ks, vs = kv_cache.attend_view(fmt, c)
        bias = attn._mask_bias(
            jnp.broadcast_to(q_pos, (b, s)), jnp.arange(t), True, None, valid
        )
        return attn._attend_dense(
            qq, ck, cv, bias[:, None, None], kscale=ks, vscale=vs
        )

    def flash(c, qq):
        return flash_attend(
            qq, c["k"], c["v"], c.get("ke"), c.get("ve"),
            jnp.full((b, 1), start, jnp.int32),
            valid.astype(jnp.int32).reshape(b, 1),
            jnp.full((1, 1), 2**30, jnp.int32), fmt=fmt, interpret=True,
        )

    min_elems = t * kh * hd  # one full unpacked cache leaf
    return {
        "oracle": count_float_materializations(
            oracle, cache, q, min_elems=min_elems
        ),
        "flash": count_float_materializations(
            flash, cache, q, min_elems=min_elems
        ),
    }


def _bench_kv_prefill(reps: int, mesh_tag: str = "1") -> List[Dict]:
    """Chunked-prefill-over-packed-cache cells at KV_BENCH_LEN.

    One 64-token chunk dispatched mid-prompt (start = T/2) against a B=1
    KV_BENCH_LEN cache, per kv format x {oracle, flash} -- the TTFT hot
    path.  Columns mirror the decode kv cells: chunk tokens/sec plus the
    cache bytes the dispatch streamed against the HBM roofline.  The flash
    cells run the Pallas kernel (interpret mode off-TPU); the oracle cells
    are the XLA fold-the-scales path.  Cells are keyed
    (format, prefill_{oracle,flash}, mesh) in the --check gate."""
    from repro.models import kv_cache
    from repro.roofline.analysis import HBM_BW

    chunk, reps = 64, max(3, reps // 3)
    rows: List[Dict] = []
    for fmt in KV_FORMATS:
        for flash in (False, True):
            cfg = tiny_lm(QuantConfig(w_bits=8, group_size=16, mode="ptq"))
            cfg = dataclasses.replace(
                cfg, kv_fmt=fmt, flash_prefill=flash
            )
            api = build_model(cfg)
            params = api.init(jax.random.PRNGKey(0))
            qparams, plan, qapi = quantize_and_plan(api, params)
            cache = qapi.init_cache(1, KV_BENCH_LEN)
            cbytes = kv_cache.cache_bytes(cache)
            toks = jnp.zeros((1, chunk), jnp.int32)
            step = jax.jit(
                lambda p, tk, st, c, _api=qapi: _api.prefill_chunk(
                    p, tk, st, c
                ),
                donate_argnums=(3,),
            )
            state = {"c": cache}

            def tick():
                lg, state["c"] = step(
                    qparams, toks, jnp.int32(KV_BENCH_LEN // 2), state["c"]
                )
                return lg

            prefill_s = _timed_steps(tick, reps)
            rows.append({
                "format": fmt,
                "mode": "prefill_flash" if flash else "prefill_oracle",
                "mesh": mesh_tag, "devices": 1,
                "seq_len": KV_BENCH_LEN, "chunk": chunk,
                "prefill_tok_per_s": chunk / prefill_s,
                "prefill_chunk_us": prefill_s * 1e6,
                "kv_cache_bytes": cbytes,
                "achieved_gb_s_per_device": cbytes / prefill_s / 1e9,
                "roofline_gb_s": HBM_BW / 1e9,
            })
    return rows


def _ragged_recompiles() -> int:
    """Fused-path recompiles across ragged batch sizes after bucket warmup."""
    from repro.kernels.ternary_matmul import ternary_matmul_fused

    qt = quantize_weights(jnp.ones((64, 32), jnp.float32), 2, 16)
    qdense(jnp.ones((8, 64)), qt, backend="pallas")  # warm the M=8 bucket
    base = ternary_matmul_fused._cache_size()
    for m in (1, 2, 3, 5, 7, 8, 6, 4):
        qdense(jnp.ones((m, 64)), qt, backend="pallas")
    return ternary_matmul_fused._cache_size() - base


def run(csv=print, *, slots: int = 4, seq: int = 16, reps: int = 15,
        json_path: str = None, mesh_spec: str = None) -> List[Dict]:
    mesh = None
    if mesh_spec:
        from repro.launch.mesh import parse_mesh_spec

        mesh = parse_mesh_spec(mesh_spec)
    mesh_tag = mesh_spec or "1"
    devices = 1 if mesh is None else mesh.devices.size
    rows: List[Dict] = []
    for fmt, (bits, fmt_name) in FORMATS.items():
        passes = _bench_site(bits, fmt=fmt_name)
        csv(
            f"decode/hbm_passes_{fmt},{passes['fused']:.0f},"
            f"unfused={passes['unfused']};fused_is_single_kernel="
            f"{str(passes['fused'] == 1).lower()}"
        )
        for mode in MODES:
            r = _bench_model(bits, mode, slots, seq, reps, mesh=mesh,
                             fmt=fmt_name)
            rows.append({
                "format": fmt, "mode": mode,
                "mesh": mesh_tag, "devices": devices, **r,
                "hbm_passes_per_site": passes.get(mode, passes["unfused"]),
            })
            csv(
                f"decode/{fmt}_{mode},{r['decode_step_us']:.1f},"
                f"decode_tok_s={r['decode_tok_per_s']:.1f};"
                f"prefill_tok_s={r['prefill_tok_per_s']:.1f};"
                f"mesh={mesh_tag};"
                f"tok_s_per_dev={r['decode_tok_per_s_per_device']:.1f}"
            )
    for r in _bench_kv_cache(reps, mesh=mesh, mesh_tag=mesh_tag):
        rows.append(r)
        csv(
            f"decode/kv_{r['format']}_T{r['seq_len']},{r['decode_step_us']:.1f},"
            f"cache_mb={r['kv_cache_bytes'] / 1e6:.2f};"
            f"bits_per_value={r['kv_bits_per_value']:.2f};"
            f"reduction_vs_bf16={r['cache_reduction_vs_bf16']:.2f}x;"
            f"achieved_gb_s_per_dev={r['achieved_gb_s_per_device']:.3f};"
            f"roofline_gb_s={r['roofline_gb_s']:.0f}"
        )
    for r in _bench_kv_prefill(reps, mesh_tag=mesh_tag):
        rows.append(r)
        csv(
            f"decode/{r['mode']}_{r['format']}_T{r['seq_len']},"
            f"{r['prefill_chunk_us']:.1f},"
            f"prefill_tok_s={r['prefill_tok_per_s']:.1f};"
            f"chunk={r['chunk']};"
            f"cache_mb={r['kv_cache_bytes'] / 1e6:.2f};"
            f"achieved_gb_s_per_dev={r['achieved_gb_s_per_device']:.3f};"
            f"roofline_gb_s={r['roofline_gb_s']:.0f}"
        )
    for fmt in KV_FORMATS:
        m = _prefill_read_materializations(fmt)
        csv(
            f"decode/prefill_read_materializations_{fmt},{m['flash']:.0f},"
            f"oracle={m['oracle']};"
            f"flash_single_pass={str(m['flash'] == 0).lower()}"
        )
        rows.append({
            "format": fmt, "mode": "prefill_read",
            "mesh": mesh_tag,
            "prefill_read_materializations_flash": m["flash"],
            "prefill_read_materializations_oracle": m["oracle"],
            "flash_single_pass": m["flash"] == 0,
        })
    rc = _ragged_recompiles()
    csv(f"decode/ragged_recompiles_after_warmup,{rc:.0f},want=0")
    rows.append({"ragged_recompiles_after_warmup": rc, "mesh": mesh_tag})
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


if __name__ == "__main__":
    import argparse
    import sys

    # forced host devices for --mesh must be set before jax initializes
    from repro.launch.mesh import preinit_mesh_flag

    preinit_mesh_flag(sys.argv)

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="dump the table as JSON")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--reps", type=int, default=15)
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="run the decode cells sharded, e.g. 'dp=2,ep=2'")
    a = ap.parse_args()
    run(slots=a.slots, seq=a.seq, reps=a.reps, json_path=a.json,
        mesh_spec=a.mesh)
