"""Paper Fig. 1 / Sec. 3 analogue: PTQ accuracy vs weight bits and cluster
size N -- the central accuracy claim, on a trainable-here proxy LM.

Reproduces the paper's qualitative structure:
  * 8a-8w ~ fp baseline,
  * 8a-4w within a small gap (paper: within 2% top-1),
  * 8a-2w (ternary) a larger gap (paper: within 6%),
  * growing the cluster size N degrades ternary accuracy (the Sec.-3.3
    performance/accuracy trade-off) -- the motivation for Sec. 4 retraining.
Also reports the raw weight-reconstruction error on ResNet-101-shaped weight
ensembles (direct Algorithm-1 validation without training in the loop).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import eval_loss_and_top1, tiny_lm, train_fp_baseline
from repro.configs.base import QuantConfig
from repro.core import quantizer
from repro.models import build_model, quantize_and_plan


def run(csv=print):
    cfg, api, params, dcfg, hist = train_fp_baseline(steps=150)
    fp_loss, fp_top1 = eval_loss_and_top1(api, params, cfg, dcfg)
    csv(f"quant_error/fp_baseline,0,loss={fp_loss:.4f};top1={fp_top1:.4f}")

    for bits in (8, 4, 2):
        for n in (4, 16, 64):
            qc = QuantConfig(w_bits=bits, group_size=n, mode="ptq", backend="xla")
            qcfg = dataclasses.replace(tiny_lm(), quant=qc)
            qparams, _plan, qapi = quantize_and_plan(build_model(qcfg), params)
            loss, top1 = eval_loss_and_top1(qapi, qparams, qcfg, dcfg)
            csv(
                f"quant_error/8a-{bits}w-N{n},0,"
                f"loss={loss:.4f};top1={top1:.4f};"
                f"dloss={loss - fp_loss:+.4f};dtop1={top1 - fp_top1:+.4f}"
            )

    # sub-8-bit block formats: nf4 sweeps the cluster size like int4 (same
    # 4-bit budget, quantile grid); mx pins its 32-element block, so it gets
    # one cell.  Selected by NAME through the plan (QuantConfig.fmt).
    for fmt, bits, sweep in (("nf4", 4, (4, 16, 64)), ("mx", 8, (32,))):
        for n in sweep:
            qc = QuantConfig(
                w_bits=bits, group_size=n, mode="ptq", backend="xla", fmt=fmt
            )
            qcfg = dataclasses.replace(tiny_lm(), quant=qc)
            qparams, _plan, qapi = quantize_and_plan(build_model(qcfg), params)
            loss, top1 = eval_loss_and_top1(qapi, qparams, qcfg, dcfg)
            csv(
                f"quant_error/8a-{fmt}-N{n},0,"
                f"loss={loss:.4f};top1={top1:.4f};"
                f"dloss={loss - fp_loss:+.4f};dtop1={top1 - fp_top1:+.4f}"
            )

    # direct Algorithm-1 reconstruction error on ResNet-101-shaped ensembles
    rng = np.random.default_rng(0)
    for name, (k, nout, f) in {
        "res101_3x3x256": (256 * 9, 256, 9),
        "res101_1x1x1024": (1024, 256, 1),
    }.items():
        w = jnp.asarray(rng.normal(size=(k, nout)).astype(np.float32))
        for bits in (2, 4, 8):
            for n in (4, 64):
                g = n * f
                if k % g:
                    continue
                err = float(
                    quantizer.weight_quantization_error(w, bits, g, f)
                ) / float(jnp.sum(w * w))
                csv(f"quant_error/recon_{name}_{bits}w_N{n},0,rel_err={err:.4f}")
        # block formats on the same ensembles (fmt-selected; mx block fixed)
        for fmt, groups in (("nf4", (32, 64)), ("mx", (32,))):
            for g in groups:
                if k % g:
                    continue
                qt = quantizer.quantize_weights(w, group_size=g, fmt=fmt)
                rec = quantizer.dequantize_weights(qt)
                err = float(jnp.sum((w - rec) ** 2)) / float(jnp.sum(w * w))
                csv(f"quant_error/recon_{name}_{fmt}_N{g},0,rel_err={err:.4f}")
    return {"fp_loss": fp_loss, "fp_top1": fp_top1}


if __name__ == "__main__":
    run()
