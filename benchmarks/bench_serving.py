"""Staged-vs-lockstep serving under open-loop Poisson load (BENCH traj).

Cells: {ternary, int8} x {staged, lockstep} on the tiny PTQ LM, driven by a
seeded open-loop arrival process (exponential inter-arrivals -- requests
arrive on THEIR schedule, not when the engine is ready) over a mixed
long+short prompt workload.  Measured per cell:

  * sustained tok/s -- generated tokens / wall-clock from first dispatch to
    drain, under saturating load.  The lockstep engine burns one whole-batch
    tick per prompt TOKEN during prefill; the staged engine consumes the
    same prompt in ``ceil(P / chunk)`` chunk dispatches, which is where its
    throughput win on long prompts comes from.
  * TTFT / TPOT / queue-wait p50+p95+p99 (ms) from the engines' own
    per-request SLO accounting (``stats()["latency"]``).

Wall-clock on the CPU container is regression *tracking*, not the perf
claim.  The structural claim the committed baseline must show: staged
sustained tok/s > lockstep sustained tok/s on the mixed workload.

``--smoke`` is the CI invocation and is deliberately non-flapping: it
asserts the two engines emit BIT-IDENTICAL greedy tokens per request
(the parity contract) and prints the table without judging wall-clock.
``--json out.json`` dumps rows for the BENCH trajectory
(``benchmarks/BENCH_serving.json`` is the committed baseline, made via
``run.py --serving-json``).

Fault tolerance rides the same harness:

  * the default table gains a GOODPUT-UNDER-FAULT cell: the staged engine
    under overload (open-loop arrivals past capacity, bounded queue,
    per-request deadlines) with a seeded 1% per-dispatch fault rate --
    reported as goodput tok/s (finished requests only) plus shed / expired
    / quarantined / retried / failed rates.
  * ``--chaos --smoke`` is the CI containment matrix, and is CLOSED-loop
    (all requests submitted upfront, armed one-shot faults) so it cannot
    flap on machine speed: for every fault kind it asserts exactly the
    afflicted request fails (or retries to a bit-identical recovery) while
    every other request matches the fault-free baseline bit for bit.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import tiny_lm
from repro.configs.base import QuantConfig
from repro.models import build_model, quantize_and_plan
from repro.serving import (
    AdmissionConfig,
    FaultInjector,
    HealthConfig,
    Request,
    SchedulerConfig,
    ServingEngine,
    StagedEngine,
)

FORMATS = {"ternary": 2, "int8": 8}
CHUNK = 16
CHAOS_RATE = 0.05  # per-dispatch fault probability for the goodput cell


def _workload(seed: int, n_requests: int, vocab: int, rate_hz: float,
              long_len: int = 80, short_len: int = 4, max_new: int = 8):
    """Seeded mixed long+short workload with Poisson arrival offsets.

    Alternating long/short prompts: the long ones are where lockstep
    prefill stalls the batch and staged chunking pays; the short ones feel
    that stall as inter-token latency.  Returns (requests, arrival_times);
    everything derives from ``seed`` so two engines replay the identical
    offered load.
    """
    rng = np.random.default_rng(seed)
    reqs, arrivals = [], []
    t = 0.0
    for i in range(n_requests):
        n = long_len if i % 2 == 0 else short_len
        reqs.append(Request(
            uid=i, prompt=rng.integers(0, vocab, n).tolist(),
            max_new_tokens=max_new,
        ))
        t += rng.exponential(1.0 / rate_hz)
        arrivals.append(t)
    return reqs, arrivals


def _drive_open_loop(eng, reqs: List[Request], arrivals: List[float],
                     max_wall_s: float = 600.0):
    """Open-loop driver: submissions follow the arrival clock regardless of
    engine progress (arrivals the engine cannot absorb queue up -- that IS
    the load model).  Returns (finished, wall_seconds)."""
    t0 = time.perf_counter()
    done: List[Request] = []
    i = 0
    while (i < len(reqs) or eng._has_work()) \
            and time.perf_counter() - t0 < max_wall_s:
        now = time.perf_counter() - t0
        while i < len(reqs) and arrivals[i] <= now:
            eng.submit(reqs[i])
            i += 1
        if not eng._has_work():
            if i < len(reqs):  # idle: wait out the arrival process
                time.sleep(min(arrivals[i] - now, 0.005))
            continue
        done.extend(eng.step())
    return done, time.perf_counter() - t0


def _make_engine(kind: str, api, qparams, n_slots: int, max_len: int):
    if kind == "staged":
        return StagedEngine(api, qparams, n_slots=n_slots, max_len=max_len,
                            sched=SchedulerConfig(prefill_chunk=CHUNK))
    return ServingEngine(api, qparams, n_slots=n_slots, max_len=max_len)


def _bench_cell(kind: str, api, qparams, *, n_slots: int, max_len: int,
                n_requests: int, rate_hz: float, vocab: int) -> Dict:
    from repro.serving.scheduler import LatencyStats

    eng = _make_engine(kind, api, qparams, n_slots, max_len)
    # warm every compiled shape on THIS engine's jit wrappers (decode tick,
    # full chunk + pow2 remainder chunks, insert, first-token) so the timed
    # window measures serving, not tracing
    warm, warm_at = _workload(99, 4, vocab, 1e6)
    _drive_open_loop(eng, warm, warm_at)
    eng._lat = LatencyStats()
    if hasattr(eng, "counts"):
        eng.counts = {k: 0 for k in eng.counts}

    reqs, arrivals = _workload(0, n_requests, vocab, rate_hz)
    done, wall = _drive_open_loop(eng, reqs, arrivals)
    toks = sum(len(r.output) for r in done)
    lat = eng.stats()["latency"]

    def ms(field, p):
        return None if lat[field] is None else lat[field][p] * 1e3

    return {
        "bench": "serving", "engine": kind,
        "sustained_tok_s": toks / wall,
        "wall_s": wall, "n_finished": len(done), "gen_tokens": toks,
        "prompt_tokens": sum(len(r.prompt) for r in done),
        "ttft_p50_ms": ms("ttft", "p50"), "ttft_p95_ms": ms("ttft", "p95"),
        "ttft_p99_ms": ms("ttft", "p99"),
        "tpot_p50_ms": ms("tpot", "p50"), "tpot_p95_ms": ms("tpot", "p95"),
        "tpot_p99_ms": ms("tpot", "p99"),
        "queue_wait_p95_ms": ms("queue_wait", "p95"),
    }, done


def _quantized_lm(bits: int, **cfg_knobs):
    import dataclasses

    cfg = tiny_lm(QuantConfig(w_bits=bits, group_size=16, mode="ptq",
                              backend="xla"))
    if cfg_knobs:
        cfg = dataclasses.replace(cfg, **cfg_knobs)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    qparams, _, qapi = quantize_and_plan(api, params)
    return qapi, qparams, cfg.vocab


# ---------------------------------------------------------------------------
# Goodput under fault: overload + deadlines + 1% seeded chaos.
# ---------------------------------------------------------------------------
def _chaos_goodput_cell(api, qparams, vocab, *, n_slots: int, max_len: int,
                        n_requests: int, rate_hz: float) -> Dict:
    """Staged engine driven PAST capacity with a bounded queue, per-request
    deadlines, retry budget 1, and a seeded ``CHAOS_RATE`` fault stream
    (nan_logits | kv_corrupt).  Goodput counts FINISHED requests' tokens
    only; shed/expired/failed work is the cost being measured."""
    inj = FaultInjector(rate=CHAOS_RATE, kinds=("nan_logits", "kv_corrupt"),
                        seed=1)
    eng = StagedEngine(
        api, qparams, n_slots=n_slots, max_len=max_len,
        sched=SchedulerConfig(prefill_chunk=CHUNK),
        admission=AdmissionConfig(max_queue=2 * n_slots, deadline_ms=4000.0,
                                  retry_backoff_ms=1.0),
        health=HealthConfig(overload_queue=n_slots),
        faults=inj,
    )
    warm, warm_at = _workload(99, 4, vocab, 1e6)
    _drive_open_loop(eng, warm, warm_at)

    reqs, arrivals = _workload(0, n_requests, vocab, rate_hz)
    for r in reqs:
        r.max_retries = 1
    done, wall = _drive_open_loop(eng, reqs, arrivals)
    by_status: Dict[str, int] = {}
    for r in reqs:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    good_toks = sum(len(r.output) for r in done if r.status == "finished")
    ev = eng.stats()["health"]["events"]
    return {
        "bench": "serving_chaos_goodput", "engine": "staged",
        "fault_rate": CHAOS_RATE,
        "goodput_tok_s": good_toks / wall,
        "wall_s": wall,
        "n_offered": len(reqs),
        "n_finished": by_status.get("finished", 0),
        "shed_rate": by_status.get("shed", 0) / len(reqs),
        "expired_rate": by_status.get("expired", 0) / len(reqs),
        "failed_rate": by_status.get("failed", 0) / len(reqs),
        "quarantined": ev["quarantined"], "retried": ev["retried"],
        "faults_injected": ev["faults_injected"],
        "overload_entered": eng.stats()["health"]["overload_entered"],
    }


# ---------------------------------------------------------------------------
# Containment matrix (--chaos --smoke): closed-loop, armed, non-flapping.
# ---------------------------------------------------------------------------
def _containment_matrix(csv=print, *, n_slots: int = 4,
                        max_len: int = 64) -> List[Dict]:
    """For each fault kind, CI-grade containment proof on the staged
    engine: exactly the afflicted request fails (or, with a retry budget,
    recovers bit-identical), all others match the fault-free baseline bit
    for bit.  Closed loop + armed one-shots: nothing here depends on wall
    clock, so the step cannot flap on runner speed.

    Runs on UNQUANTIZED fp params deliberately: the matrix proves the
    ENGINE's quarantine machinery, which needs faults to reach the logits.
    Under PTQ the DFP activation quantizer launders a NaN-poisoned KV read
    into finite values (``jnp.round(nan) -> nan`` but the int8 mantissa
    cast maps NaN to 0, core/dfp.py), so kv_corrupt would be silently
    swallowed -- the quantized-path behavior is measured separately by the
    goodput-under-fault cells above."""
    cfg = tiny_lm()
    api = build_model(cfg)
    qparams = api.init(jax.random.PRNGKey(0))
    vocab = cfg.vocab
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, vocab, n).tolist() for n in (6, 3, 9, 4)]

    def closed_loop(faults=None, arm=None, max_retries=0, health=None):
        kw = {"health": health} if health is not None else {}
        eng = StagedEngine(api, qparams, n_slots=n_slots, max_len=max_len,
                           sched=SchedulerConfig(prefill_chunk=4),
                           faults=faults,
                           admission=AdmissionConfig(retry_backoff_ms=1.0),
                           **kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=6,
                               max_retries=max_retries))
        done = []
        # two healthy ticks first so the armed slot has live KV rows --
        # kv_corrupt behind position 0 is fully masked and proves nothing
        done.extend(eng.step())
        done.extend(eng.step())
        if arm is not None:
            faults.arm(arm, slot=0)
        done.extend(eng.run(max_ticks=4000))
        return eng, {r.uid: r for r in done}

    _, base = closed_loop()
    assert all(r.status == "finished" for r in base.values())
    rows: List[Dict] = []

    def emit(case: str, ok: bool, detail: str):
        csv(f"serving/chaos_{case},{0 if ok else 1:.0f},{detail}")
        rows.append({"bench": "serving_chaos_matrix", "case": case, "ok": ok})
        if not ok:
            raise AssertionError(f"chaos containment violated [{case}]: "
                                 f"{detail}")

    for kind in ("nan_logits", "inf_logits", "sat_logits", "kv_corrupt"):
        inj = FaultInjector()
        _, got = closed_loop(faults=inj, arm=kind)
        victim = inj.log[0].uid
        others_identical = all(
            r.status == "finished" and r.output == base[u].output
            for u, r in got.items() if u != victim
        )
        ok = (victim is not None and len(got) == len(base)
              and got[victim].status == "failed" and others_identical)
        emit(kind, ok,
             f"victim_uid={victim};victim_status={got[victim].status};"
             f"others_bit_identical={str(others_identical).lower()}")

    # retry budget: the victim recovers and the WHOLE run matches baseline
    inj = FaultInjector()
    eng, got = closed_loop(faults=inj, arm="nan_logits", max_retries=1)
    recovered = (
        {u: r.output for u, r in got.items()}
        == {u: r.output for u, r in base.items()}
        and all(r.status == "finished" for r in got.values())
        and eng.stats()["health"]["events"]["retried"] == 1
    )
    emit("retry_recovers", recovered,
         f"bit_identical_after_retry={str(recovered).lower()}")

    # stall: watchdog flags it, tokens unaffected
    inj = FaultInjector(stall_s=0.12)
    eng, got = closed_loop(faults=inj, arm="stall_tick",
                           health=HealthConfig(tick_slow_s=0.1))
    h = eng.stats()["health"]
    stall_ok = (
        h["slow_ticks"] + h["hung_ticks"] >= 1
        and {u: r.output for u, r in got.items()}
        == {u: r.output for u, r in base.items()}
    )
    emit("stall_tick", stall_ok,
         f"slow_ticks={h['slow_ticks']};tokens_unaffected="
         f"{str(stall_ok).lower()}")
    return rows


def run(csv=print, *, n_slots: int = 4, max_len: int = 96,
        n_requests: int = 12, rate_hz: float = 200.0,
        json_path: str = None, smoke: bool = False) -> List[Dict]:
    formats = {"ternary": FORMATS["ternary"]} if smoke else FORMATS
    if smoke:
        n_requests = min(n_requests, 6)
    rows: List[Dict] = []
    for fmt, bits in formats.items():
        api, qparams, vocab = _quantized_lm(bits)
        outs = {}
        for kind in ("staged", "lockstep"):
            row, done = _bench_cell(
                kind, api, qparams, n_slots=n_slots, max_len=max_len,
                n_requests=n_requests, rate_hz=rate_hz, vocab=vocab,
            )
            row["format"] = fmt
            rows.append(row)
            outs[kind] = {r.uid: r.output for r in done}
            csv(
                f"serving/{fmt}_{kind},{1e6 / row['sustained_tok_s']:.1f},"
                f"sustained_tok_s={row['sustained_tok_s']:.1f};"
                f"ttft_p95_ms={row['ttft_p95_ms']:.1f};"
                f"tpot_p95_ms={row['tpot_p95_ms']:.1f};"
                f"finished={row['n_finished']}"
            )
        # greedy parity is the correctness gate CI leans on: identical token
        # streams per request, engine-order independent, wall-clock-free
        parity = outs["staged"] == outs["lockstep"]
        csv(f"serving/{fmt}_parity,{0 if parity else 1:.0f},"
            f"staged_matches_lockstep={str(parity).lower()}")
        rows.append({"bench": "serving_parity", "format": fmt, "ok": parity})
        if not parity:
            raise AssertionError(
                f"staged/lockstep token divergence on {fmt}: "
                f"{outs['staged']} vs {outs['lockstep']}"
            )
        # all-flash serving cell: the SAME staged workload with BOTH flash
        # knobs on (prefill chunks through the S > 1 kernel, generate
        # ticks through the S == 1 path), TTFT delta vs the plain staged
        # cell.  Greedy bit-parity is a SAME-NUMERICS contract -- the
        # kernel's tile-ordered summation can legitimately flip a
        # near-tied argmax vs the XLA oracle -- so the asserted oracle is
        # a lockstep engine that also routes through the flash kernel:
        # row-wise the online softmax is identical whether rows arrive one
        # per tick (lockstep decode) or as a prefill chunk, so this pair
        # IS bit-comparable.  Off-TPU the kernel runs interpreted --
        # wall-clock here is regression tracking, the parity + TTFT
        # structure is the claim.
        fapi, fqparams, _ = _quantized_lm(
            bits, flash_prefill=True, flash_decode=True
        )
        frow, fdone = _bench_cell(
            "staged", fapi, fqparams, n_slots=n_slots, max_len=max_len,
            n_requests=n_requests, rate_hz=rate_hz, vocab=vocab,
        )
        frow["format"] = fmt
        frow["engine"] = "staged_flash"
        base_ttft = next(
            r["ttft_p95_ms"] for r in rows
            if r.get("engine") == "staged" and r.get("format") == fmt
        )
        frow["ttft_p95_delta_vs_staged_ms"] = frow["ttft_p95_ms"] - base_ttft
        rows.append(frow)
        _, fldone = _bench_cell(
            "lockstep", fapi, fqparams, n_slots=n_slots, max_len=max_len,
            n_requests=n_requests, rate_hz=rate_hz, vocab=vocab,
        )
        fparity = (
            {r.uid: r.output for r in fdone}
            == {r.uid: r.output for r in fldone}
        )
        csv(
            f"serving/{fmt}_staged_flash,"
            f"{1e6 / frow['sustained_tok_s']:.1f},"
            f"sustained_tok_s={frow['sustained_tok_s']:.1f};"
            f"ttft_p95_ms={frow['ttft_p95_ms']:.1f};"
            f"ttft_p95_delta_vs_staged_ms="
            f"{frow['ttft_p95_delta_vs_staged_ms']:+.1f};"
            f"parity_vs_flash_lockstep={str(fparity).lower()}"
        )
        if not fparity:
            raise AssertionError(
                f"all-flash staged/lockstep token divergence on {fmt}"
            )
        if not smoke:
            # goodput under fault: overload + deadlines + 1% seeded chaos
            row = _chaos_goodput_cell(
                api, qparams, vocab, n_slots=n_slots, max_len=max_len,
                n_requests=2 * n_requests, rate_hz=2 * rate_hz,
            )
            row["format"] = fmt
            rows.append(row)
            csv(
                f"serving/{fmt}_chaos_goodput,"
                f"{1e6 / max(row['goodput_tok_s'], 1e-9):.1f},"
                f"goodput_tok_s={row['goodput_tok_s']:.1f};"
                f"shed_rate={row['shed_rate']:.2f};"
                f"expired_rate={row['expired_rate']:.2f};"
                f"failed_rate={row['failed_rate']:.2f};"
                f"retried={row['retried']};"
                f"faults={row['faults_injected']}"
            )
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="dump the table as JSON")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: ternary only, small workload, parity "
                         "asserted, wall-clock never judged")
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-containment matrix instead of the "
                         "throughput table; with --smoke this is the CI "
                         "chaos step (closed-loop, armed, non-flapping)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (req/s) of the open loop")
    a = ap.parse_args()
    if a.chaos:
        chaos_rows = _containment_matrix(csv=print, max_len=a.max_len)
        if a.json:
            with open(a.json, "w") as f:
                json.dump(chaos_rows, f, indent=2)
    else:
        run(n_slots=a.slots, max_len=a.max_len, n_requests=a.requests,
            rate_hz=a.rate, json_path=a.json, smoke=a.smoke)
