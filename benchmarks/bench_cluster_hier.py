"""Paper Sec. 3.1 ablation: the hierarchical (filter -> cluster) RMS search
vs flat alternatives -- the paper's claim that hierarchical search 'helps
finding the optimal scaling factor that minimizes quantization loss'.

Compares reconstruction error of:
  * hierarchical Algorithm 1+2 (ours/paper) at cluster granularity,
  * TWN-style threshold (Li et al.: delta = 0.7*mean|w|) at BOTH cluster and
    per-layer granularity (the paper's actual comparison point is per-layer),
  * the exhaustive-optimal single scale per cluster (lower bound),
  * the beyond-paper refit_scale variant.

Finding recorded in EXPERIMENTS.md: at equal granularity the paper's RMS
rule reconstructs WORSE than TWN's -- it deliberately over-prunes ("helps
speed up weight pruning", Sec. 3.1), buying sparsity; the paper's accuracy
win comes from the finer per-cluster granularity vs TWN's per-layer scale.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import ternary


def _twn(cluster: np.ndarray):
    delta = 0.7 * np.mean(np.abs(cluster))
    mask = np.abs(cluster) > delta
    if mask.sum() == 0:
        return float(np.sum(cluster**2))
    alpha = np.abs(cluster[mask]).mean()
    rec = np.where(mask, np.sign(cluster) * alpha, 0.0)
    return float(np.sum((cluster - rec) ** 2))


def _optimal(cluster: np.ndarray):
    """Exhaustive optimal (support, scale) single-alpha ternary:
    err(t) = total - A_t^2 / t with support = top-t magnitudes."""
    a = np.flip(np.sort(np.abs(cluster).ravel()))
    cum = np.cumsum(a)
    t = np.arange(1, a.size + 1)
    err = np.sum(a * a) - cum**2 / t
    return float(err.min())


def run(csv=print):
    rng = np.random.default_rng(0)
    for dist, sample in {
        "gauss": lambda s: rng.normal(size=s),
        "heavy": lambda s: rng.standard_t(3, size=s),
    }.items():
        for n, f in ((4, 9), (16, 9), (64, 1)):
            w = sample((64, n * f)).astype(np.float32)
            errs = {
                "twn_cluster": 0.0, "paper_hier": 0.0,
                "refit": 0.0, "optimal_cluster": 0.0,
            }
            for row in w:
                cl = row.reshape(n, f)
                errs["twn_cluster"] += _twn(cl)
                errs["optimal_cluster"] += _optimal(cl)
                codes, a = ternary.cluster_ternarize(jnp.asarray(cl))
                errs["paper_hier"] += float(
                    jnp.sum((cl - codes.astype(jnp.float32) * a) ** 2)
                )
                codes, a = ternary.cluster_ternarize(jnp.asarray(cl), refit_scale=True)
                errs["refit"] += float(
                    jnp.sum((cl - codes.astype(jnp.float32) * a) ** 2)
                )
            errs["twn_per_layer"] = _twn(w)  # one scale for the whole layer
            total = float(np.sum(w * w))
            # sparsity the paper's rule buys (fraction of zeroed weights)
            codes, _ = ternary.ternarize_matrix(
                jnp.asarray(w.T.copy()), n * f, f
            )
            sparsity = float(np.mean(np.asarray(codes) == 0))
            csv(
                f"cluster_hier/{dist}_N{n}_F{f},0,"
                + ";".join(f"{k}={v / total:.4f}" for k, v in errs.items())
                + f";paper_sparsity={sparsity:.3f}"
            )


if __name__ == "__main__":
    run()
