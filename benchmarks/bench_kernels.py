"""Kernel-level microbench: quantized matmul paths + derived HBM metrics.

Wall-clock on this CPU container is NOT the perf claim (the kernels target
TPU MXU; see EXPERIMENTS.md Roofline) -- reported here are (a) CPU
wall-times of the XLA-lowered integer pipeline for regression tracking and
(b) the derived bytes-streamed metrics that set the TPU roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.core.quantizer import quantize_weights
from repro.kernels import ops


def run(csv=print):
    rng = np.random.default_rng(0)
    m, k, n, g = 128, 2048, 2048, 64
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))

    # fp32 baseline matmul
    f_fp = jax.jit(lambda a, b: a @ b)
    us = timed(f_fp, x, w)
    csv(f"kernels/fp32_matmul_{m}x{k}x{n},{us:.1f},bytes_w={k * n * 4}")

    for bits in (2, 4, 8):
        qt = quantize_weights(w, bits, g)
        f_q = jax.jit(lambda a, q: ops.qmatmul(a, q, backend="xla"))
        us = timed(f_q, x, qt)
        wb = int(np.asarray(qt.packed).nbytes + np.asarray(qt.scale_m).nbytes)
        csv(
            f"kernels/qmm_xla_{bits}w_{m}x{k}x{n},{us:.1f},"
            f"bytes_w={wb};compression={k * n * 2 / wb:.2f}x_vs_bf16"
        )

    # pallas interpret-mode correctness path (small shape; CPU interpret is slow)
    qt = quantize_weights(w[:256, :256], 2, g)
    f_p = jax.jit(
        lambda a, q: ops.qmatmul(a, q, backend="pallas", block_k=256)
    )
    us = timed(f_p, x[:32, :256], qt, reps=2)
    csv(f"kernels/qmm_pallas_interp_2w_32x256x256,{us:.1f},interpret=True")


if __name__ == "__main__":
    run()
