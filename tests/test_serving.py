"""Serving engine: decode/forward parity, continuous batching, PTQ serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import QuantConfig
from repro.models import build_model, quantize_model_params
from repro.serving import Request, SamplerConfig, ServingEngine
from repro.serving.sampler import sample

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["qwen3-8b", "gemma3-12b", "falcon-mamba-7b", "zamba2-7b"])
def test_engine_greedy_matches_full_forward(arch):
    cfg = configs.get_smoke(arch)
    api = build_model(cfg)
    params = api.init(KEY)
    prompt = [5, 9, 2, 7, 11]
    eng = ServingEngine(api, params, n_slots=2, max_len=16)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=1))
    out = eng.run()[0].output[0]
    logits = api.forward(params, {"tokens": jnp.asarray([prompt])})
    ref = int(jnp.argmax(logits[0, -1]))
    assert out == ref


def test_engine_multi_token_matches_sequential_forward():
    """3 greedy tokens from the engine == 3 rounds of full re-forward."""
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    prompt = [3, 1, 4]
    eng = ServingEngine(api, params, n_slots=1, max_len=16)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=3))
    got = eng.run()[0].output

    seq = list(prompt)
    want = []
    for _ in range(3):
        logits = api.forward(params, {"tokens": jnp.asarray([seq])})
        t = int(jnp.argmax(logits[0, -1]))
        want.append(t)
        seq.append(t)
    assert got == want


def test_continuous_batching_isolation():
    """Requests admitted mid-flight do not perturb running slots."""
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)

    solo = ServingEngine(api, params, n_slots=1, max_len=32)
    solo.submit(Request(uid=0, prompt=[7, 7, 3], max_new_tokens=4))
    want = solo.run()[0].output

    eng = ServingEngine(api, params, n_slots=3, max_len=32)
    eng.submit(Request(uid=0, prompt=[7, 7, 3], max_new_tokens=4))
    eng.step()
    eng.submit(Request(uid=1, prompt=[1, 2], max_new_tokens=4))
    eng.submit(Request(uid=2, prompt=[9], max_new_tokens=2))
    done = {r.uid: r.output for r in eng.run()}
    assert done[0] == want


def test_slot_reuse_after_finish():
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    eng = ServingEngine(api, params, n_slots=1, max_len=16)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=[i + 1, 2], max_new_tokens=2))
    done = eng.run()
    assert sorted(r.uid for r in done) == [0, 1, 2]
    assert all(len(r.output) == 2 for r in done)


def test_ptq_serving_pipeline():
    cfg = configs.get_smoke(
        "qwen3-8b", QuantConfig(w_bits=2, group_size=16, mode="ptq", backend="xla")
    )
    api = build_model(cfg)
    params = api.init(KEY)
    qparams = quantize_model_params(params, api.ctx.policy)
    eng = ServingEngine(api, qparams, n_slots=2, max_len=16)
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4))
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 4


def test_eos_stops_generation():
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    # find the greedy first token, then use it as "eos"
    eng = ServingEngine(api, params, n_slots=1, max_len=16)
    eng.submit(Request(uid=0, prompt=[5, 6], max_new_tokens=8))
    first = eng.run()[0].output[0]
    eng2 = ServingEngine(api, params, n_slots=1, max_len=16)
    eng2.submit(Request(uid=0, prompt=[5, 6], max_new_tokens=8, eos_id=first))
    out = eng2.run()[0].output
    assert out == [first]


def test_submit_rejects_overlong_prompt():
    """A prompt that cannot fit max_len comes back ``rejected`` with a
    reason (one bad client must not take the serve loop down), never queued
    to silently finish done=True with truncated/empty output.
    ``strict=True`` restores the loud raise-at-submit behavior."""
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    eng = ServingEngine(api, params, n_slots=1, max_len=8)
    for uid, prompt in ((0, list(range(8))), (1, list(range(20)))):
        r = eng.submit(Request(uid=uid, prompt=prompt, max_new_tokens=2))
        assert r.status == "rejected" and "max_len" in r.reason
        assert not r.done and len(eng.queue) == 0
    r = eng.submit(Request(uid=2, prompt=[], max_new_tokens=2))
    assert r.status == "rejected" and "empty" in r.reason
    # strict mode: the original raise-on-malformed contract
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(uid=3, prompt=list(range(8)), max_new_tokens=2),
                   strict=True)
    assert eng.stats()["health"]["events"]["rejected"] == 3
    eng.submit(Request(uid=4, prompt=list(range(7)), max_new_tokens=1))  # fits
    assert len(eng.run()) == 1


def test_queue_depth_and_admission_ticks():
    """The request queue is a deque reporting depth + per-request admission
    tick through stats()."""
    from collections import deque

    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    eng = ServingEngine(api, params, n_slots=1, max_len=16)
    assert isinstance(eng.queue, deque)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=[i + 1, 2], max_new_tokens=1))
    s0 = eng.stats()
    assert s0["queued"] == 3 and s0["tick"] == 0
    eng.step()
    s1 = eng.stats()
    assert s1["queued"] == 2  # one admitted into the single slot
    assert s1["admitted_tick"] == [0]  # admitted before the first tick ran
    assert s1["tick"] == 1
    done = eng.run()
    # FIFO admission order survives the deque swap, and later requests
    # record later admission ticks
    ticks = [r.admitted_tick for r in sorted(done, key=lambda r: r.uid)]
    assert ticks == sorted(ticks) and ticks[0] == 0
    assert eng.stats()["queued"] == 0


def test_lockstep_run_budget_reports_leftover():
    """run(max_ticks) expiry must not silently abandon work: every
    submitted request is accounted for in finished + leftover()."""
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    eng = ServingEngine(api, params, n_slots=1, max_len=32)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=[1, 2, 3, 4], max_new_tokens=8))
    done = eng.run(max_ticks=2)
    left = eng.leftover()
    assert done == [] and len(left["in_flight"]) == 1 and len(left["queued"]) == 2
    assert all(not r.done for r in left["in_flight"] + left["queued"])
    drained = eng.drain()
    assert {r.uid for r in drained["in_flight"] + drained["queued"]} == {0, 1, 2}
    assert eng.leftover() == {"in_flight": [], "queued": []}
    assert eng.stats()["active"] == 0 and eng.stats()["queued"] == 0


def test_ssm_slot_reuse_no_stale_state():
    """Recurrent state is NOT masked by cache positions the way stale KV
    rows are: a reused slot must be cleared on admission, or the previous
    occupant's SSM state leaks into the new request's tokens."""
    cfg = configs.get_smoke("falcon-mamba-7b")
    api = build_model(cfg)
    params = api.init(KEY)
    probe = [5, 9, 2]

    fresh = ServingEngine(api, params, n_slots=1, max_len=16)
    fresh.submit(Request(uid=0, prompt=list(probe), max_new_tokens=3))
    want = fresh.run()[0].output

    eng = ServingEngine(api, params, n_slots=1, max_len=16)
    eng.submit(Request(uid=0, prompt=[13, 8, 8, 8, 1], max_new_tokens=4))
    eng.submit(Request(uid=1, prompt=list(probe), max_new_tokens=3))
    done = {r.uid: r.output for r in eng.run()}
    assert done[1] == want


def test_sampler_modes():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(sample(KEY, logits, SamplerConfig(temperature=0.0))[0]) == 1
    t = sample(KEY, logits, SamplerConfig(temperature=1.0, top_k=2))
    assert int(t[0]) in (1, 2)
    counts = set()
    for i in range(20):
        counts.add(int(sample(jax.random.PRNGKey(i), logits, SamplerConfig(temperature=5.0))[0]))
    assert len(counts) > 1  # high temperature actually samples


def test_int8_kv_cache_greedy_parity():
    """DFP-quantized KV cache (beyond-paper) preserves greedy decode."""
    import dataclasses

    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    api8 = build_model(dataclasses.replace(cfg, kv_bits=8))

    prompt = jnp.asarray([[5, 9, 2, 7, 11, 3]])
    l_ref, c_ref = api.prefill(params, {"tokens": prompt}, api.init_cache(1, 16))
    l_q, c_q = api8.prefill(params, {"tokens": prompt}, api8.init_cache(1, 16))
    assert c_q["k"].dtype == jnp.int8 and "ke" in c_q
    t1 = jnp.argmax(l_ref[:, -1:], -1).astype(jnp.int32)
    t2 = jnp.argmax(l_q[:, -1:], -1).astype(jnp.int32)
    assert int(t1[0, 0]) == int(t2[0, 0])
    for i in range(3):
        l_ref, c_ref = api.decode(params, t1, jnp.int32(6 + i), c_ref)
        l_q, c_q = api8.decode(params, t2, jnp.int32(6 + i), c_q)
        t1 = jnp.argmax(l_ref[:, -1:], -1).astype(jnp.int32)
        t2 = jnp.argmax(l_q[:, -1:], -1).astype(jnp.int32)
        assert int(t1[0, 0]) == int(t2[0, 0])
