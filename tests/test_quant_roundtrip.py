"""QTensor round-trips: pack/unpack exactness across the paper's N sweep,
pytree registration, and (QTensor + QuantPlan) serialization round-trips."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dfp
from repro.quant import (
    QTensor,
    decode_codes,
    dequantize_scales,
    dequantize_weights,
    pack2,
    pack4,
    quantize_weights,
    unpack2,
    unpack4,
)

BITS = (2, 4, 8)
GROUPS = (4, 16, 64)  # the paper's N sweep


def _rand_w(k, n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(k, n)), jnp.float32)


# ---------------------------------------------------------------------------
# Packing primitives.
# ---------------------------------------------------------------------------
def test_pack2_roundtrip_exact():
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(-1, 2, size=(64, 8)), jnp.int8)
    assert (np.asarray(unpack2(pack2(codes), 64)) == np.asarray(codes)).all()


def test_pack4_roundtrip_exact_symmetric_range():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.integers(-7, 8, size=(64, 8)), jnp.int8)
    assert (np.asarray(unpack4(pack4(q), 64)) == np.asarray(q)).all()


def test_pack4_rejects_asymmetric_minus8():
    """The quantizer clips int4 mantissas to +/-qmax(4) == 7; pack4 enforces
    that symmetric-range contract on concrete inputs."""
    bad = jnp.full((8, 2), -8, jnp.int8)
    with pytest.raises(AssertionError):
        pack4(bad)
    assert dfp.qmax(4) == 7


# ---------------------------------------------------------------------------
# QTensor mantissa/scale round-trips over bits x group size.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("g", GROUPS)
def test_codes_pack_unpack_exact(bits, g):
    qt = quantize_weights(_rand_w(128, 24, seed=bits * 10 + g), bits, g)
    codes = np.asarray(decode_codes(qt))
    assert codes.shape == (128, 24) and codes.dtype == np.int8
    assert np.abs(codes).max() <= (1 if bits == 2 else dfp.qmax(bits))
    # re-encode through the format's own packer: bit-exact round trip
    from repro.quant import format_of

    fmt = format_of(qt)
    repacked = fmt.encode(jnp.asarray(codes))
    assert (np.asarray(repacked) == np.asarray(qt.packed)).all()


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("g", GROUPS)
def test_dequantize_on_scale_grid(bits, g):
    """Reconstruction lies exactly on the (codes x 8-bit scale table) grid."""
    qt = quantize_weights(_rand_w(128, 12, seed=bits + g), bits, g)
    rec = np.asarray(dequantize_weights(qt))
    codes = np.asarray(decode_codes(qt), np.float32)
    scales = np.asarray(dequantize_scales(qt.scale_m, qt.scale_e))
    want = (codes.reshape(qt.n_groups, g, 12)
            * scales[:, None, :]).reshape(128, 12)
    np.testing.assert_array_equal(rec, want)


@pytest.mark.parametrize("fmt", ("nf4", "mx"))
def test_block_format_dequant_on_scale_grid(fmt):
    """nf4/mx reconstructions lie exactly on the decode(packed) x scale-table
    grid -- the invariant that lets every integer consumer (ref oracle,
    xla_int8, Pallas kernels) treat them like any built-in format."""
    qt = quantize_weights(_rand_w(128, 12, seed=11), group_size=32, fmt=fmt)
    g = qt.group_size
    rec = np.asarray(dequantize_weights(qt))
    codes = np.asarray(decode_codes(qt), np.float32)
    assert codes.shape == (128, 12)
    scales = np.asarray(dequantize_scales(qt.scale_m, qt.scale_e))
    want = (codes.reshape(qt.n_groups, g, 12)
            * scales[:, None, :]).reshape(128, 12)
    np.testing.assert_array_equal(rec, want)


def test_mx_dead_block_does_not_degrade_live_blocks():
    """Regression: an all-zero 32-block (zero padding, pruned channel) must
    not drag the shared exponent base up -- choose_exponent maps max_abs==0
    to e=0, far above real weight-block exponents, and pre-fix one dead
    block clamped every live block onto a ~800x coarser grid."""
    rng = np.random.default_rng(0)
    w = np.asarray(rng.normal(size=(128, 8)) * 0.02, np.float32)
    w[:32, 0] = 0.0  # one dead 32-block
    qt = quantize_weights(jnp.asarray(w), group_size=32, fmt="mx")
    rec = np.asarray(dequantize_weights(qt))
    assert (rec[:32, 0] == 0).all()  # the dead block stays exactly zero
    err = float(np.sum((w - rec) ** 2) / np.sum(w**2))
    assert err < 1e-3  # pre-fix this was ~5e-2
    # all-zero tensors still quantize cleanly (the any(live) fallback)
    qt0 = quantize_weights(jnp.zeros((64, 4)), group_size=32, fmt="mx")
    assert (np.asarray(dequantize_weights(qt0)) == 0).all()


def test_nf4_beats_int4_on_gaussian_weights():
    """The point of the LUT: on normal-distributed weights (the shape real
    projections have), nf4's quantile grid reconstructs with lower error
    than the uniform int4 grid at the same 4-bit budget."""
    from repro.quant import weight_quantization_error

    w = _rand_w(256, 32, seed=5)
    qt_nf4 = quantize_weights(w, group_size=32, fmt="nf4")
    err_nf4 = float(jnp.sum((w - dequantize_weights(qt_nf4)) ** 2))
    err_int4 = float(weight_quantization_error(w, 4, 32))
    assert err_nf4 < err_int4


@pytest.mark.parametrize("bits", (4, 8))
def test_requantize_idempotent(bits):
    """Quantizing an already-quantized DFP weight is (near-)exact: the values
    sit on the DFP grid, so a second pass reproduces them.  (Ternary is
    excluded: Algorithm 1's threshold search is not idempotent by design.)"""
    g = 16
    qt = quantize_weights(_rand_w(64, 8, seed=7), bits, g)
    w1 = dequantize_weights(qt)
    w2 = dequantize_weights(quantize_weights(w1, bits, g))
    scale = float(jnp.max(jnp.abs(w1))) + 1e-9
    assert float(jnp.max(jnp.abs(w1 - w2))) / scale < 1e-2


# ---------------------------------------------------------------------------
# Pytree + serialization round-trips.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", BITS)
def test_qtensor_pytree_roundtrip(bits):
    qt = quantize_weights(_rand_w(64, 8), bits, 16)
    leaves, treedef = jax.tree.flatten(qt)
    assert len(leaves) == 3  # packed, scale_m, scale_e
    back = jax.tree.unflatten(treedef, leaves)
    assert (back.bits, back.group_size, back.shape, back.fmt) == (
        qt.bits, qt.group_size, qt.shape, qt.fmt
    )
    assert (np.asarray(back.packed) == np.asarray(qt.packed)).all()
    # jit transparency: a QTensor passes through jit as a pytree argument
    out = jax.jit(lambda t: dequantize_weights(t))(qt)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(dequantize_weights(qt)))


def test_qtensor_checkpoint_serialization_roundtrip():
    """QTensors inside a param tree survive the training checkpoint codec."""
    from repro.training import checkpoint as ck

    tree = {
        "lm": {"w": quantize_weights(_rand_w(64, 8, seed=3), 2, 16)},
        # the block formats ride the same codec: packed-dim projections
        # differ (nf4 packs K/8 uint32 rows, mx stores K raw int8 rows +
        # a K/32 scale table) but each payload is self-describing
        "nf": {"w": quantize_weights(_rand_w(64, 8, seed=4), group_size=16, fmt="nf4")},
        "mx": {"w": quantize_weights(_rand_w(64, 8, seed=5), group_size=32, fmt="mx")},
        "b": jnp.arange(4, dtype=jnp.float32),
    }
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 1, tree)
        step, back = ck.restore_latest(d, jax.eval_shape(lambda: tree))
    assert step == 1
    qt, bt = tree["lm"]["w"], back["lm"]["w"]
    assert (bt.bits, bt.group_size, bt.shape) == (qt.bits, qt.group_size, qt.shape)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert (np.asarray(a) == np.asarray(b)).all()
    np.testing.assert_array_equal(
        np.asarray(dequantize_weights(bt)), np.asarray(dequantize_weights(qt))
    )


def test_quantplan_checkpointable_alongside_qtensors():
    """A plan rides with its quantized params: flatten the pair, rebuild,
    and the plan still resolves (the checkpointable-quantized-model shape)."""
    from repro.core.policy import PrecisionPolicy
    from repro.quant import compile_policy

    params = {"lm_head": {"w": _rand_w(64, 8)}}
    plan = compile_policy(PrecisionPolicy.int8(16), params).with_act_exponents(
        {"lm_head": -2}
    )
    qt = quantize_weights(params["lm_head"]["w"], 8, 16)
    bundle = {"params": {"lm_head": {"w": qt}}, "plan": plan}
    leaves, treedef = jax.tree.flatten(bundle)
    back = jax.tree.unflatten(treedef, leaves)
    assert back["plan"] == plan
    assert back["plan"].act_exponent("lm_head") == -2
    assert back["plan"].resolve("lm_head").w_bits == 8
