"""Calibration observers, BN-recompute analogue, and precision policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import calibration, dfp
from repro.core.policy import FULL_PRECISION, PrecisionPolicy


def test_observer_tracks_max_and_msq():
    st = calibration.init_observer()
    st = calibration.observe(st, "act0", jnp.asarray([1.0, -3.0]))
    st = calibration.observe(st, "act0", jnp.asarray([2.0, 0.5]))
    assert float(st["act0"]["max_abs"]) == 3.0
    assert float(st["act0"]["count"]) == 2.0
    exps = calibration.finalize(st)
    # static exponent covers the observed range
    assert 3.0 <= dfp.qmax(8) * 2.0 ** float(exps["act0"])


def test_static_vs_dynamic_quantization_agree_on_seen_range():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    st = calibration.observe(calibration.init_observer(), "s", x)
    e = calibration.finalize(st)["s"]
    q_static = calibration.quantize_act(x, e)
    rec = dfp.dequantize(q_static, e)
    step = 2.0 ** float(e)
    assert float(jnp.max(jnp.abs(x - rec))) <= step / 2 + 1e-6


def test_recalibrate_gamma_restores_rms():
    """The BN-recompute analogue with TRUE RMS inputs: scaling activations
    by c scales RMS by c, so the gain absorbs the plain ratio (the old
    sqrt-of-ratio behavior assumed mean-square inputs)."""
    gamma = jnp.ones((8,))
    g2 = calibration.recalibrate_gamma(
        gamma, rms_fp=jnp.asarray(4.0), rms_q=jnp.asarray(1.0)
    )
    assert float(g2[0]) == pytest.approx(4.0, rel=1e-3)


def test_rms_observer_contract_analytic_gain_ratio():
    """Regression for the mean-square-vs-RMS contract bug:
    ``rms_from_observer`` must return sqrt(E[x^2]) (batch-averaged), and
    feeding its outputs to ``recalibrate_gamma`` must reproduce the
    analytically known gain ratio when the quantized site is a scaled copy
    of the fp site.  Pre-fix, the pair returned mean squares + sqrt'd the
    ratio -- self-consistent, but a caller passing a true RMS (the
    documented contract) got a half-strength (sqrt) correction."""
    c = 0.5  # "quantization" that exactly halves every activation
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)
    st = calibration.init_observer()
    st = calibration.observe(st, "s", x)
    st = calibration.observe(st, "s", x)  # two batches: exercises count avg
    st_q = calibration.observe(calibration.init_observer(), "s", c * x)

    rms_fp = calibration.rms_from_observer(st, "s")
    rms_q = calibration.rms_from_observer(st_q, "s")
    want = float(jnp.sqrt(jnp.mean(jnp.square(x))))
    assert float(rms_fp) == pytest.approx(want, rel=1e-6)
    assert float(rms_q) == pytest.approx(c * want, rel=1e-6)
    g = calibration.recalibrate_gamma(jnp.ones(()), rms_fp, rms_q, eps=0.0)
    assert float(g) == pytest.approx(1.0 / c, rel=1e-5)


def test_policy_paper_rules():
    pol = PrecisionPolicy.ternary(group_size=64)
    assert pol.resolve("blocks/attn/wq/w").w_bits == 2  # default ternary
    assert pol.resolve("embed/table").w_bits == 8  # C1 analogue
    assert pol.resolve("lm_head/w").w_bits == 8  # FC analogue
    assert pol.resolve("blocks/moe/router/w").w_bits == 8  # control path
    assert pol.resolve("blocks/ln1/norm").w_bits == FULL_PRECISION
    assert pol.resolve("mamba/conv1d").w_bits == FULL_PRECISION
    # all activations 8-bit everywhere (paper Sec. 4)
    assert pol.resolve("blocks/mlp/up/w").act_bits == 8


def test_policy_first_match_wins():
    pol = PrecisionPolicy.int4(group_size=32)
    assert pol.resolve("blocks/mlp/gate/w").w_bits == 4
    assert pol.resolve("frontend/patch/w").w_bits == 8


def test_per_row_dynamic_quant_tightens_ranges():
    """Per-token exponents beat a per-tensor exponent on skewed rows."""
    x = jnp.asarray([[0.01] * 32, [100.0] * 32], jnp.float32)
    per_tensor = calibration.fake_quantize_act(x, 8, per_row=False)
    per_row = calibration.fake_quantize_act(x, 8, per_row=True)
    err_t = float(jnp.sum((x - per_tensor) ** 2))
    err_r = float(jnp.sum((x - per_row) ** 2))
    assert err_r <= err_t
