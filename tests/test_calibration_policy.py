"""Calibration observers, BN-recompute analogue, and precision policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import calibration, dfp
from repro.core.policy import FULL_PRECISION, PrecisionPolicy


def test_observer_tracks_max_and_msq():
    st = calibration.init_observer()
    st = calibration.observe(st, "act0", jnp.asarray([1.0, -3.0]))
    st = calibration.observe(st, "act0", jnp.asarray([2.0, 0.5]))
    assert float(st["act0"]["max_abs"]) == 3.0
    assert float(st["act0"]["count"]) == 2.0
    exps = calibration.finalize(st)
    # static exponent covers the observed range
    assert 3.0 <= dfp.qmax(8) * 2.0 ** float(exps["act0"])


def test_static_vs_dynamic_quantization_agree_on_seen_range():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    st = calibration.observe(calibration.init_observer(), "s", x)
    e = calibration.finalize(st)["s"]
    q_static = calibration.quantize_act(x, e)
    rec = dfp.dequantize(q_static, e)
    step = 2.0 ** float(e)
    assert float(jnp.max(jnp.abs(x - rec))) <= step / 2 + 1e-6


def test_recalibrate_gamma_restores_rms():
    """The BN-recompute analogue: rescaled gain matches fp second moments."""
    gamma = jnp.ones((8,))
    g2 = calibration.recalibrate_gamma(gamma, rms_fp=jnp.asarray(4.0), rms_q=jnp.asarray(1.0))
    assert float(g2[0]) == pytest.approx(2.0, rel=1e-3)


def test_policy_paper_rules():
    pol = PrecisionPolicy.ternary(group_size=64)
    assert pol.resolve("blocks/attn/wq/w").w_bits == 2  # default ternary
    assert pol.resolve("embed/table").w_bits == 8  # C1 analogue
    assert pol.resolve("lm_head/w").w_bits == 8  # FC analogue
    assert pol.resolve("blocks/moe/router/w").w_bits == 8  # control path
    assert pol.resolve("blocks/ln1/norm").w_bits == FULL_PRECISION
    assert pol.resolve("mamba/conv1d").w_bits == FULL_PRECISION
    # all activations 8-bit everywhere (paper Sec. 4)
    assert pol.resolve("blocks/mlp/up/w").act_bits == 8


def test_policy_first_match_wins():
    pol = PrecisionPolicy.int4(group_size=32)
    assert pol.resolve("blocks/mlp/gate/w").w_bits == 4
    assert pol.resolve("frontend/patch/w").w_bits == 8


def test_per_row_dynamic_quant_tightens_ranges():
    """Per-token exponents beat a per-tensor exponent on skewed rows."""
    x = jnp.asarray([[0.01] * 32, [100.0] * 32], jnp.float32)
    per_tensor = calibration.fake_quantize_act(x, 8, per_row=False)
    per_row = calibration.fake_quantize_act(x, 8, per_row=True)
    err_t = float(jnp.sum((x - per_tensor) ** 2))
    err_r = float(jnp.sum((x - per_row) ** 2))
    assert err_r <= err_t
