"""Property-testing front door: real hypothesis when installed, else a
minimal vendored fallback so the property suite ALWAYS runs.

The dependency is declared in ``requirements-dev.txt`` (CI installs it and
runs the real engine); the seed image this repo grew up in ships without
``hypothesis``, and the suite was silently skipped for five PRs because of
it.  The fallback below implements just the surface ``test_properties.py``
uses -- ``given``/``settings``/``assume``, scalar strategies, and
``hypothesis.extra.numpy.arrays`` -- as deterministic seeded random
sampling.  It does no shrinking and no example database; it exists so the
properties are *exercised* everywhere, not to replace hypothesis where the
real thing is available.
"""
from __future__ import annotations

try:  # the real engine, preferred whenever installed
    from hypothesis import assume, given, settings  # noqa: F401
    import hypothesis.extra.numpy as hnp  # noqa: F401
    import hypothesis.strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # vendored fallback
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Unsatisfied(Exception):
        """Raised by assume() to discard one generated example."""

    def assume(condition):
        if not condition:
            raise _Unsatisfied()
        return True

    class _Strategy:
        """One sampleable value source: ``draw(rng)`` -> value."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class _St:
        """The ``hypothesis.strategies`` subset the suite uses."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def floats(min_value, max_value, width=64, **_):
            def draw(rng):
                v = rng.uniform(min_value, max_value)
                return float(np.float32(v)) if width == 32 else float(v)

            return _Strategy(draw)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def tuples(*strategies):
            return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

    st = _St()

    class _Hnp:
        """``hypothesis.extra.numpy`` subset: the ``arrays`` strategy."""

        @staticmethod
        def arrays(dtype, shape, elements=None, unique=False, **_):
            dtype = np.dtype(dtype)

            def draw(rng):
                shp = shape.draw(rng) if isinstance(shape, _Strategy) else shape
                size = int(np.prod(shp))
                if elements is None:
                    flat = rng.standard_normal(size)
                elif unique:
                    # rejection-sample to uniqueness (float draws over a
                    # continuous range collide with probability ~0; a few
                    # redraws cover the rest)
                    flat = np.empty(size, dtype)
                    seen = set()
                    i = 0
                    while i < size:
                        v = dtype.type(elements.draw(rng))
                        if v not in seen:
                            seen.add(v)
                            flat[i] = v
                            i += 1
                else:
                    flat = np.asarray(
                        [elements.draw(rng) for _ in range(size)], dtype
                    )
                return flat.astype(dtype).reshape(shp)

            return _Strategy(draw)

    hnp = _Hnp()

    def settings(max_examples=20, deadline=None, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            # NOT functools.wraps: copying __wrapped__ would hand pytest the
            # inner signature and make it hunt for fixtures named after the
            # generated arguments
            def runner(*args, **kwargs):
                n = getattr(fn, "_max_examples", 20)
                # deterministic per-test seed: same cases every run
                rng = np.random.default_rng(
                    zlib.crc32(fn.__name__.encode()) & 0xFFFFFFFF
                )
                ran = 0
                attempts = 0
                while ran < n and attempts < n * 50:
                    attempts += 1
                    example = [s.draw(rng) for s in strategies]
                    try:
                        fn(*args, *example, **kwargs)
                    except _Unsatisfied:
                        continue
                    ran += 1
                assert ran, f"{fn.__name__}: every generated example was assumed away"

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco
