"""Unified flash kernel for S > 1 cache-attends vs the XLA oracle.

Parity matrix: {kv_bf16, kv_int8, kv_mx} x {causal-global, sliding-window}
x {GQA, MHA} x ragged chunk starts.  Both paths read the SAME cache
(history written to ``start``, then the chunk written at ``start``), so
format quantization error cancels and the comparison isolates the
kernel's online-softmax math over the packed leaves; only float sum-order
differences remain (atol 5e-5).

Plus: model-level routing (``cfg.flash_prefill`` toggles the kernel under
real ``prefill_chunk`` dispatches at ragged starts), the in-chunk
self-attention tail (``api.prefill``), query/KV block selection, and the
KV_SEQ_SHARD fallback -- flash routing must be cleanly BYPASSED (oracle
output, no pallas_call in the jaxpr) whenever a multi-device activation
mesh shards the cache, for both the S == 1 and S > 1 paths (subprocess:
the forced host device count must precede jax's first initialization).
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels.flash_prefill import flash_attend, pick_kv_block, pick_q_block
from repro.models import build_model, kv_cache
from repro.models.attention import _attend_dense, _mask_bias

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FORMATS = ("kv_bf16", "kv_int8", "kv_mx")


class _Cfg:
    kv_bits = 16

    def __init__(self, kh, hd, fmt):
        self.n_kv_heads = kh
        self.kv_fmt = fmt
        self._hd = hd

    def hd(self):
        return self._hd


def _chunked_cache(fmt, b, t, kh, hd, s, start, seed=0):
    """History [0, start) then a chunk [start, start + s), like chunked
    prefill writes them.  Returns (cache, valid (B,))."""
    rng = np.random.default_rng(seed)
    cache = kv_cache.init_cache(_Cfg(kh, hd, fmt), (b,), t)
    if start:
        hk = jnp.asarray(rng.normal(size=(b, start, kh, hd)) * 0.5, jnp.float32)
        hv = jnp.asarray(rng.normal(size=(b, start, kh, hd)) * 0.5, jnp.float32)
        cache, _ = kv_cache.write(fmt, cache, hk, hv, jnp.int32(0))
    ck = jnp.asarray(rng.normal(size=(b, s, kh, hd)) * 0.5, jnp.float32)
    cv = jnp.asarray(rng.normal(size=(b, s, kh, hd)) * 0.5, jnp.float32)
    cache, valid = kv_cache.write(fmt, cache, ck, cv, jnp.int32(start))
    return cache, valid


def _oracle(q, cache, fmt, start, valid, window):
    """XLA fold-the-scales cache attend for a contiguous chunk at start."""
    b, s = q.shape[0], q.shape[1]
    t = cache["k"].shape[1]
    ck, cv, ks, vs = kv_cache.attend_view(fmt, cache)
    q_pos = jnp.broadcast_to(start + jnp.arange(s), (b, s))
    bias = _mask_bias(q_pos, jnp.arange(t), True, window, valid)
    return _attend_dense(q, ck, cv, bias[:, None, None], kscale=ks, vscale=vs)


def _flash(q, cache, fmt, start, valid, window, **kw):
    b = q.shape[0]
    win = jnp.asarray(
        2**30 if window is None else window, jnp.int32
    ).reshape(1, 1)
    return flash_attend(
        q, cache["k"], cache["v"], cache.get("ke"), cache.get("ve"),
        jnp.full((b, 1), start, jnp.int32),
        valid.astype(jnp.int32).reshape(b, 1),
        win, fmt=fmt, interpret=True, **kw,
    )


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("start", [0, 13], ids=["start0", "ragged"])
@pytest.mark.parametrize(
    "kh,g,window", [(2, 2, None), (4, 1, None), (2, 2, 8)],
    ids=["gqa", "mha", "window"],
)
def test_flash_prefill_parity(fmt, start, kh, g, window):
    b, t, hd, s = 2, 64, 16, 8
    cache, valid = _chunked_cache(fmt, b, t, kh, hd, s, start)
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(b, s, kh, g, hd)), jnp.float32)
    got = _flash(q, cache, fmt, start, valid, window)
    want = _oracle(q, cache, fmt, start, valid, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)


@pytest.mark.parametrize("fmt", FORMATS)
def test_flash_prefill_small_blocks(fmt):
    """Multiple grid steps on BOTH the query and KV axes."""
    b, kh, g, hd, t, s, start = 2, 2, 2, 8, 128, 16, 37
    cache, valid = _chunked_cache(fmt, b, t, kh, hd, s, start)
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(b, s, kh, g, hd)), jnp.float32)
    bk = 32 if fmt == "kv_mx" else 16
    got = _flash(q, cache, fmt, start, valid, None, block_q=4, block_k=bk)
    want = _oracle(q, cache, fmt, start, valid, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)


def test_flash_prefill_ragged_valid_rows():
    """Per-row fill levels (continuous batching: slots at different
    depths): rows must not see past their own valid length."""
    fmt, b, kh, g, hd, t, s = "kv_int8", 3, 2, 2, 16, 64, 4
    rng = np.random.default_rng(3)
    cache = kv_cache.init_cache(_Cfg(kh, hd, fmt), (b,), t)
    full = jnp.asarray(rng.normal(size=(b, 48, kh, hd)) * 0.5, jnp.float32)
    cache, _ = kv_cache.write(fmt, cache, full, full, jnp.int32(0))
    starts = np.asarray([5, 20, 41])
    valid = jnp.asarray(starts + s, jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, s, kh, g, hd)), jnp.float32)
    win = jnp.full((1, 1), 2**30, jnp.int32)
    got = flash_attend(
        q, cache["k"], cache["v"], cache.get("ke"), cache.get("ve"),
        jnp.asarray(starts, jnp.int32).reshape(b, 1),
        valid.reshape(b, 1), win, fmt=fmt, interpret=True,
    )
    ck, cv, ks, vs = kv_cache.attend_view(fmt, cache)
    q_pos = jnp.asarray(starts)[:, None] + jnp.arange(s)[None, :]
    bias = _mask_bias(q_pos, jnp.arange(t), True, None, valid)
    want = _attend_dense(q, ck, cv, bias[:, None, None], kscale=ks, vscale=vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)


def test_pick_q_block():
    assert pick_q_block(64, 1) == 64
    assert pick_q_block(64, 2) == 32
    assert pick_q_block(8, 16) == 4  # row budget: bq*G stays near want
    assert pick_q_block(13, 2) == 13  # prime chunk: one whole-S block
    assert pick_q_block(12, 4, want=32) == 6
    assert pick_q_block(7, 16) == 1  # G alone above budget: one query row
    # kv blocks are shared with the decode kernel (re-exported)
    assert pick_kv_block(2048, "kv_mx") == 128


@pytest.mark.parametrize("fmt", FORMATS)
def test_model_level_prefill_routing(fmt):
    """cfg.flash_prefill toggles the kernel under real prefill_chunk
    dispatches at ragged starts.  Later layers re-quantize their K/V from
    hidden states that differ by kernel sum-order, so bf16 caches round
    one ulp apart -- logits agree to 5e-3 and greedy argmax exactly."""
    base = configs.get_smoke("gemma3-12b")  # sliding-window + GQA coverage
    outs = {}
    for flash in (False, True):
        cfg = dataclasses.replace(base, kv_fmt=fmt, flash_prefill=flash)
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        cache = api.init_cache(2, 64)
        toks = jnp.arange(26, dtype=jnp.int32).reshape(2, 13) % cfg.vocab
        # 13 tokens -> ragged chunks [5, 8] at starts 0 and 5
        _, cache = api.prefill_chunk(params, toks[:, :5], jnp.int32(0), cache)
        logits, cache = api.prefill_chunk(
            params, toks[:, 5:], jnp.int32(5), cache
        )
        outs[flash] = np.asarray(logits)
    np.testing.assert_allclose(outs[True], outs[False], atol=5e-3)
    np.testing.assert_array_equal(
        outs[True].argmax(-1), outs[False].argmax(-1)
    )


@pytest.mark.parametrize("fmt", ["kv_bf16", "kv_mx"])
def test_model_level_self_tail_routing(fmt):
    """cfg.flash_prefill also routes the in-chunk self-attention tail
    (full-prompt prefill, attend_cache=False) -- decode steps off the
    written cache must agree with the oracle-prefilled run."""
    base = configs.get_smoke("gemma3-12b")
    outs = {}
    for flash in (False, True):
        cfg = dataclasses.replace(base, kv_fmt=fmt, flash_prefill=flash)
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        cache = api.init_cache(2, 64)
        batch = {
            "tokens": jnp.arange(16, dtype=jnp.int32).reshape(2, 8) % cfg.vocab
        }
        logits, cache = api.prefill(params, batch, cache)
        for i in range(8, 10):
            logits, cache = api.decode(
                params, jnp.full((2, 1), 3, jnp.int32), jnp.int32(i), cache
            )
        outs[flash] = np.asarray(logits)
    np.testing.assert_allclose(outs[True], outs[False], atol=5e-3)
    np.testing.assert_array_equal(
        outs[True].argmax(-1), outs[False].argmax(-1)
    )


def test_flash_prefill_training_unaffected():
    """flash_prefill is a serving-time knob: the training path (no cache)
    must neither route through the kernel (it has no VJP) nor change the
    loss."""
    base = configs.get_smoke("qwen3-8b")
    losses = {}
    for flash in (False, True):
        cfg = dataclasses.replace(base, flash_prefill=flash)
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        batch = {
            "tokens": jnp.arange(32, dtype=jnp.int32).reshape(2, 16) % cfg.vocab,
            "labels": jnp.arange(32, dtype=jnp.int32).reshape(2, 16) % cfg.vocab,
        }
        loss, grads = jax.value_and_grad(api.train_loss)(params, batch)
        losses[flash] = float(loss)
        assert all(
            bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads)
        )
    assert losses[True] == losses[False]


# ---------------------------------------------------------------------------
# KV_SEQ_SHARD fallback: flash routing bypassed under a multi-device mesh.
# ---------------------------------------------------------------------------
BYPASS_SCRIPT = r"""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import build_model
from repro.launch.mesh import parse_mesh_spec
from repro.parallel import sharding as rules

assert jax.device_count() == 4, jax.device_count()

# gemma3 smoke: 2 kv heads on a 2-way model axis -> under KV_SEQ_SHARD the
# S-axis fallback applies to quantized caches; either way the cache is NOT
# whole per device and flash routing must stand down.
base = configs.get_smoke("gemma3-12b")
mesh = parse_mesh_spec("dp=2,tp=2")
toks = jnp.arange(26, dtype=jnp.int32).reshape(2, 13) % base.vocab

def run(flash_prefill, flash_decode, meshed):
    cfg = dataclasses.replace(
        base, kv_fmt="kv_int8",
        flash_prefill=flash_prefill, flash_decode=flash_decode,
    )
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache = api.init_cache(2, 64)
    prev = rules._ACT_MESH[0]
    try:
        if meshed:
            rules.set_activation_mesh(mesh)
        # S > 1 cache attend (chunked prefill) then S == 1 decode steps
        _, cache = api.prefill_chunk(params, toks[:, :5], jnp.int32(0), cache)
        jaxpr_prefill = str(jax.make_jaxpr(
            lambda p, t, s, c: api.prefill_chunk(p, t, s, c)
        )(params, toks[:, 5:], jnp.int32(5), cache))
        lg, cache = api.prefill_chunk(params, toks[:, 5:], jnp.int32(5), cache)
        jaxpr_decode = str(jax.make_jaxpr(
            lambda p, t, i, c: api.decode(p, t, i, c)
        )(params, jnp.full((2, 1), 3, jnp.int32), jnp.int32(13), cache))
        lg2, cache = api.decode(
            params, jnp.full((2, 1), 3, jnp.int32), jnp.int32(13), cache
        )
    finally:
        rules.set_activation_mesh(prev)
    return (np.asarray(lg), np.asarray(lg2),
            "pallas_call" in jaxpr_prefill, "pallas_call" in jaxpr_decode)

# single-device reference: the oracle path, no mesh, no flash
ref_lg, ref_lg2, ref_pf, ref_dec = run(False, False, meshed=False)
assert not ref_pf and not ref_dec

# flash flags ON under the 4-device mesh: routing must be BYPASSED --
# no pallas_call in either graph, output identical to the oracle
got_lg, got_lg2, got_pf, got_dec = run(True, True, meshed=True)
assert not got_pf, "S>1 flash prefill must stand down under a sharded cache"
assert not got_dec, "S==1 flash decode must stand down under a sharded cache"
np.testing.assert_allclose(got_lg, ref_lg, atol=1e-5)
np.testing.assert_allclose(got_lg2, ref_lg2, atol=1e-5)

# sanity: without the mesh the same flags DO route (kernel present)
_, _, on_pf, on_dec = run(True, True, meshed=False)
assert on_pf and on_dec, "flags should route when the cache is whole"
print("BYPASS OK")
"""


@pytest.mark.slow
def test_kv_seq_shard_flash_bypass():
    """Under a multi-device activation mesh (kv-head- or KV_SEQ_SHARD
    sequence-sharded cache) flash routing is cleanly bypassed -- oracle
    outputs, no pallas_call -- for BOTH the S == 1 and S > 1 paths, and
    re-engages without the mesh."""
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(REPO, "src"),
        XLA_FLAGS="--xla_force_host_platform_device_count=4",
    )
    r = subprocess.run(
        [sys.executable, "-c", BYPASS_SCRIPT],
        capture_output=True, text=True, timeout=420, env=env, cwd=REPO,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "BYPASS OK" in r.stdout
