"""Algorithms 1 & 2 against a brute-force NumPy oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ternary


def brute_filter_threshold(w: np.ndarray) -> float:
    """Exhaustive Algorithm 2."""
    a = np.flip(np.sort(np.abs(w)))
    best_err, best_a = np.inf, 0.0
    for t in range(1, len(w) + 1):
        al = float(np.sqrt(np.sum(a[:t] ** 2) / t))
        idx = np.argsort(-np.abs(w))[:t]
        wq = np.zeros_like(w)
        wq[idx] = np.sign(w[idx]) * al
        err = float(np.sum((w - wq) ** 2))
        if err < best_err:
            best_err, best_a = err, al
    return best_a


def brute_cluster(cluster: np.ndarray) -> float:
    """Exhaustive Algorithm 1 (threshold == scale semantics)."""
    alphas = np.array([brute_filter_threshold(w) for w in cluster])
    b = np.flip(np.sort(alphas))
    best_err, best_a = np.inf, 0.0
    for t in range(1, len(alphas) + 1):
        al = float(np.sqrt(np.sum(b[:t] ** 2) / t))
        wq = np.where(np.abs(cluster) > al, np.sign(cluster) * al, 0.0)
        err = float(np.sum((cluster - wq) ** 2))
        if err < best_err:
            best_err, best_a = err, al
    return best_a


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("f", [4, 9, 16])
def test_algorithm2_matches_bruteforce(seed, f):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(f,)).astype(np.float32)
    got = float(ternary.filter_threshold(jnp.asarray(w)))
    want = brute_filter_threshold(w)
    assert got == pytest.approx(want, rel=1e-5)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n,f", [(4, 4), (8, 9), (2, 16)])
def test_algorithm1_matches_bruteforce(seed, n, f):
    rng = np.random.default_rng(seed)
    cl = rng.normal(size=(n, f)).astype(np.float32)
    codes, alpha = ternary.cluster_ternarize(jnp.asarray(cl))
    assert float(alpha) == pytest.approx(brute_cluster(cl), rel=1e-5)
    # codes consistent with the threshold rule
    mask = np.abs(cl) > float(alpha)
    assert (np.asarray(codes) == (np.sign(cl) * mask).astype(np.int8)).all()


def test_ternarize_matrix_shapes_and_values():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(128, 24)).astype(np.float32))
    codes, alpha = ternary.ternarize_matrix(w, group_size=32, filter_size=8)
    assert codes.shape == (128, 24) and alpha.shape == (4, 24)
    assert set(np.unique(np.asarray(codes))) <= {-1, 0, 1}
    assert (np.asarray(alpha) >= 0).all()


def test_reconstruction_beats_naive_scale():
    """Hierarchical search should beat a naive mean-|w| ternary scale."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(256, 16)).astype(np.float32) ** 3)  # heavy tails
    codes, alpha = ternary.ternarize_matrix(w, group_size=64, filter_size=8)
    rec = ternary.ternary_dequantize(codes, alpha, 64)
    err = float(jnp.sum((w - rec) ** 2))
    naive_alpha = float(jnp.mean(jnp.abs(w)))
    naive = jnp.sign(w) * naive_alpha
    naive_err = float(jnp.sum((w - naive) ** 2))
    assert err < naive_err


def test_all_zero_cluster():
    cl = jnp.zeros((4, 8))
    codes, alpha = ternary.cluster_ternarize(cl)
    assert float(alpha) == 0.0
    assert (np.asarray(codes) == 0).all()


def test_refit_scale_never_worse():
    rng = np.random.default_rng(2)
    cl = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))

    def err(codes, alpha):
        return float(jnp.sum((cl - codes.astype(jnp.float32) * alpha) ** 2))

    c1, a1 = ternary.cluster_ternarize(cl, refit_scale=False)
    c2, a2 = ternary.cluster_ternarize(cl, refit_scale=True)
    assert err(c2, a2) <= err(c1, a1) + 1e-6
