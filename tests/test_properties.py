"""Property tests for the quantization core.

Runs under real hypothesis when installed (declared in
requirements-dev.txt); falls back to the deterministic sampling shim in
``tests/_proptest.py`` otherwise, so this suite is never skipped -- it was
silently dead from the seed through PR 4 because the image lacks the
dependency."""
import os
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _proptest import assume, given, hnp, settings, st  # noqa: E402,F401

from repro.core import dfp, quantizer, ternary  # noqa: E402

F32 = hnp.arrays(
    np.float32,
    st.tuples(st.integers(2, 6).map(lambda x: x * 16), st.integers(1, 8)),
    elements=st.floats(-100, 100, width=32),
)


@given(F32, st.sampled_from([4, 8]))
@settings(max_examples=30, deadline=None)
def test_dfp_error_bound(x, bits):
    """|x - dq(q(x))| <= 2**(e-1) elementwise (round-to-nearest), when not clipped."""
    q, e = dfp.quantize_tensor(jnp.asarray(x), bits)
    rec = np.asarray(dfp.dequantize(q, e))
    step = 2.0 ** float(e)
    assert np.all(np.abs(x - rec) <= step / 2 + 1e-6)


@given(F32)
@settings(max_examples=30, deadline=None)
def test_dfp_idempotent(x):
    """Quantizing an already-quantized tensor is exact."""
    y = np.asarray(dfp.fake_quantize(jnp.asarray(x), 8))
    z = np.asarray(dfp.fake_quantize(jnp.asarray(y), 8))
    assert np.allclose(y, z)


@given(
    hnp.arrays(
        np.float32, (64, 4),
        elements=st.floats(-10, 10, width=32), unique=True,
    ),
    st.floats(0.1, 8.0),
)
@settings(max_examples=25, deadline=None)
def test_ternary_scale_equivariance(w, c):
    """W -> cW  =>  reconstruction error scales by c^2 (the true invariant --
    the argmin itself may flip between near-tied optima under fp scaling)."""

    def recon_err(mat, codes, alpha):
        rec = ternary.ternary_dequantize(codes, alpha, 32)
        return float(jnp.sum((mat - rec) ** 2))

    w1 = jnp.asarray(w)
    w2 = w1 * c
    k1, a1 = ternary.ternarize_matrix(w1, 32, 8)
    k2, a2 = ternary.ternarize_matrix(w2, 32, 8)
    # unique elements => no |w| == alpha exact ties (the paper's strict
    # threshold is discontinuous there, e.g. for constant matrices)
    e1 = recon_err(w1, k1, a1)
    e2 = recon_err(w2, k2, a2)
    tol = max(0.02 * e1 * c * c, 1e-3 * float(jnp.sum(w2 * w2)) + 1e-6)
    assert abs(e2 - e1 * c * c) <= tol


@given(hnp.arrays(np.int8, (64, 8), elements=st.integers(-1, 1)))
@settings(max_examples=25, deadline=None)
def test_pack2_roundtrip(codes):
    packed = quantizer.pack2(jnp.asarray(codes))
    assert packed.shape == (4, 8)
    assert (np.asarray(quantizer.unpack2(packed, 64)) == codes).all()


@given(hnp.arrays(np.int8, (32, 4), elements=st.integers(-7, 7)))
@settings(max_examples=25, deadline=None)
def test_pack4_roundtrip(q):
    packed = quantizer.pack4(jnp.asarray(q))
    assert packed.shape == (4, 4)
    assert (np.asarray(quantizer.unpack4(packed, 32)) == q).all()


@given(
    hnp.arrays(
        np.float32, (128, 2),
        elements=st.floats(-5, 5, width=32), unique=True,
    ),
    st.sampled_from([2, 4, 8]),
)
@settings(max_examples=20, deadline=None)
def test_monotone_error_in_bits(w, bits):
    """More bits never increases quantization error (same grouping), up to
    the 8-bit scale-table quantization wobble (paper Alg. 1 step 9)."""
    errs = {
        b: float(quantizer.weight_quantization_error(jnp.asarray(w), b, 32, 8))
        for b in (2, 4, 8)
    }
    tol = 1e-4 + 1e-4 * float(np.sum(w.astype(np.float64) ** 2))
    assert errs[8] <= errs[4] + tol
    assert errs[4] <= errs[2] + tol


@given(hnp.arrays(np.float32, (64, 2), elements=st.floats(-5, 5, width=32)))
@settings(max_examples=20, deadline=None)
def test_cluster_independence(w):
    """Perturbing one cluster leaves other clusters' scales unchanged."""
    g = 32
    _, a1 = ternary.ternarize_matrix(jnp.asarray(w), g, 8)
    w2 = np.array(w)
    w2[:g, 0] *= 3.7  # only cluster (0, 0)
    _, a2 = ternary.ternarize_matrix(jnp.asarray(w2), g, 8)
    a1n, a2n = np.asarray(a1), np.asarray(a2)
    mask = np.ones_like(a1n, bool)
    mask[0, 0] = False
    assert np.allclose(a1n[mask], a2n[mask])


@given(
    hnp.arrays(np.float32, (64, 3), elements=st.floats(-50, 50, width=32)),
    st.sampled_from([2, 4, 8]),
)
@settings(max_examples=20, deadline=None)
def test_qtensor_roundtrip_structure(w, bits):
    qt = quantizer.quantize_weights(jnp.asarray(w), bits, 16, 4)
    rec = quantizer.dequantize_weights(qt)
    assert rec.shape == w.shape
    assert qt.scale_m.dtype == jnp.int8
    # scale table quantized to 8-bit: dequantized scales on the DFP grid
    scales = np.asarray(quantizer.dequantize_scales(qt.scale_m, qt.scale_e))
    step = 2.0 ** float(qt.scale_e)
    assert np.allclose(scales / step, np.round(scales / step), atol=1e-4)


# ---------------------------------------------------------------------------
# nf4: unsigned LUT-code pack/unpack contracts.
# ---------------------------------------------------------------------------
@given(hnp.arrays(np.int8, (32, 4), elements=st.integers(0, 15)))
@settings(max_examples=25, deadline=None)
def test_pack4u_roundtrip(codes):
    """pack4u/unpack4u are exact inverses over the unsigned code range."""
    packed = quantizer.pack4u(jnp.asarray(codes))
    assert packed.shape == (4, 4) and packed.dtype == jnp.uint32
    assert (np.asarray(quantizer.unpack4u(packed, 32)) == codes).all()


@given(hnp.arrays(np.int8, (16, 2), elements=st.integers(0, 15)))
@settings(max_examples=25, deadline=None)
def test_nf4_decode_is_lut_of_codes(codes):
    """Packed-nf4 decode == LUT applied to the unpacked codes, values on the
    int8 LUT grid (the range contract the kernels' in-VMEM LUT mirrors)."""
    lut = np.asarray(quantizer.NF4_LUT_I8, np.int8)
    packed = quantizer.pack4u(jnp.asarray(codes))
    dec = np.asarray(quantizer.nf4_lut_decode(quantizer.unpack4u(packed, 16)))
    assert (dec == lut[codes.astype(np.int32)]).all()
    assert dec.dtype == np.int8 and set(dec.flatten()) <= set(lut.tolist())


def test_pack4u_rejects_out_of_range():
    """The unsigned range contract is asserted on concrete inputs."""
    import pytest

    with pytest.raises(AssertionError):
        quantizer.pack4u(jnp.full((8, 2), 16, jnp.int8))
    with pytest.raises(AssertionError):
        quantizer.pack4u(jnp.full((8, 2), -1, jnp.int8))


@given(hnp.arrays(np.float32, (64, 3), elements=st.floats(-50, 50, width=32)))
@settings(max_examples=20, deadline=None)
def test_nf4_qtensor_range_and_grid(w):
    """nf4 QTensors: packed codes in [0, 15], decoded mantissas on the LUT
    grid, reconstruction == codes x dequantized scale table exactly."""
    from repro.quant import formats

    qt = formats.quantize_weights(jnp.asarray(w), group_size=16, fmt="nf4")
    codes = np.asarray(quantizer.unpack4u(qt.packed, 64))
    assert codes.min() >= 0 and codes.max() <= 15
    dec = np.asarray(formats.decode_codes(qt))
    assert set(dec.flatten()) <= set(quantizer.NF4_LUT_I8)
    scales = np.asarray(quantizer.dequantize_scales(qt.scale_m, qt.scale_e))
    want = (dec.astype(np.float32).reshape(4, 16, 3) * scales[:, None, :])
    np.testing.assert_array_equal(
        np.asarray(formats.dequantize_weights(qt)), want.reshape(64, 3)
    )


# ---------------------------------------------------------------------------
# mx: shared power-of-two block exponents (all-shift scale contract).
# ---------------------------------------------------------------------------
@given(hnp.arrays(np.float32, (64, 3), elements=st.floats(-50, 50, width=32)))
@settings(max_examples=20, deadline=None)
def test_mx_qtensor_shift_only_scales(w):
    """mx QTensors: every scale mantissa is an exact power of two in
    [1, 64] (the all-shift dequant contract), mantissas stay in the
    symmetric int8 range, and the block length is pinned to 32."""
    from repro.quant import formats

    qt = formats.quantize_weights(jnp.asarray(w), group_size=16, fmt="mx")
    assert qt.group_size == 32  # format-pinned block, caller's 16 overridden
    sm = np.asarray(qt.scale_m).astype(np.int32)
    assert ((sm > 0) & ((sm & (sm - 1)) == 0)).all() and sm.max() <= 64
    codes = np.asarray(formats.decode_codes(qt))
    assert np.abs(codes.astype(np.int32)).max() <= dfp.qmax(8)
    # the loudest block reconstructs within half a step of its own exponent
    rec = np.asarray(formats.dequantize_weights(qt))
    blocks = w.reshape(2, 32, 3)
    rblocks = rec.reshape(2, 32, 3)
    eff = np.log2(np.asarray(
        quantizer.dequantize_scales(qt.scale_m, qt.scale_e), np.float64))
    loud = np.unravel_index(np.argmax(np.abs(blocks).max(1)), (2, 3))
    g, c = loud
    step = 2.0 ** eff[g, c]
    assert np.abs(blocks[g, :, c] - rblocks[g, :, c]).max() <= step / 2 + 1e-6


# ---------------------------------------------------------------------------
# KV-cache formats: nibble packing + quantize-on-write error bounds.
# ---------------------------------------------------------------------------
@given(hnp.arrays(np.int64, st.tuples(st.integers(1, 4), st.integers(1, 8)),
                  elements=st.integers(-8, 7)))
@settings(max_examples=30, deadline=None)
def test_kv_pack_i4_roundtrip(codes):
    """pack_i4/unpack_i4 is an exact bijection on [-8, 7] codes."""
    from repro.models import kv_cache

    c = jnp.asarray(np.repeat(codes, 2, axis=-1), jnp.int8)  # even head_dim
    packed = kv_cache.pack_i4(c)
    assert packed.dtype == jnp.uint8 and packed.shape[-1] == c.shape[-1] // 2
    assert np.array_equal(np.asarray(kv_cache.unpack_i4(packed)), np.asarray(c))


KV_TOKENS = hnp.arrays(
    np.float32,
    st.tuples(st.integers(1, 2), st.integers(1, 3).map(lambda x: x * 8)),
    elements=st.floats(-30, 30, width=32),
)


@given(KV_TOKENS)
@settings(max_examples=20, deadline=None)
def test_kv_int8_write_error_bound(x):
    """kv_int8 quantize-on-write: each token reconstructs within half a step
    of its own per-(token, head) exponent (round-to-nearest DFP)."""
    from repro.models import kv_cache

    b, hd = x.shape
    kv = jnp.asarray(x).reshape(1, b, 1, hd)  # (B=1, S=b, Kh=1, hd)
    cache = kv_cache.get_kv_format("kv_int8").init((1,), 32, 1, hd, jnp.bfloat16)
    cache, _ = kv_cache.write("kv_int8", cache, kv, kv, jnp.int32(0))
    ck, _, ks, _ = kv_cache.attend_view("kv_int8", cache)
    rec = np.asarray(ck, np.float32)[0, :b, 0] * np.asarray(ks)[0, :b, 0, None]
    step = np.asarray(ks)[0, :b, 0, None]  # scale == 2**e == one code step
    assert (np.abs(rec - x) <= step / 2 + 1e-6).all()


@given(hnp.arrays(np.float32, (40, 8), elements=st.floats(-30, 30, width=32)))
@settings(max_examples=20, deadline=None)
def test_kv_mx_write_error_bound(x):
    """kv_mx: every token reconstructs within half a step of its BLOCK's
    shared exponent (the running max over the block's tokens), and stored
    nibbles stay in the symmetric int4 range."""
    from repro.models import kv_cache

    s, hd = x.shape
    kv = jnp.asarray(x).reshape(1, s, 1, hd)
    cache = kv_cache.get_kv_format("kv_mx").init((1,), 64, 1, hd, jnp.bfloat16)
    cache, _ = kv_cache.write("kv_mx", cache, kv, kv, jnp.int32(0))
    codes = np.asarray(kv_cache.unpack_i4(cache["k"]))[0, :s, 0]
    assert np.abs(codes).max() <= 7
    ck, _, ks, _ = kv_cache.attend_view("kv_mx", cache)
    rec = np.asarray(ck, np.float32)[0, :s, 0] * np.asarray(ks)[0, :s, 0, None]
    step = np.asarray(ks)[0, :s, 0, None]
    assert (np.abs(rec - x) <= step / 2 + 1e-6).all()


@given(hnp.arrays(np.float32, (4, 8), elements=st.floats(-30, 30, width=32)),
       st.integers(0, 31))
@settings(max_examples=20, deadline=None)
def test_kv_mx_running_max_rescale(x, pos0):
    """Masked single-token writes into one block: earlier tokens re-scale
    when a later, louder token raises the block exponent -- every resident
    token still reconstructs within half the FINAL block step."""
    from repro.models import kv_cache

    hd = x.shape[1]
    cache = kv_cache.get_kv_format("kv_mx").init((1,), 32, 1, hd, jnp.bfloat16)
    positions = [(pos0 + i) % 32 for i in range(4)]
    for i, p in enumerate(positions):
        kv = jnp.asarray(x[i]).reshape(1, 1, 1, hd)
        cache, _ = kv_cache.write(
            "kv_mx", cache, kv, kv, jnp.asarray([p], jnp.int32)
        )
    ck, _, ks, _ = kv_cache.attend_view("kv_mx", cache)
    step = float(np.asarray(ks)[0, 0, 0])  # one block -> one shared scale
    rec = np.asarray(ck, np.float32)[0, :, 0] * step
    for i, p in enumerate(positions):
        # rescale of residents rounds twice; allow one extra half-step
        assert np.abs(rec[p] - x[i]).max() <= step + 1e-6


# ---------------------------------------------------------------------------
# serving scheduler: chunk planning and latency aggregation invariants.
# ---------------------------------------------------------------------------
@given(st.integers(1, 4096), st.integers(1, 256))
@settings(max_examples=60, deadline=None)
def test_chunk_plan_properties(n_tokens, chunk):
    """Every plan (a) sums to exactly n_tokens, (b) never exceeds the chunk
    budget, (c) decomposes the remainder into strictly-descending powers of
    two -- so the compiled shape set stays {chunk} U {2^i < chunk}."""
    from repro.serving import chunk_plan

    sizes = chunk_plan(n_tokens, chunk)
    assert sum(sizes) == n_tokens
    assert all(1 <= s <= chunk for s in sizes)
    tail = [s for s in sizes if s != chunk]
    assert all(s & (s - 1) == 0 for s in tail)  # powers of two
    assert tail == sorted(tail, reverse=True)
    assert len(set(tail)) == len(tail)  # strictly descending: no repeats
    full = [s for s in sizes if s == chunk]
    assert sizes[: len(full)] == full  # full chunks lead the plan


@given(st.integers(1, 1024), st.integers(1, 256))
@settings(max_examples=40, deadline=None)
def test_degraded_chunk_plan_nests(n_tokens, chunk):
    """Overload degradation introduces no new compiled prefill shape: every
    size a degraded plan uses is already reachable under the normal chunk."""
    from repro.serving import chunk_plan, degraded_chunk

    normal_shapes = {chunk} | {1 << i for i in range((chunk).bit_length())
                               if (1 << i) < chunk} | {1}
    degraded_shapes = set(chunk_plan(n_tokens, degraded_chunk(chunk)))
    assert degraded_shapes <= normal_shapes


class _Req:
    """Minimal Request stand-in: just the timing fields LatencyStats reads."""

    def __init__(self, submit_t=None, prefill_start_t=None, first_token_t=None,
                 finish_t=None, n_out=0):
        self.submit_t = submit_t
        self.prefill_start_t = prefill_start_t
        self.first_token_t = first_token_t
        self.finish_t = finish_t
        self.output = [0] * n_out


def test_latency_stats_empty_and_untimed():
    """No samples (or only never-submitted requests) -> every percentile
    block is None, never a numpy empty-slice crash."""
    from repro.serving import LatencyStats

    stats = LatencyStats()
    assert stats.summary() == {"queue_wait": None, "ttft": None, "tpot": None}
    stats.record(_Req())  # submit_t None: ignored entirely
    assert stats.summary() == {"queue_wait": None, "ttft": None, "tpot": None}


@given(st.floats(0.0, 10.0), st.floats(0.001, 5.0), st.floats(0.001, 5.0),
       st.integers(2, 50))
@settings(max_examples=40, deadline=None)
def test_latency_stats_single_sample(t0, wait, gen, n_out):
    """One finished request: p50 == p95 == p99 == the one sample, and TPOT
    is (finish - first_token) / (n_out - 1)."""
    from repro.serving import LatencyStats

    stats = LatencyStats()
    first = t0 + wait
    finish = first + gen
    stats.record(_Req(submit_t=t0, prefill_start_t=t0, first_token_t=first,
                      finish_t=finish, n_out=n_out))
    s = stats.summary()
    for block, want in (("queue_wait", 0.0), ("ttft", wait),
                        ("tpot", gen / (n_out - 1))):
        p = s[block]
        assert p["n"] == 1
        assert abs(p["p50"] - want) < 1e-9 + 1e-6 * abs(want)
        assert p["p50"] == p["p95"] == p["p99"]


def test_latency_stats_single_token_has_no_tpot():
    """A 1-token request defines TTFT but not TPOT (no inter-token gap)."""
    from repro.serving import LatencyStats

    stats = LatencyStats()
    stats.record(_Req(submit_t=0.0, prefill_start_t=0.1, first_token_t=0.2,
                      finish_t=0.2, n_out=1))
    s = stats.summary()
    assert s["ttft"]["n"] == 1 and s["tpot"] is None
