"""Hypothesis property tests for the quantization core."""
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this image")

import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import assume, given, settings

from repro.core import dfp, quantizer, ternary

F32 = hnp.arrays(
    np.float32,
    st.tuples(st.integers(2, 6).map(lambda x: x * 16), st.integers(1, 8)),
    elements=st.floats(-100, 100, width=32),
)


@given(F32, st.sampled_from([4, 8]))
@settings(max_examples=30, deadline=None)
def test_dfp_error_bound(x, bits):
    """|x - dq(q(x))| <= 2**(e-1) elementwise (round-to-nearest), when not clipped."""
    q, e = dfp.quantize_tensor(jnp.asarray(x), bits)
    rec = np.asarray(dfp.dequantize(q, e))
    step = 2.0 ** float(e)
    assert np.all(np.abs(x - rec) <= step / 2 + 1e-6)


@given(F32)
@settings(max_examples=30, deadline=None)
def test_dfp_idempotent(x):
    """Quantizing an already-quantized tensor is exact."""
    y = np.asarray(dfp.fake_quantize(jnp.asarray(x), 8))
    z = np.asarray(dfp.fake_quantize(jnp.asarray(y), 8))
    assert np.allclose(y, z)


@given(
    hnp.arrays(
        np.float32, (64, 4),
        elements=st.floats(-10, 10, width=32), unique=True,
    ),
    st.floats(0.1, 8.0),
)
@settings(max_examples=25, deadline=None)
def test_ternary_scale_equivariance(w, c):
    """W -> cW  =>  reconstruction error scales by c^2 (the true invariant --
    the argmin itself may flip between near-tied optima under fp scaling)."""

    def recon_err(mat, codes, alpha):
        rec = ternary.ternary_dequantize(codes, alpha, 32)
        return float(jnp.sum((mat - rec) ** 2))

    w1 = jnp.asarray(w)
    w2 = w1 * c
    k1, a1 = ternary.ternarize_matrix(w1, 32, 8)
    k2, a2 = ternary.ternarize_matrix(w2, 32, 8)
    # unique elements => no |w| == alpha exact ties (the paper's strict
    # threshold is discontinuous there, e.g. for constant matrices)
    e1 = recon_err(w1, k1, a1)
    e2 = recon_err(w2, k2, a2)
    tol = max(0.02 * e1 * c * c, 1e-3 * float(jnp.sum(w2 * w2)) + 1e-6)
    assert abs(e2 - e1 * c * c) <= tol


@given(hnp.arrays(np.int8, (64, 8), elements=st.integers(-1, 1)))
@settings(max_examples=25, deadline=None)
def test_pack2_roundtrip(codes):
    packed = quantizer.pack2(jnp.asarray(codes))
    assert packed.shape == (4, 8)
    assert (np.asarray(quantizer.unpack2(packed, 64)) == codes).all()


@given(hnp.arrays(np.int8, (32, 4), elements=st.integers(-7, 7)))
@settings(max_examples=25, deadline=None)
def test_pack4_roundtrip(q):
    packed = quantizer.pack4(jnp.asarray(q))
    assert packed.shape == (4, 4)
    assert (np.asarray(quantizer.unpack4(packed, 32)) == q).all()


@given(
    hnp.arrays(
        np.float32, (128, 2),
        elements=st.floats(-5, 5, width=32), unique=True,
    ),
    st.sampled_from([2, 4, 8]),
)
@settings(max_examples=20, deadline=None)
def test_monotone_error_in_bits(w, bits):
    """More bits never increases quantization error (same grouping), up to
    the 8-bit scale-table quantization wobble (paper Alg. 1 step 9)."""
    errs = {
        b: float(quantizer.weight_quantization_error(jnp.asarray(w), b, 32, 8))
        for b in (2, 4, 8)
    }
    tol = 1e-4 + 1e-4 * float(np.sum(w.astype(np.float64) ** 2))
    assert errs[8] <= errs[4] + tol
    assert errs[4] <= errs[2] + tol


@given(hnp.arrays(np.float32, (64, 2), elements=st.floats(-5, 5, width=32)))
@settings(max_examples=20, deadline=None)
def test_cluster_independence(w):
    """Perturbing one cluster leaves other clusters' scales unchanged."""
    g = 32
    _, a1 = ternary.ternarize_matrix(jnp.asarray(w), g, 8)
    w2 = np.array(w)
    w2[:g, 0] *= 3.7  # only cluster (0, 0)
    _, a2 = ternary.ternarize_matrix(jnp.asarray(w2), g, 8)
    a1n, a2n = np.asarray(a1), np.asarray(a2)
    mask = np.ones_like(a1n, bool)
    mask[0, 0] = False
    assert np.allclose(a1n[mask], a2n[mask])


@given(
    hnp.arrays(np.float32, (64, 3), elements=st.floats(-50, 50, width=32)),
    st.sampled_from([2, 4, 8]),
)
@settings(max_examples=20, deadline=None)
def test_qtensor_roundtrip_structure(w, bits):
    qt = quantizer.quantize_weights(jnp.asarray(w), bits, 16, 4)
    rec = quantizer.dequantize_weights(qt)
    assert rec.shape == w.shape
    assert qt.scale_m.dtype == jnp.int8
    # scale table quantized to 8-bit: dequantized scales on the DFP grid
    scales = np.asarray(quantizer.dequantize_scales(qt.scale_m, qt.scale_e))
    step = 2.0 ** float(qt.scale_e)
    assert np.allclose(scales / step, np.round(scales / step), atol=1e-4)
