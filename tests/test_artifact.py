"""Quantized artifact lifecycle: packed save/load round-trips, cold-start
serving parity, plan persistence, and corruption fallback."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import QuantConfig, config_from_dict, config_to_dict
from repro.models import (
    build_model,
    load_servable,
    make_smoke_batch,
    quantize_and_plan,
    save_servable,
)
from repro.quant import QTensor, load_artifact, save_artifact
from repro.serving import Request, ServingEngine
from repro.training import checkpoint as ck

KEY = jax.random.PRNGKey(0)

# one representative smoke arch per zoo family
FAMILY_ARCHS = {
    "dense": "qwen3-8b",
    "moe": "grok-1-314b",
    "vlm": "qwen2-vl-72b",
    "hybrid": "zamba2-7b",
    "ssm": "falcon-mamba-7b",
    "encdec": "whisper-base",
}


def _quantized(arch, bits, calib=False):
    cfg = configs.get_smoke(
        arch, QuantConfig(w_bits=bits, group_size=16, mode="ptq", backend="xla")
    )
    api = build_model(cfg)
    params = api.init(KEY)
    batches = None
    if calib:
        batches = [
            make_smoke_batch(jax.random.PRNGKey(100 + i), cfg, batch=2, seq=16)
            for i in range(2)
        ]
    qparams, plan, qapi = quantize_and_plan(api, params, calib_batches=batches)
    return qapi, qparams, plan


def _flat(tree):
    return [
        (ck._path_str(p), l)
        for p, l in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]


def _assert_trees_bit_exact(a, b):
    fa, fb = _flat(a), _flat(b)
    assert [p for p, _ in fa] == [p for p, _ in fb]
    for (path, la), (_, lb) in zip(fa, fb):
        assert np.asarray(la).dtype == np.asarray(lb).dtype, path
        assert np.array_equal(np.asarray(la), np.asarray(lb)), path


# ---------------------------------------------------------------------------
# Round-trip matrix: every zoo family x every built-in format.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", sorted(FAMILY_ARCHS.values()))
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_artifact_roundtrip_family_x_format(arch, bits, tmp_path):
    qapi, qparams, plan = _quantized(arch, bits)
    save_servable(str(tmp_path), qapi, qparams, plan)
    api2, loaded, art = load_servable(str(tmp_path))

    _assert_trees_bit_exact(qparams, loaded)
    # QTensor static metadata survives (bits/group/shape/fmt), still packed
    orig_qt = {p: l for p, l in _flat_qts(qparams)}
    got_qt = {p: l for p, l in _flat_qts(loaded)}
    assert orig_qt.keys() == got_qt.keys() and orig_qt
    for path, qt in got_qt.items():
        ref = orig_qt[path]
        assert (qt.bits, qt.group_size, qt.shape, qt.fmt) == (
            ref.bits, ref.group_size, ref.shape, ref.fmt
        ), path
        assert qt.packed.dtype == ref.packed.dtype
    # plan round-trips byte-identical, config rebuilds exactly
    assert art.plan is not None and art.plan.to_json() == plan.to_json()
    assert api2.cfg == qapi.cfg


def _flat_qts(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda l: isinstance(l, QTensor)
    )
    return [
        (ck._path_str(p), l) for p, l in flat if isinstance(l, QTensor)
    ]


@pytest.mark.parametrize("fmt", ["nf4", "mx"])
def test_artifact_roundtrip_block_formats(fmt, tmp_path):
    """nf4/mx artifacts round-trip packed (payload projections differ per
    format: nf4 packs K/8 uint32 rows like int4, mx stores raw int8 plus a
    K/32 block-scale table) and cold-start decode is bit-identical."""
    cfg = configs.get_smoke(
        "qwen3-8b",
        QuantConfig(
            w_bits=4 if fmt == "nf4" else 8, group_size=16, mode="ptq",
            backend="xla", fmt=fmt,
        ),
    )
    api = build_model(cfg)
    params = api.init(KEY)
    qparams, plan, qapi = quantize_and_plan(api, params)
    fmts = {qt.fmt for _, qt in _flat_qts(qparams)}
    assert fmt in fmts  # default sites actually use the named format

    save_servable(str(tmp_path), qapi, qparams, plan)
    cold_api, cold_params, art = load_servable(str(tmp_path))
    _assert_trees_bit_exact(qparams, cold_params)
    for path, qt in _flat_qts(cold_params):
        ref = dict(_flat_qts(qparams))[path]
        assert (qt.bits, qt.group_size, qt.shape, qt.fmt) == (
            ref.bits, ref.group_size, ref.shape, ref.fmt
        ), path
    assert art.plan.to_json() == plan.to_json()

    tok = jnp.asarray([[3]], jnp.int32)
    l_mem, _ = qapi.decode(qparams, tok, jnp.int32(0), qapi.init_cache(1, 8))
    l_cold, _ = cold_api.decode(
        cold_params, tok, jnp.int32(0), cold_api.init_cache(1, 8)
    )
    assert np.array_equal(np.asarray(l_mem), np.asarray(l_cold))


def test_legacy_empty_fmt_manifest_resolves_by_bits(tmp_path):
    """Pre-fix artifacts stamped fmt="" (bits-resolved QTensors) must keep
    loading and resolving through the bits default -- which registration
    keeps pointed at the built-ins even though nf4/mx now share those
    widths.  Simulates a pre-fix manifest by blanking the stored fmt tags."""
    from repro.quant.formats import format_of

    qapi, qparams, plan = _quantized("qwen3-8b", 4)
    save_servable(str(tmp_path), qapi, qparams, plan)
    d = tmp_path / "step_000000000"
    mpath = d / "manifest.json"
    man = json.loads(mpath.read_text())
    blanked = 0
    for node in man["nodes"].values():
        if node["codec"] == "qtensor" and node["meta"].get("fmt"):
            node["meta"]["fmt"] = ""  # what a pre-fix writer stored
            blanked += 1
    assert blanked  # post-fix writers always stamp a name
    mpath.write_text(json.dumps(man))  # meta is not payload-checksummed

    _, cold_params, _ = load_servable(str(tmp_path))
    legacy = dict(_flat_qts(cold_params))
    assert legacy
    for path, qt in legacy.items():
        assert qt.fmt == ""  # the artifact really is legacy-shaped
        want = {2: "ternary", 4: "int4", 8: "int8"}[qt.bits]
        assert format_of(qt).name == want, path  # bits default, not nf4/mx
        ref = dict(_flat_qts(qparams))[path]
        # bits-resolution decodes the payload identically to the stamped
        # original (leading stacked-layer axes decode per-matrix)
        dec = format_of(qt).decode
        unstack = lambda a: a.reshape((-1,) + a.shape[-2:])
        got = [dec(p, qt.k) for p in unstack(qt.packed)]
        exp = [dec(p, ref.k) for p in unstack(ref.packed)]
        for g, e in zip(got, exp):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(e))
        np.testing.assert_array_equal(
            np.asarray(qt.scale_m), np.asarray(ref.scale_m)
        )


def test_config_dict_roundtrip():
    cfg = configs.get_smoke("qwen3-8b", QuantConfig(w_bits=4, mode="ptq"))
    blob = json.dumps(config_to_dict(cfg))  # must be JSON-safe
    assert config_from_dict(json.loads(blob)) == cfg


# ---------------------------------------------------------------------------
# Cold-start serving parity: artifact tokens == in-memory quantize tokens.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen3-8b", "grok-1-314b"])
def test_cold_start_decode_bit_exact(arch, tmp_path):
    """The decode step served from a loaded artifact is bit-identical to the
    in-memory ``quantize_and_plan`` path (calibrated static exponents
    included)."""
    qapi, qparams, plan = _quantized(arch, 2, calib=True)
    assert plan.calibrated
    save_servable(str(tmp_path), qapi, qparams, plan)
    cold_api, cold_params, _ = load_servable(str(tmp_path))

    tok = jnp.asarray([[3]], jnp.int32)
    l_mem, _ = qapi.decode(qparams, tok, jnp.int32(0), qapi.init_cache(1, 8))
    l_cold, _ = cold_api.decode(
        cold_params, tok, jnp.int32(0), cold_api.init_cache(1, 8)
    )
    assert np.array_equal(np.asarray(l_mem), np.asarray(l_cold))


def test_engine_from_artifact_serves_same_tokens(tmp_path):
    qapi, qparams, plan = _quantized("qwen3-8b", 2, calib=True)
    save_servable(str(tmp_path), qapi, qparams, plan)

    def tokens(eng):
        eng.submit(Request(uid=0, prompt=[5, 9, 2], max_new_tokens=4))
        return eng.run()[0].output

    warm = tokens(ServingEngine(qapi, qparams, n_slots=2, max_len=16))
    cold = tokens(ServingEngine.from_artifact(str(tmp_path), n_slots=2, max_len=16))
    assert warm == cold


def test_artifact_smaller_than_fp32(tmp_path):
    """Packed ternary artifact on disk is >= 4x smaller than the fp32 tree
    (the deployment claim bench_checkpoint measures at larger scale)."""
    cfg = configs.get_smoke(
        "qwen3-8b", QuantConfig(w_bits=2, group_size=16, mode="ptq", backend="xla")
    )
    api = build_model(cfg)
    params = api.init(KEY)
    qparams, plan, qapi = quantize_and_plan(api, params)

    fp_dir, q_dir = tmp_path / "fp", tmp_path / "q"
    ck.save(str(fp_dir), 0, params)
    save_servable(str(q_dir), qapi, qparams, plan)

    # smoke models are embedding-heavy (kept 8-bit-in-fp32 storage), so the
    # projection compression is diluted; 2x on disk here implies >= 4x at
    # real scale where projections dominate -- asserted exactly in
    # benchmarks/bench_checkpoint.py with a projection-dominated config
    assert ck.dir_bytes(str(fp_dir)) / ck.dir_bytes(str(q_dir)) > 2.0


# ---------------------------------------------------------------------------
# Plan persistence + corruption injection.
# ---------------------------------------------------------------------------
def test_truncated_plan_fails_verification_and_falls_back(tmp_path):
    """A corrupt/truncated quant_plan section must invalidate the step (not
    restore as 'unquantized'): restore_latest falls back to the previous
    intact step, load_artifact skips it."""
    qapi, qparams, plan = _quantized("qwen3-8b", 2)
    save_artifact(
        str(tmp_path), qparams, plan,
        extra={"arch_config": config_to_dict(qapi.cfg)}, step=1,
    )
    save_artifact(
        str(tmp_path), qparams, plan,
        extra={"arch_config": config_to_dict(qapi.cfg)}, step=2,
    )
    plan_file = tmp_path / "step_000000002" / ck.PLAN_FILE
    blob = plan_file.read_text()
    plan_file.write_text(blob[: len(blob) // 2])  # truncate mid-JSON

    assert ck.latest_intact_step(str(tmp_path)) == 1
    art = load_artifact(str(tmp_path))
    assert art.step == 1 and art.plan is not None
    assert art.plan.to_json() == plan.to_json()

    template = jax.eval_shape(lambda: qparams)
    step, tree = ck.restore_latest(str(tmp_path), template)
    assert step == 1
    _assert_trees_bit_exact(tree, qparams)


def test_corrupt_packed_payload_falls_back(tmp_path):
    """Bit-rot in a packed QTensor payload is caught by its sha256."""
    qapi, qparams, plan = _quantized("qwen3-8b", 2)
    save_servable(str(tmp_path), qapi, qparams, plan)
    d = tmp_path / "step_000000000"
    victim = sorted(f for f in os.listdir(d) if f.endswith(".npy"))[0]
    with open(d / victim, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        f.write(b"\xff")
    with pytest.raises(IOError):
        load_artifact(str(tmp_path))


def test_plan_json_tamper_detected(tmp_path):
    """A plan whose JSON parses but whose bytes changed (checksum mismatch)
    is rejected -- content integrity, not just well-formedness."""
    qapi, qparams, plan = _quantized("qwen3-8b", 2)
    save_servable(str(tmp_path), qapi, qparams, plan)
    plan_file = tmp_path / "step_000000000" / ck.PLAN_FILE
    tampered = json.loads(plan_file.read_text())
    tampered["mode"] = "qat"
    plan_file.write_text(json.dumps(tampered))
    with pytest.raises(IOError):
        load_artifact(str(tmp_path))


def test_type_corrupt_manifest_falls_back(tmp_path):
    """A manifest that is valid JSON but structurally wrong-typed (null
    array entry) counts as corrupt and falls back, not crashes."""
    tree = {"a": jnp.arange(4.0)}
    ck.save(str(tmp_path), 1, tree)
    ck.save(str(tmp_path), 2, tree)
    mpath = tmp_path / "step_000000002" / "manifest.json"
    m = json.loads(mpath.read_text())
    m["arrays"] = {"a": None}
    mpath.write_text(json.dumps(m))
    assert ck.latest_intact_step(str(tmp_path)) == 1
    step, _ = ck.restore_latest(str(tmp_path), jax.eval_shape(lambda: tree))
    assert step == 1


def test_checkpoint_without_plan_still_restores(tmp_path):
    """Plain (plan-less) checkpoints keep working through the codec layer."""
    tree = {"a": jnp.arange(4.0), "n": {"b": jnp.ones((2, 2), jnp.int32)}}
    ck.save(str(tmp_path), 3, tree)
    d = ck.step_dir(str(tmp_path), 3)
    assert ck.load_plan(d) is None
    got = ck.restore_tree(d)
    _assert_trees_bit_exact(tree, got)


# ---------------------------------------------------------------------------
# MoE calibration satellite: expert sites land in the plan.
# ---------------------------------------------------------------------------
def test_moe_expert_sites_calibrated(tmp_path):
    """The vmapped expert matmuls route through the observer: expert MLP
    sites carry profiled static exponents, and they survive the artifact."""
    qapi, qparams, plan = _quantized("grok-1-314b", 2, calib=True)
    exp_sites = {p for p, _ in plan.act_exponents}
    assert {
        "blocks/moe/experts/gate",
        "blocks/moe/experts/up",
        "blocks/moe/experts/down",
    } <= exp_sites
    # router (a dense() site) is profiled too
    assert any(p.endswith("moe/router") for p in exp_sites)
    save_servable(str(tmp_path), qapi, qparams, plan)
    _, _, art = load_servable(str(tmp_path))
    assert {p for p, _ in art.plan.act_exponents} == exp_sites


def test_trainer_restores_plan(tmp_path):
    """Trainer.maybe_restore is plan-aware: a restarted node resumes with
    the checkpointed precision table, calibrated exponents included."""
    from repro.training import OptConfig, TrainConfig, Trainer
    from repro.training.data import DataConfig, make_batch

    _, _, plan = _quantized("qwen3-8b", 2, calib=True)
    cfg = configs.get_smoke("phi4-mini-3.8b")
    api = build_model(cfg)
    params = api.init(KEY)
    tcfg = TrainConfig(
        opt=OptConfig(lr=1e-4, warmup_steps=0), ckpt_dir=str(tmp_path),
        ckpt_every=2,
    )
    tr = Trainer(api.train_loss, params, tcfg, plan=plan)
    tr.train(lambda i: make_batch(cfg, DataConfig(batch=2, seq=16), i), 2)

    fresh = Trainer(api.train_loss, params, tcfg)  # "new node", no plan
    assert fresh.maybe_restore() == 2
    assert fresh.plan is not None
    assert fresh.plan.to_json() == plan.to_json()
