"""Optimizer, data pipeline, checkpoint and trainer-resume tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import QuantConfig
from repro.models import build_model
from repro.training import OptConfig, TrainConfig, Trainer, init_state, make_train_step
from repro.training import checkpoint as ck
from repro.training import optimizer as opt_lib
from repro.training.data import DataConfig, make_batch, shard_for_rank


# -- optimizer ---------------------------------------------------------------
def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    cfg = OptConfig(lr=0.3, warmup_steps=0, decay_steps=10_000, weight_decay=0.0)
    state = init_state(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt_lib.apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_8bit_state_matches_fp32_convergence():
    """DFP-compressed moments reach the same optimization quality (per-step
    requantization noise makes exact trajectory tracking the wrong target;
    the invariant is: no blow-up, same convergence)."""
    rng = np.random.default_rng(0)
    w0 = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    final = {}
    for bits in (32, 8):
        params = {"w": w0}
        cfg = OptConfig(lr=0.05, warmup_steps=0, weight_decay=0.0, state_bits=bits)
        state = init_state(params, cfg)
        for i in range(50):
            grads = {"w": 2 * params["w"] + 0.01 * jnp.sin(i + jnp.arange(64.0))}
            params, state, _ = opt_lib.apply_updates(params, grads, state, cfg)
        final[bits] = np.asarray(params["w"])
    l32 = float(np.sum(final[32] ** 2))
    l8 = float(np.sum(final[8] ** 2))
    init_loss = float(np.sum(np.asarray(w0) ** 2))
    assert l32 < 0.05 * init_loss  # fp32 converged
    assert l8 < 0.10 * init_loss  # 8-bit converged comparably
    assert np.abs(final[8]).max() < 2 * np.abs(final[32]).max() + 1e-3  # no blow-up


def test_8bit_v_sqrt_domain_no_explosion():
    """Regression: wide dynamic-range rows must not explode when v rounds
    to zero (the sqrt-domain encoding keeps m and sqrt(v) proportional)."""
    w = jnp.asarray([10.0] + [1e-3] * 63, jnp.float32)
    params = {"w": w}
    cfg = OptConfig(lr=0.01, warmup_steps=0, weight_decay=0.0, state_bits=8)
    state = init_state(params, cfg)
    for _ in range(20):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt_lib.apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 20.0  # bounded updates


def test_grad_clip_metric():
    params = {"w": jnp.ones((4,))}
    cfg = OptConfig(grad_clip=1.0, warmup_steps=0)
    state = init_state(params, cfg)
    _, _, metrics = opt_lib.apply_updates(params, {"w": jnp.full((4,), 100.0)}, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


# -- data pipeline -----------------------------------------------------------
def test_data_deterministic_and_resumable():
    cfg = configs.get_smoke("qwen3-8b")
    d = DataConfig(batch=4, seq=32, seed=7)
    b1 = make_batch(cfg, d, step=13)
    b2 = make_batch(cfg, d, step=13)
    assert (np.asarray(b1["tokens"]) == np.asarray(b2["tokens"])).all()
    b3 = make_batch(cfg, d, step=14)
    assert not (np.asarray(b1["tokens"]) == np.asarray(b3["tokens"])).all()
    # labels are next-token shifted
    assert b1["tokens"].shape == (4, 32) and b1["labels"].shape == (4, 32)


def test_data_rank_sharding_partitions():
    cfg = configs.get_smoke("qwen3-8b")
    b = make_batch(cfg, DataConfig(batch=8, seq=16), 0)
    shards = [shard_for_rank(b, r, 4) for r in range(4)]
    rebuilt = np.concatenate([np.asarray(s["tokens"]) for s in shards])
    assert (rebuilt == np.asarray(b["tokens"])).all()


def test_data_has_learnable_structure():
    """Induced sequential structure => bigram MI is non-trivial."""
    cfg = configs.get_smoke("qwen3-8b")
    b = make_batch(cfg, DataConfig(batch=32, seq=64, structure=0.9), 0)
    toks = np.asarray(b["tokens"])
    nxt = np.asarray(b["labels"])
    pred = (toks * 31 + 7) % cfg.vocab
    assert (pred == nxt).mean() > 0.5  # structure dominates


# -- checkpoint --------------------------------------------------------------
def _tree():
    return {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "n": {"b": jnp.ones((4,), jnp.int32)},
    }


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        t = _tree()
        ck.save(d, 5, t)
        step, got = ck.restore_latest(d, jax.eval_shape(lambda: t))
        assert step == 5
        assert (np.asarray(got["a"]) == np.asarray(t["a"])).all()
        assert got["n"]["b"].dtype == jnp.int32


def test_checkpoint_corruption_falls_back():
    with tempfile.TemporaryDirectory() as d:
        t = _tree()
        ck.save(d, 1, t)
        ck.save(d, 2, jax.tree.map(lambda x: x * 2, t))
        # corrupt the newest checkpoint
        newest = os.path.join(d, "step_000000002")
        victim = [f for f in os.listdir(newest) if f.endswith(".npy")][0]
        with open(os.path.join(newest, victim), "wb") as f:
            f.write(b"garbage")
        step, got = ck.restore_latest(d, jax.eval_shape(lambda: t))
        assert step == 1  # fell back to the intact checkpoint
        assert (np.asarray(got["a"]) == np.asarray(t["a"])).all()


def test_checkpoint_retention():
    with tempfile.TemporaryDirectory() as d:
        for s in range(6):
            ck.save(d, s, _tree())
        ck.retain(d, keep=2)
        assert ck.list_steps(d) == [4, 5]


def test_resume_equivalence():
    """Train 6 steps straight == train 3, crash, resume, train 3."""
    cfg = configs.get_smoke("phi4-mini-3.8b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    d = DataConfig(batch=2, seq=16)
    batch_fn = lambda i: make_batch(cfg, d, i)

    def fresh_tcfg(ckdir):
        return TrainConfig(
            opt=OptConfig(lr=1e-4, warmup_steps=0), ckpt_dir=ckdir, ckpt_every=3
        )

    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        t_straight = Trainer(api.train_loss, params, fresh_tcfg(d1))
        h1 = t_straight.train(batch_fn, 6)

        t_a = Trainer(api.train_loss, params, fresh_tcfg(d2))
        t_a.train(batch_fn, 3)  # checkpoint lands at step 3
        t_b = Trainer(api.train_loss, params, fresh_tcfg(d2))  # "new node"
        assert t_b.maybe_restore() == 3
        h2 = t_b.train(batch_fn, 3)
        np.testing.assert_allclose(h1["loss"][3:], h2["loss"], rtol=1e-4)


def test_microbatch_equivalence():
    """Accumulated microbatch gradient == full-batch gradient.

    (Compared at the gradient level: the first Adam step normalizes by
    |g| + eps, which amplifies fp-roundoff on near-zero gradient entries.)"""
    cfg = configs.get_smoke("phi4-mini-3.8b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, DataConfig(batch=4, seq=16), 0)

    full_loss, full_grads = jax.value_and_grad(api.train_loss)(params, batch)

    halves = [jax.tree.map(lambda x: x[i * 2 : (i + 1) * 2], batch) for i in (0, 1)]
    accum = None
    losses = []
    for h in halves:
        l, g = jax.value_and_grad(api.train_loss)(params, h)
        losses.append(float(l))
        accum = g if accum is None else jax.tree.map(jnp.add, accum, g)
    accum = jax.tree.map(lambda x: x / 2, accum)

    assert float(full_loss) == pytest.approx(sum(losses) / 2, rel=1e-5)
    scale = max(float(jnp.max(jnp.abs(l))) for l in jax.tree.leaves(full_grads))
    for a, b in zip(jax.tree.leaves(full_grads), jax.tree.leaves(accum)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=1e-5 * max(scale, 1.0), rtol=1e-3,
        )
