"""Trainable quantization state: STE gradients (act clip, TTQ, learned-grid
INQ), optimizer special-casing, mid-schedule resume, and the learned-grid
end-to-end deployment parity proofs (docs/TRAINING.md)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import QuantConfig
from repro.core import ste
from repro.models import build_model, quantize_and_plan, save_servable
from repro.quant import (
    QTensor,
    QuantState,
    dequantize_scales,
    dequantize_weights,
    init_quant_state,
    inq_event_steps,
    quantize_scales,
    quantize_weights,
    ttq_partition,
)
from repro.serving import Request, ServingEngine
from repro.training import OptConfig, TrainConfig, Trainer, init_state
from repro.training import optimizer as opt_lib
from repro.training.data import DataConfig, make_batch

KEY = jax.random.PRNGKey(0)


# -- act_ste -----------------------------------------------------------------
def test_act_ste_static_exponent_clips_gradient():
    """With a calibrated static exponent the clip is real: identity gradient
    inside the representable range, zero outside."""
    from repro.core import dfp

    e = -4
    r = float(dfp.qmax(8) * dfp.exp2i(jnp.asarray(e)))
    x = jnp.asarray([-2 * r, -0.5 * r, 0.0, 0.5 * r, 2 * r], jnp.float32)
    g = jax.grad(lambda x: jnp.sum(ste.act_ste(x, 8, exponent=e)))(x)
    np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 1.0, 1.0, 0.0])
    # dynamic exponent: the range is fit to max|x| every call, so interior
    # values never see the clip
    x2 = jnp.asarray([-1.0, -0.3, 0.0, 0.3, 1.0], jnp.float32)
    gd = jax.grad(lambda x: jnp.sum(ste.act_ste(x, 8)))(x2)
    np.testing.assert_array_equal(np.asarray(gd), np.ones(5))


# -- ternary fmt threading ---------------------------------------------------
def test_ternary_weights_ste_threads_fmt():
    """``fmt`` reaches the registry: the ttq format's threshold-partition
    codes differ from Algorithm-1 codes, and the fake-quant forward must
    match the PTQ grid of the SAME format."""
    w = jax.random.normal(KEY, (32, 8)) * 0.1
    default = ste.ternary_weights_ste(w, 16)
    via_fmt = ste.ternary_weights_ste(w, 16, fmt="ttq")
    assert not np.allclose(np.asarray(default), np.asarray(via_fmt))
    ptq = dequantize_weights(quantize_weights(w, 2, 16, 1, False, fmt="ttq"))
    np.testing.assert_array_equal(np.asarray(via_fmt), np.asarray(ptq))


# -- TTQ STE -----------------------------------------------------------------
def test_ttq_ste_backward_matches_analytic_rule():
    """dWp = sum of output grads over the positive partition, dWn = -sum
    over the negative partition (chained through sign); latent grads are
    scale-amplified on the partitions and identity in the deadzone."""
    g_size = 8
    w = jax.random.normal(KEY, (16, 4)) * 0.1
    wpn = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (2, 2, 4))) + 0.1
    u = jax.random.normal(jax.random.PRNGKey(2), (16, 4))

    dw, dwpn = jax.grad(
        lambda w, s: jnp.sum(ste.ttq_ste(w, s, g_size) * u), argnums=(0, 1)
    )(w, wpn)

    codes = np.asarray(ttq_partition(w, g_size), np.float32)
    pos, neg = (codes > 0), (codes < 0)
    ub = np.asarray(u).reshape(2, g_size, 4)
    dwp_ref = np.sum(ub * pos.reshape(2, g_size, 4), axis=1)
    dwn_ref = -np.sum(ub * neg.reshape(2, g_size, 4), axis=1)
    np.testing.assert_allclose(np.asarray(dwpn[0]), dwp_ref, atol=1e-6)
    np.testing.assert_allclose(np.asarray(dwpn[1]), dwn_ref, atol=1e-6)
    # deadzone latent grad is identity
    dead = ~(pos | neg)
    np.testing.assert_allclose(
        np.asarray(dw)[dead], np.asarray(u)[dead], atol=1e-6
    )


# -- learned-grid INQ STE ----------------------------------------------------
def _grid_from_fit(w, bits, g):
    qt = quantize_weights(w, bits, g, 1, False)
    return dequantize_scales(qt.scale_m, qt.scale_e)


@pytest.mark.parametrize("bits", [2, 4])
def test_inq_ste_matches_deployment_grid(bits):
    """Forward == quantize-then-dequantize on the |s| grid, for the exact
    path ``quantize_params`` deploys through."""
    w = jax.random.normal(KEY, (32, 8)) * 0.1
    s = _grid_from_fit(w, bits, 16) * 1.07  # drift off the fit
    mask = (jnp.abs(w) < 0.03).astype(jnp.float32)
    wq = ste.inq_ste(w, mask, s, bits, 16)
    deq = dequantize_weights(
        quantize_weights(w, bits, 16, 1, False, scales=jnp.abs(s))
    )
    np.testing.assert_array_equal(np.asarray(wq), np.asarray(deq))


def test_inq_ste_gradients():
    """Frozen coords get zero weight grad, live get identity; the scale
    grad is the code-weighted gradient sum over ALL cluster coords."""
    g_size = 8
    w = jax.random.normal(KEY, (16, 4)) * 0.1
    s = _grid_from_fit(w, 2, g_size)
    mask = (jnp.abs(w) < 0.05).astype(jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(3), (16, 4))

    dw, dm, ds = jax.grad(
        lambda w, m, s: jnp.sum(ste.inq_ste(w, m, s, 2, g_size) * u),
        argnums=(0, 1, 2),
    )(w, mask, s)

    np.testing.assert_array_equal(np.asarray(dw * mask), np.zeros((16, 4)))
    np.testing.assert_allclose(
        np.asarray(dw), np.asarray(u * (1 - mask)), atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(dm), np.zeros((16, 4)))
    sq = dequantize_scales(*quantize_scales(jnp.abs(s)))
    codes = np.asarray(ste.inq_ste(w, mask, s, 2, g_size)).reshape(
        2, g_size, 4
    ) / np.where(np.asarray(sq) > 0, np.asarray(sq), 1.0)[:, None, :]
    ds_ref = np.sum(np.asarray(u).reshape(2, g_size, 4) * codes, axis=1)
    np.testing.assert_allclose(np.asarray(ds), ds_ref, atol=1e-5)


# -- optimizer special-casing ------------------------------------------------
def test_scale_leaves_f32_moments_and_no_decay():
    """ttq_scales / inq_scales keep f32 moments under state_bits=8 and are
    excluded from weight decay; inq_mask gets no moments at all."""
    params = {
        "a": {"w": jnp.ones((8, 4)), "ttq_scales": jnp.ones((2, 1, 4))},
        "b": {"w": jnp.ones((8, 4)), "inq_mask": jnp.zeros((8, 4)),
              "inq_scales": jnp.ones((1, 4))},
    }
    cfg = OptConfig(lr=0.0, warmup_steps=0, weight_decay=0.1, state_bits=8)
    state = init_state(params, cfg)
    assert isinstance(state["m"]["a"]["w"], dict)  # DFP-8 entry
    assert isinstance(state["m"]["a"]["ttq_scales"], jnp.ndarray)  # f32
    assert isinstance(state["m"]["b"]["inq_scales"], jnp.ndarray)  # f32
    assert state["m"]["b"]["inq_mask"] is None  # not trainable

    zero_g = jax.tree.map(jnp.zeros_like, params)
    cfg_lr = dataclasses.replace(cfg, lr=0.5)
    new_p, _, _ = opt_lib.apply_updates(params, zero_g, state, cfg_lr)
    # decay moved the weights but not the scale leaves or the mask
    assert float(jnp.max(jnp.abs(new_p["a"]["w"] - 1.0))) > 0
    np.testing.assert_array_equal(np.asarray(new_p["a"]["ttq_scales"]),
                                  np.ones((2, 1, 4)))
    np.testing.assert_array_equal(np.asarray(new_p["b"]["inq_scales"]),
                                  np.ones((1, 4)))
    np.testing.assert_array_equal(np.asarray(new_p["b"]["inq_mask"]),
                                  np.zeros((8, 4)))


@pytest.mark.parametrize("state_bits", [32, 8])
def test_inq_frozen_coords_pinned_through_updates(state_bits):
    """Frozen coordinates are BIT-identical after an optimizer step with
    nonzero gradients AND nonzero weight decay -- neither decay nor moment
    debiasing (nor DFP-8 moment noise) can move them."""
    w = jax.random.normal(KEY, (16, 4))
    mask = (jnp.abs(w) < 0.5).astype(jnp.float32)
    assert 0 < float(mask.sum()) < mask.size
    params = {"site": {"w": w, "inq_mask": mask, "inq_scales": jnp.ones((2, 4))}}
    grads = {
        "site": {
            "w": jnp.ones_like(w),  # nonzero even on frozen coords
            "inq_mask": jnp.zeros_like(mask),
            "inq_scales": jnp.full((2, 4), 0.1),
        }
    }
    cfg = OptConfig(lr=0.1, warmup_steps=0, weight_decay=0.1,
                    state_bits=state_bits)
    state = init_state(params, cfg)
    new_p, _, _ = opt_lib.apply_updates(params, grads, state, cfg)
    frozen = np.asarray(mask) > 0
    np.testing.assert_array_equal(
        np.asarray(new_p["site"]["w"])[frozen], np.asarray(w)[frozen]
    )
    live = ~frozen
    assert np.all(np.asarray(new_p["site"]["w"])[live] != np.asarray(w)[live])
    # the trainable grid moved too
    assert np.all(np.asarray(new_p["site"]["inq_scales"]) != 1.0)


# -- schedule ----------------------------------------------------------------
def test_inq_event_steps_fraction_matched_and_clamped():
    assert inq_event_steps(120, (0.5, 0.75, 0.875, 1.0)) == (60, 90, 105, 119)
    assert inq_event_steps(8, (0.5, 1.0)) == (4, 7)
    assert inq_event_steps(0, (1.0,)) == (0,)


# -- trainer: host syncs, sharding, mid-schedule resume ----------------------
def _tiny_qat(arch="phi4-mini-3.8b", method=None, steps=8, fractions=None):
    qc = QuantConfig(w_bits=2, group_size=16, mode="qat",
                     fmt="ttq" if method == "ttq" else None)
    cfg = configs.get_smoke(arch, qc)
    api = build_model(cfg)
    params = api.init(KEY)
    api = api.compiled(params)
    qs = None
    if method is not None:
        kw = {"fractions": fractions} if fractions else {}
        params, qs = init_quant_state(
            params, api.ctx.plan, method, total_steps=steps, **kw
        )
    return cfg, api, params, qs


def test_train_defers_host_syncs():
    """The loop never materializes per-step metrics: one flush (one host
    sync) for an uncheckpointed run, one per checkpoint interval else."""
    cfg, api, params, _ = _tiny_qat()
    d = DataConfig(batch=2, seq=16)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-4, warmup_steps=0))
    tr = Trainer(api.train_loss, params, tcfg)
    hist = tr.train(lambda i: make_batch(cfg, d, i), 5)
    assert tr.sync_count == 1
    assert len(hist["loss"]) == 5 and hist["step"] == list(range(5))

    tr2 = Trainer(api.train_loss, params, tcfg)
    import tempfile

    with tempfile.TemporaryDirectory() as ckdir:
        tr2.tcfg = dataclasses.replace(tcfg, ckpt_dir=ckdir, ckpt_every=2)
        hist2 = tr2.train(lambda i: make_batch(cfg, d, i), 4)
    assert tr2.sync_count == 2  # one per checkpoint; final flush is empty
    assert len(hist2["loss"]) == 4


def test_trainer_honors_param_shardings_with_state_leaves():
    """Caller shardings cover the plain params; injected state leaves fall
    back to replicated -- the step still compiles and runs."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    cfg, api, params, qs = _tiny_qat(method="inq", steps=4,
                                     fractions=(0.5, 1.0))
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("dp",))
    rep = NamedSharding(mesh, PartitionSpec())
    from repro.quant import strip_quant_state

    shardings = jax.tree.map(lambda _: rep, strip_quant_state(params))
    tr = Trainer(api.train_loss, params, TrainConfig(
        opt=OptConfig(lr=1e-4, warmup_steps=0)),
        mesh=mesh, param_shardings=shardings, plan=api.ctx.plan,
        quant_state=qs)
    for leaf in jax.tree.leaves(tr.params):
        assert isinstance(leaf.sharding, NamedSharding)
    hist = tr.train(lambda i: make_batch(cfg, DataConfig(batch=2, seq=16), i), 4)
    assert len(hist["loss"]) == 4 and np.isfinite(hist["loss"]).all()


def test_inq_mid_schedule_resume_bit_identical(tmp_path):
    """Crash between INQ events, restore, finish == uninterrupted run, bit
    for bit (params, masks, learned grid, and the schedule cursor)."""
    steps, fr = 8, (0.5, 1.0)  # events at 4 and 7
    cfg, api, params, qs = _tiny_qat(method="inq", steps=steps, fractions=fr)
    d = DataConfig(batch=2, seq=16)
    batch_fn = lambda i: make_batch(cfg, d, i)

    def tcfg(ckdir):
        return TrainConfig(opt=OptConfig(lr=1e-4, warmup_steps=0),
                           ckpt_dir=str(ckdir), ckpt_every=4)

    d1, d2 = tmp_path / "straight", tmp_path / "interrupted"
    t_s = Trainer(api.train_loss, params, tcfg(d1), plan=api.ctx.plan,
                  quant_state=qs)
    h1 = t_s.train(batch_fn, steps)
    assert t_s.quant_state.pos == len(fr)  # both events fired

    t_a = Trainer(api.train_loss, params, tcfg(d2), plan=api.ctx.plan,
                  quant_state=qs)
    t_a.train(batch_fn, 4)  # checkpoint lands at step 4, BEFORE event 1
    t_b = Trainer(api.train_loss, params, tcfg(d2), plan=api.ctx.plan)
    assert t_b.maybe_restore() == 4
    assert t_b.quant_state == QuantState("inq", fr, 0, steps)
    h2 = t_b.train(batch_fn, 4)

    np.testing.assert_array_equal(h1["loss"][4:], h2["loss"])
    fa = jax.tree_util.tree_flatten_with_path(t_s.params)[0]
    fb = jax.tree_util.tree_flatten_with_path(t_b.params)[0]
    assert [p for p, _ in fa] == [p for p, _ in fb]
    for (path, la), (_, lb) in zip(fa, fb):
        assert np.array_equal(np.asarray(la), np.asarray(lb)), path


# -- end-to-end deployment parity --------------------------------------------
def _find_state_sites(params, qparams, state_key):
    """(path, state_leaf, master_w, QTensor) tuples at matching paths."""
    out = []

    def walk(a, b, path):
        if isinstance(a, dict):
            if state_key in a and isinstance(b.get("w"), QTensor):
                out.append((path, a[state_key], a["w"], b["w"]))
            for k in a:
                if k in (b or {}):
                    walk(a[k], b[k], f"{path}/{k}" if path else k)

    walk(params, qparams, "")
    assert out, f"no {state_key} site found"
    return out


def _deq_stacked(qt, extra_axes):
    f = dequantize_weights
    for _ in range(extra_axes):
        f = jax.vmap(f)
    return f(qt)


def test_ttq_artifact_deploys_learned_scales_never_refit(tmp_path):
    """The artifact's scale table is quantize_scales(|wpn|) -- the trained
    magnitudes, NOT an Algorithm-1 re-fit -- and its dequantized weights
    equal the last training forward (ttq_ste) bit for bit; a cold-started
    engine serves the same tokens as the in-memory tree."""
    cfg, api, params, _qs = _tiny_qat(arch="qwen3-8b", method="ttq")

    # drift the scales off their init so a silent re-fit cannot pass
    def drift(node):
        if isinstance(node, dict):
            return {k: drift(v) if k != "ttq_scales" else v * 1.1
                    for k, v in node.items()}
        return node

    params = drift(params)
    qparams, plan, qapi = quantize_and_plan(api, params)

    for _path, wpn, w, qt in _find_state_sites(params, qparams, "ttq_scales"):
        wpn2, w2, qt_sm = (np.asarray(wpn), np.asarray(w),
                           np.asarray(qt.scale_m))
        if wpn2.ndim == 4:  # stacked blocks: check layer 0
            wpn2, w2, qt_sm = wpn2[0], w2[0], qt_sm[0]
        g2, n = wpn2.shape[1], wpn2.shape[2]
        oracle_m, _ = quantize_scales(
            jnp.abs(jnp.asarray(wpn2)).reshape(2 * g2, n)
        )
        np.testing.assert_array_equal(qt_sm, np.asarray(oracle_m))
        refit = quantize_weights(
            jnp.asarray(w2, jnp.float32), 2, w2.shape[0] // g2, 1, False,
            fmt="ttq",
        )
        assert not np.array_equal(qt_sm, np.asarray(refit.scale_m))

    # dequantized artifact weights == the ttq_ste training forward
    _path, wpn, w, qt = _find_state_sites(params, qparams, "ttq_scales")[0]
    g_size = w.shape[-2] // wpn.shape[-2]
    fwd = ste.ttq_ste
    for _ in range(w.ndim - 2):
        fwd = jax.vmap(fwd, in_axes=(0, 0, None))
    np.testing.assert_array_equal(
        np.asarray(_deq_stacked(qt, w.ndim - 2)),
        np.asarray(fwd(w.astype(jnp.float32), wpn, g_size)),
    )

    save_servable(str(tmp_path), qapi, qparams, plan)

    def tokens(eng):
        eng.submit(Request(uid=0, prompt=[5, 9, 2], max_new_tokens=4))
        return eng.run()[0].output

    warm = tokens(ServingEngine(qapi, qparams, n_slots=2, max_len=16))
    cold = tokens(ServingEngine.from_artifact(str(tmp_path), n_slots=2,
                                              max_len=16))
    assert warm == cold


def test_inq_artifact_matches_training_forward():
    """After events + scale drift, quantize_params deploys on the learned
    grid: dequantized artifact weights == the inq_ste training forward."""
    from repro.quant import advance_inq

    cfg, api, params, qs = _tiny_qat(arch="qwen3-8b", method="inq", steps=4,
                                     fractions=(0.5, 1.0))
    params = advance_inq(params, api.ctx.plan, 0.5)

    def drift(node):
        if isinstance(node, dict):
            return {k: drift(v) if k != "inq_scales" else v * 1.05
                    for k, v in node.items()}
        return node

    params = drift(params)
    qparams, plan, _qapi = quantize_and_plan(api, params)
    for path, s, w, qt in _find_state_sites(params, qparams, "inq_scales"):
        prec = plan.resolve(path)  # paper overrides keep some sites at 8b
        mask = jnp.zeros(w.shape, jnp.float32)  # mask is forward-irrelevant
        fwd = ste.inq_ste
        for _ in range(w.ndim - 2):
            fwd = jax.vmap(fwd, in_axes=(0, 0, 0, None, None))
        np.testing.assert_array_equal(
            np.asarray(_deq_stacked(qt, w.ndim - 2)),
            np.asarray(
                fwd(w.astype(jnp.float32), mask, s, prec.w_bits,
                    prec.group_size)
            ),
            err_msg=path,
        )
