"""Flash-decode kernel vs the XLA fold-the-scales oracle.

Parity matrix: {kv_bf16, kv_int8, kv_mx} x {aligned slice write, per-slot
masked write} x {GQA, MHA, sliding-window}.  Both paths read the SAME cache
(written through the registered format), so format quantization error
cancels and the comparison isolates the kernel's online-softmax math; only
float sum-order differences remain (atol 5e-5).

Plus: model-level routing (``cfg.flash_decode`` toggles the kernel under a
real transformer decode_step, logits must agree) and block-size selection.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels.flash_decode import flash_decode, pick_kv_block
from repro.models import build_model, kv_cache
from repro.models.attention import _attend_dense, _mask_bias

FORMATS = ("kv_bf16", "kv_int8", "kv_mx")


class _Cfg:
    kv_bits = 16

    def __init__(self, kh, hd, fmt):
        self.n_kv_heads = kh
        self.kv_fmt = fmt
        self._hd = hd

    def hd(self):
        return self._hd


def _filled_cache(fmt, b, t, kh, hd, mode, seed=0):
    """A cache with real history plus a final write in ``mode``.

    aligned: 24 tokens at [0, 24) via the traced-scalar slice write.
    masked:  the same, then one per-slot token at positions [24, 9, ...]
             (continuous batching: every row decodes at its own offset).
    Returns (cache, q_pos (B,), valid (B,)).
    """
    rng = np.random.default_rng(seed)
    hist = 24
    k = jnp.asarray(rng.normal(size=(b, hist, kh, hd)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hist, kh, hd)) * 0.5, jnp.float32)
    cache = kv_cache.init_cache(_Cfg(kh, hd, fmt), (b,), t)
    cache, valid = kv_cache.write(fmt, cache, k, v, jnp.int32(0))
    if mode == "masked":
        pos = jnp.asarray([(24 + 7 * i) % (t - 1) for i in range(b)], jnp.int32)
        k1 = jnp.asarray(rng.normal(size=(b, 1, kh, hd)) * 0.5, jnp.float32)
        v1 = jnp.asarray(rng.normal(size=(b, 1, kh, hd)) * 0.5, jnp.float32)
        cache, valid = kv_cache.write(fmt, cache, k1, v1, pos)
    q_pos = valid - 1
    return cache, q_pos, valid


def _oracle(q, cache, fmt, q_pos, valid, window):
    b, kh, g, hd = q.shape
    t = cache["k"].shape[1]
    ck, cv, ks, vs = kv_cache.attend_view(fmt, cache)
    bias = _mask_bias(q_pos[:, None], jnp.arange(t), True, window, valid)
    out = _attend_dense(
        q.reshape(b, 1, kh, g, hd), ck, cv, bias[:, None, None],
        kscale=ks, vscale=vs,
    )
    return out.reshape(b, kh, g, hd)


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("mode", ["aligned", "masked"])
@pytest.mark.parametrize(
    "kh,g,window", [(2, 2, None), (4, 1, None), (2, 2, 8)],
    ids=["gqa", "mha", "window"],
)
def test_flash_decode_parity(fmt, mode, kh, g, window):
    b, hd, t = 3, 16, 64
    cache, q_pos, valid = _filled_cache(fmt, b, t, kh, hd, mode)
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(b, kh, g, hd)), jnp.float32)
    got = flash_decode(
        q, cache["k"], cache["v"], cache.get("ke"), cache.get("ve"),
        q_pos.reshape(b, 1).astype(jnp.int32),
        valid.reshape(b, 1).astype(jnp.int32),
        jnp.asarray(2**30 if window is None else window, jnp.int32).reshape(1, 1),
        fmt=fmt, block_k=32, interpret=True,
    )
    want = _oracle(q, cache, fmt, q_pos, valid, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)


@pytest.mark.parametrize("fmt", FORMATS)
def test_flash_decode_small_kv_block(fmt):
    """KV tile smaller than the history (multiple grid steps per head)."""
    b, kh, g, hd, t = 2, 2, 2, 8, 128
    cache, q_pos, valid = _filled_cache(fmt, b, t, kh, hd, "aligned")
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(b, kh, g, hd)), jnp.float32)
    bk = 32 if fmt == "kv_mx" else 16
    got = flash_decode(
        q, cache["k"], cache["v"], cache.get("ke"), cache.get("ve"),
        q_pos.reshape(b, 1).astype(jnp.int32),
        valid.reshape(b, 1).astype(jnp.int32),
        jnp.full((1, 1), 2**30, jnp.int32), fmt=fmt, block_k=bk,
        interpret=True,
    )
    want = _oracle(q, cache, fmt, q_pos, valid, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)


def test_pick_kv_block():
    assert pick_kv_block(256, "kv_bf16") == 128
    assert pick_kv_block(96, "kv_int8") == 96
    assert pick_kv_block(48, "kv_bf16", want=32) == 24
    # mx blocks stay 32-token aligned
    assert pick_kv_block(64, "kv_mx") == 64
    assert pick_kv_block(96, "kv_mx", want=64) == 32
    assert pick_kv_block(256, "kv_mx") == 128


@pytest.mark.parametrize("fmt", FORMATS)
def test_model_level_flash_routing(fmt):
    """cfg.flash_decode toggles the kernel under a real decode_step; the
    logits must match the oracle path on the SAME cache state."""
    base = configs.get_smoke("gemma3-12b")  # sliding-window + GQA coverage
    outs = {}
    for flash in (False, True):
        cfg = dataclasses.replace(base, kv_fmt=fmt, flash_decode=flash)
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        cache = api.init_cache(2, 64)
        batch = {"tokens": jnp.arange(16, dtype=jnp.int32).reshape(2, 8) % cfg.vocab}
        _, cache = api.prefill(params, batch, cache)
        logits = None
        for i in range(8, 12):
            logits, cache = api.decode(
                params, jnp.full((2, 1), 3, jnp.int32), jnp.int32(i), cache
            )
        outs[flash] = np.asarray(logits)
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-4)


@pytest.mark.parametrize("fmt", ["kv_int8", "kv_mx"])
def test_quantized_formats_track_bf16(fmt):
    """Quantized caches approximate the bf16 attention output (accuracy,
    not parity): int8 tight, mx within 4-bit block-quantization error."""
    b, kh, g, hd, t = 2, 2, 2, 16, 64
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(b, kh, g, hd)), jnp.float32)
    ref_cache, q_pos, valid = _filled_cache("kv_bf16", b, t, kh, hd, "aligned")
    cache, _, _ = _filled_cache(fmt, b, t, kh, hd, "aligned")
    want = _oracle(q, ref_cache, "kv_bf16", q_pos, valid, None)
    got = _oracle(q, cache, fmt, q_pos, valid, None)
    atol = 0.02 if fmt == "kv_int8" else 0.2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=atol)
