"""Smoke the multi-pod dry-run machinery end-to-end (subprocess: it must set
XLA_FLAGS before jax initializes, which cannot happen inside this process)."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=420):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )


@pytest.mark.slow
def test_dryrun_single_cell_single_pod():
    with tempfile.NamedTemporaryFile(suffix=".json") as f:
        r = _run(["--arch", "whisper-base", "--shape", "decode_32k", "--json", f.name])
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
        rows = json.load(open(f.name))
        assert rows[0]["status"] == "ok"
        assert rows[0]["mesh"] == "16x16"
        assert rows[0]["per_device"]["flops"] > 0
        assert rows[0]["roofline_s"]["dominant"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_dryrun_single_cell_multi_pod():
    with tempfile.NamedTemporaryFile(suffix=".json") as f:
        r = _run(
            ["--arch", "gemma3-12b", "--shape", "long_500k", "--multi-pod",
             "--json", f.name]
        )
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
        rows = json.load(open(f.name))
        assert rows[0]["status"] == "ok"
        assert rows[0]["mesh"] == "2x16x16"


def test_dryrun_skip_rule():
    """Pure full-attention archs skip long_500k without touching jax."""
    r = _run(["--arch", "qwen3-8b", "--shape", "long_500k"], timeout=120)
    assert r.returncode == 0
    assert "skipped" in r.stdout
