"""Staged engine: scheduler units, staged-vs-lockstep token parity, queue
discipline, drain/leftover, slot-state hygiene, SLO stats."""
import dataclasses

import jax
import pytest

from repro import configs
from repro.models import build_model
from repro.serving import (
    Request,
    SchedulerConfig,
    ServingEngine,
    StagedEngine,
    chunk_plan,
    next_action,
)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# scheduler units (no device work)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "n,chunk",
    [(1, 8), (7, 8), (8, 8), (9, 8), (31, 8), (64, 8), (13, 32), (5, 1)],
)
def test_chunk_plan_boundaries(n, chunk):
    sizes = chunk_plan(n, chunk)
    assert sum(sizes) == n
    assert all(1 <= s <= chunk for s in sizes)
    # remainder tail is strictly-descending powers of two -> the compiled
    # shape set is {chunk} U {2^i < chunk}, O(log chunk) total
    tail = [s for s in sizes if s != chunk]
    assert tail == sorted(tail, reverse=True)
    assert all(s & (s - 1) == 0 for s in tail)


def test_chunk_plan_rejects_empty():
    with pytest.raises(ValueError, match="at least one"):
        chunk_plan(0, 8)


def test_scheduler_config_validation():
    with pytest.raises(ValueError, match="prefill_chunk"):
        SchedulerConfig(prefill_chunk=0)
    with pytest.raises(ValueError, match="policy"):
        SchedulerConfig(policy="fifo")
    assert SchedulerConfig().policy == "decode"


def test_next_action_policies():
    for policy in ("decode", "prefill"):
        assert next_action(policy, prefill_ready=False, decode_ready=False,
                           last="generate") == "idle"
        assert next_action(policy, prefill_ready=True, decode_ready=False,
                           last="generate") == "prefill"
        assert next_action(policy, prefill_ready=False, decode_ready=True,
                           last="prefill") == "generate"
    # contention: prefill-priority drains prefill; decode-priority strictly
    # alternates so neither stage starves
    assert next_action("prefill", prefill_ready=True, decode_ready=True,
                       last="prefill") == "prefill"
    assert next_action("decode", prefill_ready=True, decode_ready=True,
                       last="generate") == "prefill"
    assert next_action("decode", prefill_ready=True, decode_ready=True,
                       last="prefill") == "generate"


# ---------------------------------------------------------------------------
# staged-vs-lockstep token parity (greedy oracle)
# ---------------------------------------------------------------------------
def _run(api, params, cls, prompts, max_new=4, n_slots=2, max_len=64, **kw):
    eng = cls(api, params, n_slots=n_slots, max_len=max_len, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=max_new))
    done = eng.run(max_ticks=4000)
    left = eng.leftover()
    assert not left["in_flight"] and not left["queued"]
    return {r.uid: r.output for r in done}, eng


def test_staged_matches_lockstep_transformer():
    """Boundary prompt lengths (1, chunk-1, chunk, chunk+1, max_len-1)
    through both policies produce bit-identical greedy tokens."""
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    chunk, max_len = 8, 32
    lens = [1, chunk - 1, chunk, chunk + 1, max_len - 1]
    prompts = [[(3 * j + i) % 50 + 1 for j in range(n)] for i, n in enumerate(lens)]
    lock, _ = _run(api, params, ServingEngine, prompts, max_len=max_len)
    for policy in ("decode", "prefill"):
        stag, eng = _run(
            api, params, StagedEngine, prompts, max_len=max_len,
            sched=SchedulerConfig(prefill_chunk=chunk, policy=policy),
        )
        assert stag == lock, f"policy={policy}"
        assert eng.counts["inserts"] == len(prompts)


def test_staged_matches_lockstep_moe():
    """MoE parity needs drop-free capacity: expert drops depend on which
    tokens share a dispatch, and staged prefill batches tokens differently
    from the lockstep tick."""
    cfg = dataclasses.replace(configs.get_smoke("grok-1-314b"), capacity_factor=8.0)
    api = build_model(cfg)
    params = api.init(KEY)
    prompts = [[5, 9, 2, 7, 11], [3, 1], [8] * 9]
    lock, _ = _run(api, params, ServingEngine, prompts, max_len=32)
    stag, _ = _run(api, params, StagedEngine, prompts, max_len=32,
                   sched=SchedulerConfig(prefill_chunk=4))
    assert stag == lock


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-7b"])
def test_staged_fallback_families(arch):
    """Recurrent families have no chunk graph; the budgeted per-token
    fallback prefill must still match the lockstep oracle."""
    cfg = configs.get_smoke(arch)
    api = build_model(cfg)
    params = api.init(KEY)
    assert api.prefill_chunk is None and api.insert is not None
    prompts = [[5, 9, 2, 7, 11], [3, 1]]
    lock, _ = _run(api, params, ServingEngine, prompts, max_len=32)
    stag, _ = _run(api, params, StagedEngine, prompts, max_len=32,
                   sched=SchedulerConfig(prefill_chunk=4))
    assert stag == lock


# ---------------------------------------------------------------------------
# queue discipline / drain / slot hygiene / stats
# ---------------------------------------------------------------------------
def test_queue_discipline_under_backlog():
    """More requests than slots: FIFO admission, everyone completes, later
    submissions record later admission ticks."""
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    eng = StagedEngine(api, params, n_slots=1, max_len=16,
                       sched=SchedulerConfig(prefill_chunk=4))
    for i in range(4):
        eng.submit(Request(uid=i, prompt=[i + 1, 2, 3], max_new_tokens=2))
    done = eng.run(max_ticks=500)
    assert sorted(r.uid for r in done) == [0, 1, 2, 3]
    ticks = [r.admitted_tick for r in sorted(done, key=lambda r: r.uid)]
    assert ticks == sorted(ticks)  # FIFO: uid order == admission order
    assert all(len(r.output) == 2 for r in done)


def test_run_budget_reports_leftover_then_drains():
    """A tick budget too small to finish does NOT silently discard work:
    leftover() names every in-flight/queued request, drain() hands them
    back and leaves a reusable engine."""
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    eng = StagedEngine(api, params, n_slots=1, max_len=32,
                       sched=SchedulerConfig(prefill_chunk=4))
    for i in range(3):
        eng.submit(Request(uid=i, prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=8))
    done = eng.run(max_ticks=2)  # enough for part of request 0's prefill
    left = eng.leftover()
    accounted = {r.uid for r in done} | {r.uid for r in left["in_flight"]} \
        | {r.uid for r in left["queued"]}
    assert accounted == {0, 1, 2}
    assert all(not r.done for r in left["in_flight"] + left["queued"])

    drained = eng.drain()
    assert {r.uid for r in drained["in_flight"]} == {r.uid for r in left["in_flight"]}
    assert eng.leftover() == {"in_flight": [], "queued": []}
    # drained engine is reusable and produces clean output
    eng.submit(Request(uid=9, prompt=[5, 9, 2], max_new_tokens=2))
    redo = eng.run(max_ticks=200)
    assert len(redo) == 1 and redo[0].uid == 9 and len(redo[0].output) == 2

    fresh = StagedEngine(api, params, n_slots=1, max_len=32,
                         sched=SchedulerConfig(prefill_chunk=4))
    fresh.submit(Request(uid=9, prompt=[5, 9, 2], max_new_tokens=2))
    assert fresh.run(max_ticks=200)[0].output == redo[0].output


def test_slot_state_reset_on_completion():
    """Completion returns the slot to the canonical idle state -- no stale
    next_token/slot_cursor/slot_pos for the next occupant to inherit."""
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    for cls, kw in [(ServingEngine, {}),
                    (StagedEngine, {"sched": SchedulerConfig(prefill_chunk=4)})]:
        eng = cls(api, params, n_slots=2, max_len=16, **kw)
        eng.submit(Request(uid=0, prompt=[5, 9, 2], max_new_tokens=2))
        eng.run(max_ticks=200)
        assert eng.slot_req == [None, None]
        assert eng.slot_pos.tolist() == [0, 0]
        assert eng.slot_cursor.tolist() == [0, 0]
        assert eng.next_token.tolist() == [0, 0]


def test_staged_stats_slo_fields():
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    eng = StagedEngine(api, params, n_slots=2, max_len=32,
                       sched=SchedulerConfig(prefill_chunk=4, policy="prefill"))
    for i in range(2):
        eng.submit(Request(uid=i, prompt=[1, 2, 3, 4, 5], max_new_tokens=3))
    eng.run(max_ticks=500)
    s = eng.stats()
    assert s["engine"] == "staged" and s["policy"] == "prefill"
    assert s["prefill_chunk"] == 4
    assert s["counts"]["inserts"] == 2 and s["counts"]["generate_ticks"] > 0
    lat = s["latency"]
    for field in ("queue_wait", "ttft", "tpot"):
        assert lat[field] is not None and lat[field]["n"] == 2
        assert lat[field]["p50"] <= lat[field]["p95"] <= lat[field]["p99"]


def test_decode_policy_alternates_under_contention():
    """With a running request and a backlog, decode-priority never runs two
    prefill chunks back-to-back; prefill-priority drains the whole prompt."""
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)

    def trace(policy):
        eng = StagedEngine(api, params, n_slots=2, max_len=64,
                           sched=SchedulerConfig(prefill_chunk=4, policy=policy))
        eng.submit(Request(uid=0, prompt=[7, 7], max_new_tokens=30))
        for _ in range(3):  # request 0 prefilled + generating
            eng.step()
        eng.submit(Request(uid=1, prompt=[1] * 16, max_new_tokens=2))
        actions = []
        for _ in range(8):
            eng.step()
            actions.append(eng._last_action)
        return actions

    acts = trace("decode")
    assert "prefill" in acts and "generate" in acts
    assert not any(a == b == "prefill" for a, b in zip(acts, acts[1:]))
    acts = trace("prefill")
    assert acts[:4] == ["prefill"] * 4  # 16-token prompt = 4 chunks, drained first


def test_staged_requires_insert():
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    api_no_insert = dataclasses.replace(api, insert=None)
    with pytest.raises(ValueError, match="insert"):
        StagedEngine(api_no_insert, params, n_slots=1, max_len=16)
