"""Fused qdense pipeline: bit parity vs the ref oracle, ragged-batch trace
bucketing, the per-site ``fused`` plan knob, and device-resident serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import QuantConfig
from repro.core import dfp
from repro.models import build_model, load_servable, quantize_and_plan, save_servable
from repro.quant import LayerPrecision, PrecisionPolicy, qdense, qmatmul, quantize_weights
from repro.quant.backends import has_fused_backend
from repro.serving import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


# every registered weight encoding the fused pipeline must be bit-exact on:
# the paper's three plus the two sub-8-bit block formats (nf4 shares int4's
# width, mx shares int8's -- the registry collision case).  Bit widths come
# from the registry itself so this table can never drift from the formats.
from repro.quant import get_format

FMTS = ("ternary", "int4", "int8", "nf4", "mx")
_FMT_BITS = {f: get_format(f).bits for f in FMTS}


def _site(m, k, n, g, fmt, seed=0, bias=False):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n,)), jnp.float32) if bias else None
    fmt = {2: "ternary", 4: "int4", 8: "int8"}.get(fmt, fmt)
    return x, quantize_weights(w, _FMT_BITS[fmt], g, fmt=fmt), b


# ---------------------------------------------------------------------------
# exp2i: the exact power-of-two scale the whole DFP pipeline now rides on.
# ---------------------------------------------------------------------------
def test_exp2i_exact_powers_of_two():
    e = jnp.arange(-126, 128, dtype=jnp.int32)
    got = np.asarray(dfp.exp2i(e))
    want = np.ldexp(np.float32(1.0), np.arange(-126, 128))
    assert (got == want.astype(np.float32)).all()
    # integer-valued float exponents are accepted (kernel scratch is f32)
    assert float(dfp.exp2i(jnp.float32(-20.0))) == 2.0**-20


# ---------------------------------------------------------------------------
# Fused kernel vs the ref oracle: bit-identical in interpret mode.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("static_e", [None, -4])
@pytest.mark.parametrize("bias", [False, True])
@pytest.mark.parametrize("act", [None, "silu"])
def test_qdense_fused_bit_exact_vs_ref(fmt, static_e, bias, act):
    # m=7 exercises the bucket padding; block_k=32 < K exercises the
    # multi-k-step accumulation + last-step epilogue (mx pins its own
    # 32-block, so block_k=32 also means one cluster per k-step there)
    x, qt, b = _site(7, 64, 32, 16, fmt, seed=_FMT_BITS[fmt], bias=bias)
    got = qdense(
        x, qt, bias=b, act=act, backend="pallas",
        act_exponent=static_e, block_k=32,
    )
    want = qdense(
        x, qt, bias=b, act=act, backend="ref",
        act_exponent=static_e, block_k=32,
    )
    assert np.array_equal(np.asarray(got), np.asarray(want)), (
        f"fused/{fmt} static={static_e} bias={bias} act={act}"
    )


def test_qdense_batched_leading_dims_and_bf16():
    x, qt, _ = _site(12, 64, 16, 16, 2, seed=9)
    xb = x.reshape(3, 4, 64).astype(jnp.bfloat16)
    got = qdense(xb, qt, backend="pallas")
    want = qdense(xb, qt, backend="ref")
    assert got.shape == (3, 4, 16)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_qdense_unfused_backends_match_composition():
    """fused=False composes quantize + backend + epilogue; for the pallas
    backend that must equal the fused kernel exactly."""
    x, qt, b = _site(8, 64, 32, 16, 4, seed=3, bias=True)
    fused = qdense(x, qt, bias=b, act="silu", backend="pallas", block_k=32)
    unfused = qdense(
        x, qt, bias=b, act="silu", backend="pallas", fused=False, block_k=32
    )
    assert np.array_equal(np.asarray(fused), np.asarray(unfused))


def test_qdense_matches_qmatmul_plus_epilogue():
    x, qt, b = _site(8, 64, 32, 16, 2, seed=5, bias=True)
    via_qdense = qdense(x, qt, bias=b, backend="ref")
    via_qmatmul = qmatmul(x, qt, backend="ref") + b
    assert np.array_equal(np.asarray(via_qdense), np.asarray(via_qmatmul))


def test_has_fused_backend_registry():
    assert has_fused_backend("pallas")
    assert not has_fused_backend("xla")  # falls back to the composition


def test_format_without_fused_kernel_falls_back_unfused():
    """A format registered without a fused_kernel (the register_format
    default, incl. pre-existing third-party formats) must serve through the
    unfused pipeline, not raise."""
    from repro.kernels.ternary_matmul import ternary_matmul
    from repro.quant import register_format
    from repro.quant.formats import _ternary_weight_codes, get_format
    from repro.quant.qtensor import pack2, unpack2

    register_format(
        "ternary_nofuse_test", bits=2, encode=pack2, decode=unpack2,
        weight_codes=_ternary_weight_codes, kernel=ternary_matmul,
        overwrite=True,
    )
    assert get_format("ternary_nofuse_test").fused_kernel is None
    x, qt, _ = _site(8, 64, 32, 16, 2, seed=4)
    qt = dataclasses.replace(qt, fmt="ternary_nofuse_test")
    got = qdense(x, qt, backend="pallas")  # fused=True default
    want = qdense(x, qt, backend="ref")
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", FMTS)
def test_fused_site_materializes_one_full_tensor(bits):
    """The fused dense site is ONE kernel: its jaxpr has exactly one
    equation producing a full-size tensor (the pallas_call), while the
    unfused path stages int8 mantissas + raw output + epilogue through
    separate equations (HBM-visible buffers at kernel boundaries)."""
    x, qt, _ = _site(8, 64, 64, 16, bits)

    def passes(fn):
        jaxpr = jax.make_jaxpr(fn)(x)
        return sum(
            1
            for eqn in jaxpr.jaxpr.eqns
            if eqn.primitive.name not in ("reshape", "broadcast_in_dim")
            and any(
                int(np.prod(v.aval.shape or (1,))) >= 8 * 64 for v in eqn.outvars
            )
        )

    fused = passes(lambda a: qdense(a, qt, backend="pallas"))
    unfused = passes(lambda a: qdense(a, qt, backend="pallas", fused=False))
    assert fused == 1
    assert unfused > fused


# ---------------------------------------------------------------------------
# Ragged serving batches: power-of-two buckets, no per-size recompiles.
# ---------------------------------------------------------------------------
def test_ragged_batch_sizes_share_kernel_traces():
    from repro.kernels.ternary_matmul import ternary_matmul, ternary_matmul_fused

    # dims unique to this test: the kernel jit caches are process-global, so
    # shapes shared with other tests would pre-warm the bucket and skew the
    # trace counts
    k, n = 96, 48
    _, qt, _ = _site(8, k, n, 16, 2)
    base = ternary_matmul._cache_size()
    for m in (1, 3, 5, 7, 8, 6, 2):  # all bucket to M=8
        qmatmul(jnp.ones((m, k), jnp.float32), qt, backend="pallas")
    assert ternary_matmul._cache_size() == base + 1, "one trace per bucket"
    for m in (9, 12, 16):  # all bucket to M=16
        qmatmul(jnp.ones((m, k), jnp.float32), qt, backend="pallas")
    assert ternary_matmul._cache_size() == base + 2

    fbase = ternary_matmul_fused._cache_size()
    for m in (1, 3, 5, 7, 8):
        qdense(jnp.ones((m, k), jnp.float32), qt, backend="pallas")
    assert ternary_matmul_fused._cache_size() == fbase + 1


def test_quantize_rows_ragged_m():
    """The standalone quantize kernel accepts any M (pick_block fallback)."""
    from repro.kernels.quantize import quantize_rows
    from repro.kernels.ref import quantize_rows_ref

    x = jnp.asarray(np.random.default_rng(2).normal(size=(7, 32)), jnp.float32)
    q, e = quantize_rows(x, interpret=True)
    qr, er = quantize_rows_ref(x, 8)
    assert np.array_equal(np.asarray(q), np.asarray(qr))
    assert np.array_equal(np.asarray(e), np.asarray(er))


# ---------------------------------------------------------------------------
# The per-site ``fused`` plan knob.
# ---------------------------------------------------------------------------
def test_plan_fused_knob_roundtrips_and_routes(monkeypatch):
    from repro.quant import backends as backends_mod
    from repro.quant.plan import compile_policy

    pol = PrecisionPolicy(
        default=LayerPrecision(w_bits=2, group_size=16),
        overrides=(("pinned", LayerPrecision(w_bits=2, group_size=16, fused=False)),),
    )
    params = {
        "pinned": {"w": jnp.zeros((32, 16))},
        "free": {"w": jnp.zeros((32, 16))},
    }
    plan = compile_policy(pol, params)
    assert plan.resolve("pinned").fused is False
    assert plan.resolve("free").fused is True
    # the knob survives JSON (old plans without it default to fused=True)
    plan2 = type(plan).from_json(plan.to_json())
    assert plan2.resolve("pinned").fused is False

    # dense() actually honors it: fused=False must never hit the fused path
    from repro.models.layers import dense
    from repro.quant.plan import QuantCtx

    calls = []
    real = backends_mod._FUSED_BACKENDS["pallas"]
    monkeypatch.setitem(
        backends_mod._FUSED_BACKENDS, "pallas",
        lambda *a, **k: (calls.append(1), real(*a, **k))[1],
    )
    qt = quantize_weights(jnp.asarray(np.ones((32, 16)), jnp.float32), 2, 16)
    ctx = QuantCtx(mode="ptq", backend="pallas", plan=plan)
    x = jnp.ones((4, 32), jnp.float32)
    dense({"w": qt}, x, "pinned", ctx)
    assert not calls, "fused=False site must use the unfused pipeline"
    dense({"w": qt}, x, "free", ctx)
    assert calls, "fused=True site must use the fused kernel"


# ---------------------------------------------------------------------------
# Device-resident serving: donation, single dispatch, fused decode parity.
# ---------------------------------------------------------------------------
def _engine_tokens(api, params, prompt=(5, 9, 2), n=4, slots=2):
    eng = ServingEngine(api, params, n_slots=slots, max_len=16)
    eng.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=n))
    return eng.run()[0].output


def test_step_donates_cache_and_syncs_once():
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    eng = ServingEngine(api, params, n_slots=2, max_len=16)
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=2))
    eng.step()  # compile tick
    old_cache_leaves = jax.tree.leaves(eng.cache)
    calls = []
    real = eng._decode_step
    eng._decode_step = lambda *a: (calls.append(1), real(*a))[1]
    eng.step()
    assert len(calls) == 1, "one jitted dispatch per tick"
    # donated operand: the old cache buffers were consumed in place
    assert all(leaf.is_deleted() for leaf in old_cache_leaves)


def test_step_runs_under_d2h_transfer_guard():
    """The dispatch runs with device->host transfers disallowed (on real
    accelerators a stray readback inside the tick raises; on CPU the guard
    is vacuous, so assert the setting itself is active during the call)."""
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    params = api.init(KEY)
    eng = ServingEngine(api, params, n_slots=1, max_len=16)

    seen = {}
    real = eng._decode_step

    def spying(*a):
        seen["guard"] = jax.config.jax_transfer_guard_device_to_host
        return real(*a)

    eng._decode_step = spying
    eng.submit(Request(uid=0, prompt=[1], max_new_tokens=1))
    eng.step()
    assert seen["guard"] == "disallow"


@pytest.mark.parametrize("fmt", FMTS)
def test_fused_engine_matches_artifact_path_tokens(fmt, tmp_path):
    """Serving through the fused pallas decode emits tokens bit-identical to
    the PR-2 artifact path served through the ref oracle -- for every
    registered format, the new block formats included (cold-start from the
    packed artifact, so this is also their save/load decode-parity cell)."""
    cfg = configs.get_smoke(
        "qwen3-8b",
        QuantConfig(
            w_bits=_FMT_BITS[fmt], group_size=16, mode="ptq", backend="xla",
            fmt=fmt if fmt in ("nf4", "mx") else None,
        ),
    )
    api = build_model(cfg)
    params = api.init(KEY)
    qparams, plan, qapi = quantize_and_plan(api, params)
    save_servable(str(tmp_path), qapi, qparams, plan)
    cold_api, cold_params, _ = load_servable(str(tmp_path))
    cold_plan = cold_api.ctx.plan

    ref_api = cold_api.with_plan(dataclasses.replace(cold_plan, backend="ref"))
    fused_api = cold_api.with_plan(dataclasses.replace(cold_plan, backend="pallas"))
    ref_toks = _engine_tokens(ref_api, cold_params)
    fused_toks = _engine_tokens(fused_api, cold_params)
    assert fused_toks == ref_toks
