"""Unit tests for the loop-expanded HLO cost model."""
import pytest

from repro.roofline.hlo_cost import HloCostModel, loop_expanded_cost

HLO = """
HloModule test

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %w = f32[128,128]{1,0} constant({...})
  %y = f32[8,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,128]{1,0} all-reduce(%y), to_apply=%sum
  ROOT %t = (s32[], f32[8,128]) tuple(%ni, %ar)
}

%cond (pc: (s32[], f32[8,128])) -> pred[] {
  %pc = (s32[], f32[8,128]) parameter(0)
  %ic = s32[] get-tuple-element(%pc), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%ic, %n), direction=LT
}

ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %a = f32[8,128]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,128]) tuple(%z, %a)
  %loop = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,128]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_while_trip_count_expansion():
    c = loop_expanded_cost(HLO)
    # dot: 2 * 8*128 * 128 flops, x10 trips
    assert c.flops == pytest.approx(10 * 2 * 8 * 128 * 128, rel=0.01)
    # all-reduce bytes: 8*128*4 x10
    assert c.coll["all-reduce"] == pytest.approx(10 * 8 * 128 * 4)


def test_fused_slice_counts_region():
    hlo = """
HloModule t

%fused (fp0: f32[64,128,128], fp1: s32[]) -> f32[128,128] {
  %fp0 = f32[64,128,128]{2,1,0} parameter(0)
  %fp1 = s32[] parameter(1)
  %z = s32[] constant(0)
  ROOT %ds = f32[128,128]{1,0} dynamic-slice(%fp0, %fp1, %z, %z), dynamic_slice_sizes={1,128,128}
}

ENTRY %main (w: f32[64,128,128], i: s32[]) -> f32[128,128] {
  %w = f32[64,128,128]{2,1,0} parameter(0)
  %i = s32[] parameter(1)
  ROOT %f = f32[128,128]{1,0} fusion(%w, %i), kind=kLoop, calls=%fused
}
"""
    c = loop_expanded_cost(hlo)
    # operand read at REGION size (one 128x128 slice), not the 64-layer stack
    assert c.bytes < 3 * 128 * 128 * 4 + 64


def test_standalone_slice_region():
    hlo = """
HloModule t

ENTRY %main (w: f32[64,1024]) -> f32[1,1024] {
  %w = f32[64,1024]{1,0} parameter(0)
  %z = s32[] constant(3)
  %z0 = s32[] constant(0)
  ROOT %s = f32[1,1024]{1,0} dynamic-slice(%w, %z, %z0), dynamic_slice_sizes={1,1024}
}
"""
    c = loop_expanded_cost(hlo)
    assert c.bytes == pytest.approx(2 * 1024 * 4)


def test_conditional_takes_max_branch():
    hlo = """
HloModule t

%big (q: f32[256,256]) -> f32[256,256] {
  %q = f32[256,256]{1,0} parameter(0)
  ROOT %m = f32[256,256]{1,0} multiply(%q, %q)
}

%small (r: f32[256,256]) -> f32[256,256] {
  %r = f32[256,256]{1,0} parameter(0)
  ROOT %n = f32[256,256]{1,0} copy(%r)
}

ENTRY %main (p: pred[], x: f32[256,256]) -> f32[256,256] {
  %p = pred[] parameter(0)
  %x = f32[256,256]{1,0} parameter(1)
  ROOT %c = f32[256,256]{1,0} conditional(%p, %x, %x), branch_computations={%big, %small}
}
"""
    c = loop_expanded_cost(hlo)
    assert c.flops >= 256 * 256  # the multiply branch


def test_entry_detection():
    model = HloCostModel(HLO)
    assert model.entry == "main"
    assert "body" in model.comps and "cond" in model.comps
