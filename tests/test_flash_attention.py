"""Pallas flash attention vs dense-softmax oracle (shape/causality sweep)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "bh,s,t,hd,bq,bk",
    [
        (4, 64, 64, 32, 32, 32),
        (2, 128, 128, 64, 64, 32),
        (3, 64, 128, 32, 64, 64),  # cross-attention length
        (1, 256, 256, 16, 128, 128),
    ],
)
def test_flash_attention_matches_ref(causal, bh, s, t, hd, bq, bk):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(bh, s, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, t, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, t, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 64, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 64, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 64, 32)), jnp.bfloat16)
    got = flash_attention(q, k, v, interpret=True, block_q=32, block_k=32)
    want = flash_attention_ref(q, k, v)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=3e-2
    )


def test_flash_attention_masked_row_is_finite():
    """First query row under causal mask attends only position 0."""
    q = jnp.ones((1, 32, 16), jnp.float32)
    k = jnp.ones((1, 32, 16), jnp.float32)
    v = jnp.arange(32, dtype=jnp.float32)[None, :, None] * jnp.ones((1, 32, 16))
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16, interpret=True)
    assert float(out[0, 0, 0]) == pytest.approx(0.0, abs=1e-6)  # only sees v[0]=0
    assert bool(jnp.isfinite(out).all())
