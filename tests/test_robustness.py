"""Fault-tolerance: the chaos containment matrix, deadlines/shedding,
quarantine-and-retry, watchdog/overload degradation, IO flake retry.

The central contract (the chaos matrix): for every injected fault kind,
exactly the afflicted request fails (or retries), every OTHER concurrent
request finishes bit-identical to a fault-free run, and the engine keeps
serving.  Injection is seeded/armed (repro.serving.faults), never
wall-clock-random, so each case replays deterministically.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serving import (
    AdmissionConfig,
    FaultInjector,
    FlakyIO,
    HealthConfig,
    Request,
    SchedulerConfig,
    ServingEngine,
    StagedEngine,
    corrupt_payload,
)
from repro.serving.health import (
    POISON_NONFINITE,
    POISON_SATURATED,
    OverloadController,
    describe_poison,
    poison_flags,
)
from repro.serving.scheduler import (
    admission_decision,
    degraded_chunk,
    estimate_ttft_ms,
)
from repro.training import checkpoint as ck

KEY = jax.random.PRNGKey(0)

PROMPTS = ([5, 6, 7], [11, 3], [2, 9, 4, 1])


@pytest.fixture(scope="module")
def smoke():
    cfg = configs.get_smoke("qwen3-8b")
    api = build_model(cfg)
    return api, api.init(KEY)


def _run(api, params, cls, *, faults=None, max_retries=0, health=None,
         admission=None, n_slots=4, max_new=5, prompts=PROMPTS):
    kw = {}
    if health is not None:
        kw["health"] = health
    if admission is not None:
        kw["admission"] = admission
    if cls is StagedEngine:
        kw["sched"] = SchedulerConfig(prefill_chunk=2)
    eng = cls(api, params, n_slots=n_slots, max_len=32, faults=faults, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=max_new,
                           max_retries=max_retries))
    done = eng.run(max_ticks=4000)
    return eng, {r.uid: r for r in done}


# ---------------------------------------------------------------------------
# guardrail unit: the fused poison reduction
# ---------------------------------------------------------------------------
def test_poison_flags_bits():
    import jax.numpy as jnp

    logits = jnp.asarray([
        [1.0, -2.0, 3.0],          # healthy
        [1.0, jnp.nan, 0.0],       # NaN row
        [jnp.inf, 0.0, 0.0],       # Inf row
        [2.0 ** 30, 0.0, 0.0],     # finite but saturated
        [jnp.nan, 2.0 ** 30, 0.0],  # both
    ])
    flags = np.asarray(poison_flags(logits, sat_limit=2.0 ** 24))
    assert flags.tolist() == [
        0, POISON_NONFINITE, POISON_NONFINITE, POISON_SATURATED,
        POISON_NONFINITE | POISON_SATURATED,
    ]
    assert "non-finite" in describe_poison(POISON_NONFINITE)
    assert "saturated" in describe_poison(POISON_SATURATED)


def test_guardrails_do_not_change_tokens(smoke):
    """Greedy outputs with guardrails on == guardrails off, bit for bit:
    the check is observation-only on healthy traffic."""
    api, params = smoke
    _, on = _run(api, params, ServingEngine)
    _, off = _run(api, params, ServingEngine,
                  health=HealthConfig(guardrails=False))
    assert {u: r.output for u, r in on.items()} == \
        {u: r.output for u, r in off.items()}


# ---------------------------------------------------------------------------
# THE chaos matrix: one fault -> one victim, everyone else bit-identical
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine_cls", [ServingEngine, StagedEngine])
@pytest.mark.parametrize(
    "kind", ["nan_logits", "inf_logits", "sat_logits", "kv_corrupt"]
)
def test_chaos_matrix_containment(smoke, engine_cls, kind):
    """For each fault kind: exactly the afflicted request fails (retry
    budget 0), every other request finishes bit-identical to the fault-free
    baseline, and the engine serves to completion."""
    api, params = smoke
    _, base = _run(api, params, engine_cls)
    assert all(r.status == "finished" for r in base.values())

    inj = FaultInjector()
    kw = {"sched": SchedulerConfig(prefill_chunk=2)} \
        if engine_cls is StagedEngine else {}
    eng = engine_cls(api, params, n_slots=4, max_len=32, faults=inj, **kw)
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=5))
    done = []
    # a couple of healthy ticks first, so slot 0 has live KV rows for
    # kv_corrupt to poison (a corrupt row behind position 0 is fully
    # masked and proves nothing)
    done.extend(eng.step())
    done.extend(eng.step())
    inj.arm(kind, slot=0)
    done.extend(eng.run(max_ticks=4000))
    got = {r.uid: r for r in done}

    assert len(inj.log) == 1
    victim_uid = inj.log[0].uid
    assert victim_uid is not None  # the armed slot held a live request
    assert len(got) == len(base)
    for uid, r in got.items():
        if uid == victim_uid:
            assert r.status == "failed" and not r.done
            assert r.reason  # names the poison kind
        else:
            assert r.status == "finished"
            assert r.output == base[uid].output  # bit-identical
    ev = eng.stats()["health"]["events"]
    assert ev["quarantined"] == 1 and ev["failed"] == 1
    assert ev["faults_injected"] == 1


@pytest.mark.parametrize("engine_cls", [ServingEngine, StagedEngine])
def test_quarantine_retry_recovers_bit_identical(smoke, engine_cls):
    """With retry budget, the victim is re-queued (backoff), restarted from
    its prompt, and its SECOND run matches the fault-free output exactly --
    no poisoned partial output survives."""
    api, params = smoke
    _, base = _run(api, params, engine_cls)

    inj = FaultInjector().arm("nan_logits", slot=0)
    eng, got = _run(api, params, engine_cls, faults=inj, max_retries=1)

    assert all(r.status == "finished" for r in got.values())
    assert {u: r.output for u, r in got.items()} == \
        {u: r.output for u, r in base.items()}
    ev = eng.stats()["health"]["events"]
    assert ev["quarantined"] == 1 and ev["retried"] == 1
    assert ev["failed"] == 0
    victim = got[inj.log[0].uid]
    assert victim.retries == 1


def test_stall_tick_flags_watchdog_not_tokens(smoke):
    """A stalled tick is detected (slow/hung counters) but never corrupts:
    all outputs stay bit-identical to the baseline."""
    api, params = smoke
    _, base = _run(api, params, ServingEngine)
    inj = FaultInjector(stall_s=0.12).arm("stall_tick")
    eng, got = _run(api, params, ServingEngine, faults=inj,
                    health=HealthConfig(tick_slow_s=0.1))
    assert {u: r.output for u, r in got.items()} == \
        {u: r.output for u, r in base.items()}
    h = eng.stats()["health"]
    assert h["slow_ticks"] + h["hung_ticks"] >= 1
    assert h["tick_ms_worst"] >= 100.0


def test_seeded_rate_injection_replays(smoke):
    """Rate-mode chaos is a pure function of (seed, dispatch ordinal): two
    identical runs inject the same faults and serve the same tokens."""
    api, params = smoke

    def once():
        inj = FaultInjector(rate=0.3, kinds=("nan_logits",), seed=7)
        # zero backoff so re-admission order is pure FIFO, independent of
        # wall clock -- determinism must not hinge on tick timing
        eng, got = _run(api, params, ServingEngine, faults=inj, max_retries=2,
                        admission=AdmissionConfig(retry_backoff_ms=0.0))
        return ([(e.kind, e.slot, e.tick) for e in inj.log],
                {u: (r.status, tuple(r.output)) for u, r in got.items()})

    assert once() == once()


# ---------------------------------------------------------------------------
# deadlines, shedding, rejection, cancel
# ---------------------------------------------------------------------------
def test_admission_sheds_on_queue_depth(smoke):
    api, params = smoke
    eng = ServingEngine(api, params, n_slots=1, max_len=32,
                        admission=AdmissionConfig(max_queue=2))
    rs = [eng.submit(Request(uid=i, prompt=[3, 4], max_new_tokens=2))
          for i in range(4)]
    assert [r.status for r in rs] == ["queued", "queued", "shed", "shed"]
    assert "max_queue" in rs[2].reason
    assert eng.stats()["health"]["events"]["shed"] == 2
    done = eng.run()
    assert sorted(r.uid for r in done) == [0, 1]  # shed never entered


def test_deadline_expires_everywhere(smoke):
    """A request past its deadline is expired whether queued or in flight;
    live requests keep their slots and finish."""
    api, params = smoke
    eng = ServingEngine(api, params, n_slots=1, max_len=32)
    doomed = eng.submit(Request(uid=0, prompt=[5, 6], max_new_tokens=4,
                                deadline_ms=0.0))
    alive = eng.submit(Request(uid=1, prompt=[5, 6], max_new_tokens=4))
    done = {r.uid: r for r in eng.run()}
    assert done[0] is doomed and doomed.status == "expired"
    assert not doomed.done and "deadline" in doomed.reason
    assert done[1] is alive and alive.status == "finished"


def test_cancel_queued_and_inflight(smoke):
    api, params = smoke
    eng = ServingEngine(api, params, n_slots=1, max_len=32)
    a = eng.submit(Request(uid=0, prompt=[5, 6], max_new_tokens=8))
    b = eng.submit(Request(uid=1, prompt=[7, 8], max_new_tokens=8))
    eng.step()  # admits a into the slot
    assert eng.cancel(0) and a.status == "cancelled"  # in flight
    assert eng.cancel(1) and b.status == "cancelled"  # still queued
    assert not eng.cancel(99)
    assert eng.run() == []  # nothing left
    assert eng.stats()["health"]["events"]["cancelled"] == 2


def test_estimate_and_admission_units():
    assert estimate_ttft_ms(queued_tokens=10, n_queued=2, tick_ms=0.0) == 0.0
    # lockstep: one tick per token + one first-token tick per request
    assert estimate_ttft_ms(queued_tokens=10, n_queued=2, tick_ms=2.0) == 24.0
    # staged: ceil(10/4)=3 chunk dispatches
    assert estimate_ttft_ms(queued_tokens=10, n_queued=2, tick_ms=2.0,
                            chunk=4) == 10.0
    adm = AdmissionConfig(max_queue=2, ttft_slo_ms=50.0)
    assert admission_decision(adm, queue_depth=1, est_ttft_ms=10.0) is None
    assert "max_queue" in admission_decision(adm, queue_depth=2,
                                             est_ttft_ms=0.0)
    assert "TTFT" in admission_decision(adm, queue_depth=0, est_ttft_ms=51.0)
    # the request's own deadline tightens the budget
    assert "TTFT" in admission_decision(
        AdmissionConfig(), queue_depth=0, est_ttft_ms=30.0, deadline_ms=20.0)


# ---------------------------------------------------------------------------
# overload degradation
# ---------------------------------------------------------------------------
def test_degraded_chunk_is_compiled_shape():
    for chunk in (1, 2, 3, 8, 13, 32, 100):
        d = degraded_chunk(chunk)
        assert d & (d - 1) == 0  # power of two...
        assert d <= max(1, chunk // 2)  # ...at most half the budget
        assert 2 * d > max(1, chunk // 2)  # the LARGEST such
        # every degraded size < chunk is, being a power of two, already in
        # the compiled remainder-shape set {2^i < chunk}: degradation
        # never triggers a fresh prefill compile
        assert d < chunk or chunk == 1


def test_overload_controller_hysteresis():
    ctl = OverloadController(HealthConfig(overload_queue=4))
    assert ctl.update(queue_depth=4) is False  # at threshold: no breach
    assert ctl.update(queue_depth=5) is True   # breach -> enter
    assert ctl.update(queue_depth=4) is True   # 4 > 0.8*4: still in
    assert ctl.update(queue_depth=3) is False  # under 80%: recover
    assert ctl.entered == 1


def test_staged_overload_degrades_and_recovers(smoke):
    """Queue-depth overload shrinks the prefill chunk to a pre-compiled
    power of two and forces decode-priority; everything still finishes."""
    api, params = smoke
    eng = StagedEngine(api, params, n_slots=2, max_len=32,
                       sched=SchedulerConfig(prefill_chunk=8,
                                             policy="prefill"),
                       health=HealthConfig(overload_queue=2))
    for i in range(8):
        eng.submit(Request(uid=i, prompt=[1 + i, 2, 3], max_new_tokens=2))
    eng.step()  # queue depth 7 > 2: overload latches before more dispatch
    assert eng.overload
    assert eng._effective_chunk() == degraded_chunk(8)
    done = eng.run(max_ticks=4000)
    assert len(done) == 8 and all(r.status == "finished" for r in done)
    h = eng.stats()["health"]
    assert h["overload_entered"] >= 1
    assert not eng.overload  # drained queue: recovered


# ---------------------------------------------------------------------------
# artifact-load faults: transient flake retries, corruption fails closed
# ---------------------------------------------------------------------------
def test_io_flake_retried_transparently(tmp_path, monkeypatch):
    import jax.numpy as jnp

    tree = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((3,))}
    ck.save(str(tmp_path), 1, tree)
    monkeypatch.setattr(ck, "IO_BACKOFF_S", 0.001)  # fast test
    flake = FlakyIO(n_failures=2)
    with ck.io_fault_hook(flake):
        step, got = ck.restore_latest(str(tmp_path),
                                      jax.eval_shape(lambda: tree))
    assert step == 1 and flake.raised == 2  # the flakes actually fired
    assert np.array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_io_flake_exhausts_budget_and_raises(tmp_path, monkeypatch):
    import jax.numpy as jnp

    tree = {"w": jnp.arange(4.0)}
    ck.save(str(tmp_path), 1, tree)
    monkeypatch.setattr(ck, "IO_BACKOFF_S", 0.001)
    # more consecutive failures than the retry budget: fail loud, not hang
    flake = FlakyIO(n_failures=10_000)
    with ck.io_fault_hook(flake):
        assert ck.latest_intact_step(str(tmp_path)) is None


def test_corrupt_shard_fails_closed_never_retried(tmp_path):
    """Integrity corruption is NOT transient: no retry can fix it, the step
    must be rejected (fall back to an older intact step)."""
    import jax.numpy as jnp

    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(str(tmp_path), 1, tree)
    ck.save(str(tmp_path), 2, tree)
    victim = corrupt_payload(str(tmp_path / "step_000000002"), seed=3)
    assert os.path.exists(victim)
    assert ck.latest_intact_step(str(tmp_path)) == 1
    step, got = ck.restore_latest(str(tmp_path), jax.eval_shape(lambda: tree))
    assert step == 1
    assert np.array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# injector plumbing
# ---------------------------------------------------------------------------
def test_fault_injector_spec_roundtrip():
    inj = FaultInjector.from_spec(
        "rate=0.25,kinds=nan_logits|kv_corrupt,seed=9,stall=0.5")
    assert inj.rate == 0.25 and inj.kinds == ("nan_logits", "kv_corrupt")
    assert inj.stall_s == 0.5
    with pytest.raises(ValueError, match="unknown --chaos key"):
        FaultInjector.from_spec("rat=0.1")
    with pytest.raises(ValueError, match="unknown tick fault kind"):
        FaultInjector(kinds=("bitrot",))
    with pytest.raises(ValueError, match="rate"):
        FaultInjector(rate=1.5)


def test_fault_injector_rate_targets_active_slots_only():
    inj = FaultInjector(rate=1.0, kinds=("nan_logits",), seed=0)
    assert inj.draw(0, []) is None  # nothing active: nothing to poison
    ev = inj.draw(1, [2])
    assert ev is not None and ev.slot == 2 and ev.tick == 1
    assert np.isnan(ev.payload)
    assert inj.summary() == {"injected": 1, "by_kind": {"nan_logits": 1}}
