"""Sharded quantized serving: shard_map EP bit-parity vs the single-device
oracle, per-host sharded artifacts, and the mesh-aware engine.

The multi-device cells run in a subprocess: XLA_FLAGS must force the host
platform device count before jax initializes, which cannot happen inside
this process (same pattern as test_dryrun.py).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_py(script: str, timeout: int = 420, devices: int = 4):
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(REPO, "src"),
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
    )
    return subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )


EP_PARITY_SCRIPT = r"""
import glob, json, os, tempfile
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import QuantConfig
from repro.core.quantizer import QTensor
from repro.launch.mesh import parse_mesh_spec
from repro.models import build_model, load_servable, quantize_and_plan, save_servable
from repro.parallel import sharding as rules
from repro.serving import Request, ServingEngine

assert jax.device_count() == 4, jax.device_count()

qc = QuantConfig(w_bits=2, group_size=16, mode="ptq", backend="pallas_ep")
cfg = configs.get_smoke("grok-1-314b", qc)  # MoE family: 4 experts
api = build_model(cfg)
params = api.init(jax.random.PRNGKey(0))
qparams, plan, qapi = quantize_and_plan(api, params)
mesh = parse_mesh_spec("dp=2,ep=2")

# ---- per-host sharded artifact: payload.shard{k} + per-shard sha256 ------
d = tempfile.mkdtemp()
step = save_servable(d, qapi, qparams, plan, mesh=mesh)
shard_files = [f for f in os.listdir(step) if ".shard" in f]
assert shard_files, "expected per-host shard files on disk"
man = json.load(open(os.path.join(step, "manifest.json")))
n_sharded = 0
for node in man["nodes"].values():
    for meta in node["arrays"].values():
        if "shards" in meta:
            n_sharded += 1
            assert all("sha256" in s and "index" in s for s in meta["shards"])
            assert "shape" in meta and "dtype" in meta
assert n_sharded > 0, "no payload used the sharded layout"

# ---- bit parity: sharded EP decode vs the single-device oracle -----------
def run_engine(mesh):
    eng = ServingEngine.from_artifact(d, n_slots=2, max_len=16, mesh=mesh)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=[1 + i, 2, 3], max_new_tokens=4))
    return {r.uid: r.output for r in eng.run()}

# sharded engine FIRST: each engine scopes the ambient activation mesh to
# its own dispatches, so a meshed engine must not leak its mesh into a
# mesh-less oracle built afterwards in the same process
sharded = run_engine(mesh)
oracle = run_engine(None)
assert oracle == sharded, f"tokens diverged: {oracle} vs {sharded}"
assert all(len(v) == 4 for v in oracle.values())

# ---- the loaded tree is on-mesh and expert sites go through shard_map ----
api2, qp2, art = load_servable(d, mesh=mesh)
packed_specs = [
    l.packed.sharding.spec for l in jax.tree.leaves(
        qp2, is_leaf=lambda x: isinstance(x, QTensor)
    ) if isinstance(l, QTensor)
]
assert any(
    any(ax is not None for ax in spec) for spec in packed_specs
), f"no QTensor payload actually sharded: {packed_specs}"

rules.set_activation_mesh(mesh)
cache_shapes = jax.eval_shape(lambda: api2.init_cache(2, 16))
cache = jax.device_put(
    api2.init_cache(2, 16), rules.cache_shardings(cache_shapes, mesh)
)
tok = jnp.zeros((2, 1), jnp.int32)
pos = jnp.zeros((2,), jnp.int32)
jaxpr = str(jax.make_jaxpr(
    lambda p, t, po, c: api2.decode(p, t, po, c)[0]
)(qp2, tok, pos, cache))
assert "shard_map" in jaxpr, "expert FFN did not lower through shard_map"
assert "all_to_all" in jaxpr, "no in-body dispatch/combine all-to-alls"
rules.set_activation_mesh(None)

# ---- a corrupt shard file fails closed (no silent partial restore) -------
bad = sorted(glob.glob(os.path.join(step, "*.shard0.npy")))[0]
with open(bad, "wb") as fh:
    fh.write(b"junk")
from repro.quant import load_artifact
try:
    load_artifact(d)
    raise SystemExit("corrupt shard restored as intact")
except IOError:
    pass
print("EP_PARITY_OK")
"""


def test_sharded_ep_decode_bit_parity_2x2_mesh():
    """Forced 4-device CPU mesh: per-host sharded artifact cold-start, EP
    decode bit-identical to the single-device artifact path, shard_map +
    all-to-alls in the decode jaxpr, corrupt shards fail closed."""
    r = _run_py(EP_PARITY_SCRIPT)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "EP_PARITY_OK" in r.stdout


SHARDED_RESTORE_SCRIPT = r"""
import os, tempfile
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import parse_mesh_spec
from repro.parallel import sharding as rules
from repro.quant import load_artifact, quantize_weights, save_artifact

mesh = parse_mesh_spec("dp=2,ep=2")
tree = {
    "blocks": {"attn": {"wq": {"w": quantize_weights(
        jax.random.normal(jax.random.PRNGKey(0), (64, 128)), 2, 16
    )}}},
    "embed": {"table": jax.random.normal(jax.random.PRNGKey(1), (128, 64))},
}
d = tempfile.mkdtemp()
save_artifact(d, tree, None, mesh=mesh)

# same mesh: per-shard files go straight onto their owning devices
art = load_artifact(d, mesh=mesh)
qt0, qt1 = tree["blocks"]["attn"]["wq"]["w"], art.params["blocks"]["attn"]["wq"]["w"]
assert np.array_equal(np.asarray(qt0.packed), np.asarray(qt1.packed))
assert np.array_equal(np.asarray(qt0.scale_m), np.asarray(qt1.scale_m))
# elastic fallback: a DIFFERENT mesh shape still assembles correctly
mesh2 = parse_mesh_spec("dp=1,ep=4")
art2 = load_artifact(d, mesh=mesh2)
qt2 = art2.params["blocks"]["attn"]["wq"]["w"]
assert np.array_equal(np.asarray(qt0.packed), np.asarray(qt2.packed))
# mesh-free host assembly of the same sharded files
art3 = load_artifact(d)
qt3 = art3.params["blocks"]["attn"]["wq"]["w"]
assert np.array_equal(np.asarray(qt0.packed), np.asarray(qt3.packed))
assert np.array_equal(
    np.asarray(tree["embed"]["table"]), np.asarray(art3.params["embed"]["table"])
)

# a manifest whose shards no longer tile the array (a host's shards missing)
# must fail verification, not assemble with uninitialized slices
import json
step = art.path
mpath = os.path.join(step, "manifest.json")
man = json.load(open(mpath))
for node in man["nodes"].values():
    for meta in node["arrays"].values():
        if "shards" in meta and len(meta["shards"]) > 1:
            meta["shards"] = meta["shards"][:-1]
with open(mpath, "w") as fh:
    json.dump(man, fh)
try:
    load_artifact(d)
    raise SystemExit("partial shard set restored as intact")
except IOError:
    pass
print("RESTORE_OK")
"""


def test_sharded_artifact_elastic_restore():
    """Sharded payloads restore bit-exact on the saving mesh, on a different
    mesh shape (elastic fallback) and with no mesh at all."""
    r = _run_py(SHARDED_RESTORE_SCRIPT, timeout=240)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "RESTORE_OK" in r.stdout


@pytest.mark.slow
def test_serve_cli_mesh_cold_start(tmp_path):
    """serve.py --artifact DIR --mesh dp=2,ep=2 cold-starts from per-host
    shards and prints the same tokens as the single-device path."""
    art = str(tmp_path / "art")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)

    def serve(*args, timeout=420):
        return subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", *args],
            capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
        )

    r = serve("--arch", "grok-1-314b", "--smoke", "--bits", "2",
              "--group-size", "16", "--backend", "pallas_ep",
              "--requests", "2", "--save-artifact", art,
              "--mesh", "dp=2,ep=2")
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "per-host shards" in r.stdout

    def token_lines(out):
        return [l for l in out.splitlines() if l.strip().startswith("req ")]

    single = serve("--artifact", art, "--requests", "4")
    assert single.returncode == 0, single.stdout[-2000:] + single.stderr[-2000:]
    meshed = serve("--artifact", art, "--requests", "4",
                   "--mesh", "dp=2,ep=2")
    assert meshed.returncode == 0, meshed.stdout[-2000:] + meshed.stderr[-2000:]
    assert "per-host shards assembled" in meshed.stdout
    assert token_lines(single.stdout) == token_lines(meshed.stdout)
