"""Pallas kernels (interpret mode) vs the pure-jnp oracle: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizer import quantize_weights
from repro.kernels import ops, ref
from repro.kernels.int4_matmul import int4_matmul
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.mx_matmul import mx_matmul
from repro.kernels.nf4_matmul import nf4_matmul
from repro.kernels.quantize import quantize_rows
from repro.kernels.ternary_matmul import ternary_matmul

# every registered format's packed-matmul kernel (2/4/8 keep their legacy
# bits keys; nf4 and mx are name-keyed since their widths collide).  Widths
# for the named formats come from the registry so they can never drift.
from repro.quant import get_format

KERNELS = {2: ternary_matmul, 4: int4_matmul, 8: int8_matmul,
           "nf4": nf4_matmul, "mx": mx_matmul}
_FMT_BITS = {2: 2, 4: 4, 8: 8,
             "nf4": get_format("nf4").bits, "mx": get_format("mx").bits}


def _setup(m, k, n, g, bits, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    fmt = bits if isinstance(bits, str) else None
    qt = quantize_weights(
        jnp.asarray(rng.normal(size=(k, n)), jnp.float32),
        _FMT_BITS[bits], g, fmt=fmt,
    )
    xq, xe = ref.quantize_rows_ref(x, 8)
    return x, xq, xe, qt


@pytest.mark.parametrize("bits", [2, 4, 8, "nf4", "mx"])
@pytest.mark.parametrize(
    "m,k,n,g,bk",
    [
        (8, 64, 32, 16, 32),
        (16, 256, 128, 64, 128),
        (4, 128, 16, 32, 128),  # bk == k
        (32, 512, 64, 64, 256),
    ],
)
def test_qmm_kernels_exact_vs_ref(bits, m, k, n, g, bk):
    x, xq, xe, qt = _setup(m, k, n, g, bits)
    g = qt.group_size  # mx pins its own 32-element block
    want_int = ref.qmatmul_ref(xq, xe, qt)
    kern = KERNELS[bits]
    raw = kern(
        xq, qt.packed, qt.scale_m, group=g,
        block_m=min(8, m), block_n=min(128, n), block_k=bk, interpret=True,
    )
    got = raw * jnp.exp2(qt.scale_e.astype(jnp.float32) + xe.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_int), rtol=1e-6)


@pytest.mark.parametrize("bits", [2, 4, 8, "nf4", "mx"])
def test_ops_qmatmul_backends_agree(bits):
    x, xq, xe, qt = _setup(16, 256, 64, 64, bits, seed=3)
    want = ref.qmatmul_ref(xq, xe, qt)
    got_pallas = ops.qmatmul(x, qt, backend="pallas", block_k=128)
    np.testing.assert_allclose(np.asarray(got_pallas), np.asarray(want), rtol=1e-6)
    got_xla = ops.qmatmul(x, qt, backend="xla")
    # bf16 dequant path: same math, bf16 rounding
    denom = np.abs(np.asarray(want)).max() + 1e-9
    assert np.abs(np.asarray(got_xla) - np.asarray(want)).max() / denom < 2e-2


def test_qmatmul_batched_leading_dims():
    x, _, _, qt = _setup(12, 128, 32, 32, 2, seed=5)
    xb = x.reshape(3, 4, 128)
    out = ops.qmatmul(xb, qt, backend="pallas", block_k=128)
    flat = ops.qmatmul(x, qt, backend="pallas", block_k=128)
    np.testing.assert_allclose(np.asarray(out.reshape(12, 32)), np.asarray(flat))


def test_qmatmul_row_padding():
    """Ragged serving batches: M not a multiple of the tile."""
    x, _, _, qt = _setup(7, 64, 16, 16, 2, seed=6)
    got = ops.qmatmul(x, qt, backend="pallas", block_k=64)
    want = ops.qmatmul(x, qt, backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("m,d", [(8, 64), (32, 512), (256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_rows_kernel(m, d, dtype):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(m, d)) * 10, dtype)
    q, e = quantize_rows(x, interpret=True, block_m=min(64, m))
    qr, er = ref.quantize_rows_ref(x, 8)
    assert (np.asarray(q) == np.asarray(qr)).all()
    assert (np.asarray(e) == np.asarray(er)).all()


def test_quantize_rows_zero_row():
    x = jnp.zeros((8, 32))
    q, e = quantize_rows(x, interpret=True)
    assert (np.asarray(q) == 0).all()


def test_integer_pipeline_is_integer():
    """The kernel's accumulation is exactly int32: outputs on the scale grid."""
    x, xq, xe, qt = _setup(4, 64, 8, 16, 2, seed=7)
    raw = ternary_matmul(
        xq, qt.packed, qt.scale_m, group=16, block_m=4, block_n=8,
        block_k=64, interpret=True,
    )
    # raw = sum_g int32_partial * int8_scale -> every value is an integer
    assert np.allclose(np.asarray(raw), np.round(np.asarray(raw)))
