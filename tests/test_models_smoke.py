"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions (assignment requirement), plus QAT and PTQ
variants for a representative subset.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.base import QuantConfig
from repro.models import (
    build_model,
    make_ctx,
    make_smoke_batch,
    quantize_model_params,
)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    api = build_model(cfg)
    params = api.init(KEY)
    batch = make_smoke_batch(jax.random.PRNGKey(1), cfg, batch=2, seq=16)

    logits = api.forward(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.isfinite(logits).all())

    loss, grads = jax.value_and_grad(api.train_loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    gn = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0
    )
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = configs.get_smoke(arch)
    api = build_model(cfg)
    params = api.init(KEY)
    cache = api.init_cache(2, 32)
    logits, new_cache = api.decode(params, jnp.ones((2, 1), jnp.int32), jnp.int32(0), cache)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ["qwen3-8b", "grok-1-314b", "zamba2-7b"])
def test_smoke_qat_step(arch):
    cfg = configs.get_smoke(arch, QuantConfig(w_bits=2, group_size=16, mode="qat"))
    api = build_model(cfg)
    params = api.init(KEY)
    batch = make_smoke_batch(jax.random.PRNGKey(1), cfg, batch=2, seq=16)
    loss, grads = jax.value_and_grad(api.train_loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    # STE: gradient reaches the fp32 master weights of quantized layers
    gw = grads["blocks"]["attn"]["wq"]["w"] if arch != "zamba2-7b" else (
        grads["mamba_stack"]["mamba"]["in_proj"]["w"]
    )
    assert float(jnp.sum(jnp.abs(gw))) > 0


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_smoke_ptq_bits(bits):
    cfg = configs.get_smoke(
        "qwen3-8b", QuantConfig(w_bits=bits, group_size=16, mode="ptq", backend="xla")
    )
    api = build_model(cfg)
    params = api.init(KEY)
    qparams = quantize_model_params(params, api.ctx.policy)
    batch = make_smoke_batch(jax.random.PRNGKey(1), cfg, batch=2, seq=16)
    logits = api.forward(qparams, batch)
    assert bool(jnp.isfinite(logits).all())


def test_ptq_error_decreases_with_bits():
    """PTQ logits should approach the fp logits as bits grow (paper Fig. 1)."""
    cfg = configs.get_smoke("phi4-mini-3.8b")
    api = build_model(cfg)
    params = api.init(KEY)
    batch = make_smoke_batch(jax.random.PRNGKey(1), cfg, batch=2, seq=16)
    ref = api.forward(params, batch).astype(jnp.float32)

    errs = {}
    for bits in (2, 4, 8):
        qcfg = configs.get_smoke(
            "phi4-mini-3.8b",
            QuantConfig(w_bits=bits, group_size=16, mode="ptq", backend="xla"),
        )
        qapi = build_model(qcfg)
        qparams = quantize_model_params(params, qapi.ctx.policy)
        out = qapi.forward(qparams, batch).astype(jnp.float32)
        errs[bits] = float(jnp.mean((out - ref) ** 2))
    assert errs[8] < errs[4] < errs[2]


def test_gemma3_local_global_schedule():
    from repro.models.transformer import window_schedule

    cfg = configs.get_smoke("gemma3-12b")
    win = window_schedule(cfg, 64)
    assert win.shape == (cfg.n_layers,)
    # 5 local : 1 global (global = seq_len + 1 sentinel)
    assert int(win[5]) == 65 and all(int(win[i]) == 8 for i in range(5))


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment table."""
    c = configs.get_config("grok-1-314b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (64, 6144, 48, 8)
    assert (c.d_ff, c.vocab, c.n_experts, c.top_k) == (32768, 131072, 8, 2)
    c = configs.get_config("arctic-480b")
    assert (c.n_layers, c.d_model, c.n_experts) == (35, 7168, 128)
    assert c.moe_dense_residual
    c = configs.get_config("qwen1.5-110b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (80, 8192, 49152, 152064)
    assert c.qkv_bias
    c = configs.get_config("qwen3-8b")
    assert c.qk_norm and (c.n_layers, c.d_model) == (36, 4096)
    c = configs.get_config("gemma3-12b")
    assert c.local_global_ratio == 5 and c.vocab == 262144
    c = configs.get_config("qwen2-vl-72b")
    assert c.mrope and c.frontend == "vision"
    c = configs.get_config("zamba2-7b")
    assert (c.n_layers, c.ssm_state, c.ssm_version) == (81, 64, 2)
    c = configs.get_config("falcon-mamba-7b")
    assert (c.n_layers, c.ssm_state, c.ssm_version) == (64, 16, 1)
    assert c.is_attention_free()
    c = configs.get_config("whisper-base")
    assert (c.n_enc_layers, c.n_layers, c.d_model, c.vocab) == (6, 6, 512, 51865)
    c = configs.get_config("phi4-mini-3.8b")
    assert (c.n_layers, c.d_model, c.vocab) == (32, 3072, 200064)


def test_long_context_skip_list():
    """long_500k runs only for sub-quadratic archs (assignment rule)."""
    runs = {a for a in configs.ARCH_IDS if configs.get_config(a).supports_long_context()}
    assert runs == {"gemma3-12b", "zamba2-7b", "falcon-mamba-7b"}
