"""Unified repro.quant API: compiled plans, registries, calibration-aware PTQ."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import QuantConfig
from repro.core.policy import FULL_PRECISION, LayerPrecision, PrecisionPolicy
from repro.kernels import ref
from repro.models import build_model, make_smoke_batch, quantize_and_plan
from repro.quant import (
    Observer,
    QuantCtx,
    QuantPlan,
    backend_names,
    format_for_bits,
    format_names,
    get_backend,
    get_format,
    qmatmul,
    quantize_activations,
    quantize_model,
    quantize_weights,
    register_backend,
    register_format,
)
from repro.quant import backends as backends_mod
from repro.quant.plan import compile_policy, iter_weight_sites

KEY = jax.random.PRNGKey(0)
PTQ16 = QuantConfig(w_bits=2, group_size=16, mode="ptq", backend="xla")


# ---------------------------------------------------------------------------
# Registries.
# ---------------------------------------------------------------------------
def test_builtin_registries_populated():
    assert {"ternary", "int4", "int8"} <= set(format_names())
    assert {"pallas", "xla", "xla_int8", "ref"} <= set(backend_names())
    for bits in (2, 4, 8):
        assert format_for_bits(bits).bits == bits


def test_registry_duplicate_and_unknown_errors():
    with pytest.raises(ValueError):
        register_format("ternary", bits=2, encode=None, decode=None,
                        weight_codes=None)
    with pytest.raises(ValueError):
        register_backend("xla", lambda *a, **k: None)
    with pytest.raises(KeyError):
        get_format("no_such_format")
    with pytest.raises(ValueError):
        get_backend("no_such_backend")
    with pytest.raises(ValueError):
        qmatmul(jnp.ones((2, 32)), quantize_weights(jnp.ones((32, 8)), 2, 16),
                backend="no_such_backend")


def test_custom_format_plugs_into_qmatmul():
    """A new format flows through quantize_weights + every backend without
    touching dispatch code (the point of the registry)."""
    from repro.core.quantizer import pack4, unpack4
    from repro.quant.formats import _dfp_weight_codes

    name = "int4_dup_for_test"
    try:
        get_format(name)
    except KeyError:
        register_format(
            name, bits=4, encode=pack4, decode=unpack4,
            weight_codes=_dfp_weight_codes(4),
            kernel=format_for_bits(4).kernel,
        )
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    qt = quantize_weights(w, group_size=16, fmt=name)
    assert qt.fmt == name and qt.bits == 4
    want = qmatmul(x, quantize_weights(w, 4, 16), backend="ref")
    for b in ("ref", "xla_int8", "pallas"):
        got = qmatmul(x, qt, backend=b, block_k=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_named_format_flows_through_plan_pipeline():
    """LayerPrecision.fmt selects a registered format through the whole
    quantize_model pipeline (the registry's extension point)."""
    from repro.core.quantizer import pack4, unpack4
    from repro.quant import quantize_params
    from repro.quant.formats import _dfp_weight_codes

    name = "int4_dup_for_test"
    try:
        get_format(name)
    except KeyError:
        register_format(
            name, bits=4, encode=pack4, decode=unpack4,
            weight_codes=_dfp_weight_codes(4),
            kernel=format_for_bits(4).kernel,
        )
    pol = PrecisionPolicy(
        default=LayerPrecision(w_bits=4, group_size=16, fmt=name)
    )
    params = {"proj": {"w": jnp.asarray(
        np.random.default_rng(0).normal(size=(32, 8)), jnp.float32)}}
    plan = pol.compile(params)
    qparams = quantize_params(params, plan)
    qt = qparams["proj"]["w"]
    assert qt.fmt == name and qt.bits == 4
    # and the precision (incl. fmt) survives plan serialization
    assert QuantPlan.from_json(plan.to_json()).resolve("proj").fmt == name


def test_register_format_overwrite_does_not_steal_bits_default():
    """Overwriting a format must not silently re-route fmt="" QTensors of an
    unrelated width, and a name changing width drops its stale default."""
    from repro.core.quantizer import pack4, unpack4
    from repro.quant.formats import _dfp_weight_codes

    name = "bits_probe_for_test"
    kw = dict(encode=pack4, decode=unpack4, weight_codes=_dfp_weight_codes(4))
    register_format(name, bits=4, overwrite=True, **kw)
    assert format_for_bits(4).name == "int4"  # default untouched
    # re-register the same name at a width it can't default either
    register_format(name, bits=8, overwrite=True, **kw)
    assert format_for_bits(8).name == "int8"
    assert format_for_bits(4).name == "int4"  # stale claim dropped, not kept


def test_register_format_overwrite_reassigns_orphaned_bits_default():
    """Regression: re-registering the sole claimant of a width at a NEW
    width must hand the old width's default to a surviving format of that
    width -- pre-fix the default was deleted outright, so format_for_bits
    raised for a width that resolved fine before the re-registration."""
    from repro.core.quantizer import pack4, unpack4
    from repro.quant.formats import _BY_BITS, _FORMATS, _dfp_weight_codes

    kw = dict(encode=pack4, decode=unpack4, weight_codes=_dfp_weight_codes(4))
    a, b = "orphan_probe_a", "orphan_probe_b"
    width = 3  # unclaimed by any built-in
    try:
        register_format(a, bits=width, overwrite=True, **kw)  # claims width 3
        register_format(b, bits=width, overwrite=True, **kw)  # doesn't
        assert format_for_bits(width).name == a
        # branch 1: a CODEC-COMPATIBLE survivor (same encode/decode
        # callables) exists -> the default migrates to it
        register_format(a, bits=5, overwrite=True, **kw)
        assert format_for_bits(width).name == b
        # branch 1b: a survivor with DIFFERENT code semantics must NOT
        # inherit the default -- legacy empty-fmt payloads would silently
        # mis-decode through it (e.g. int4 two's-complement through a LUT);
        # fail closed instead
        from repro.quant.formats import _nf4_decode
        from repro.core.quantizer import pack4u

        c = "orphan_probe_c"
        register_format(
            c, bits=5, overwrite=True,
            encode=pack4u, decode=_nf4_decode, weight_codes=kw["weight_codes"],
        )
        register_format(a, bits=7, overwrite=True, **kw)  # a owned width 5
        with pytest.raises(ValueError):
            format_for_bits(5)  # c survives at width 5 but is incompatible
        # branch 2: no survivor at all -> fail closed (raise), no stale ptr
        register_format(b, bits=6, overwrite=True, **kw)
        with pytest.raises(ValueError):
            format_for_bits(width)
    finally:  # the registry is process-global: leave no probe state behind
        for probe in (a, b, "orphan_probe_c"):
            _FORMATS.pop(probe, None)
        for bits in (3, 5, 6, 7):
            if _BY_BITS.get(bits) in (a, b, "orphan_probe_c"):
                del _BY_BITS[bits]


def test_quantize_weights_stamps_resolved_format_name():
    """Regression: bits-resolved QTensors must be stamped with the resolved
    format NAME, not fmt="" -- an empty stamp re-resolves through the
    mutable _BY_BITS table at decode time, which is ambiguous now that nf4
    coexists with int4 (and mx with int8) at the same width."""
    from repro.quant.formats import format_of

    w = jnp.asarray(np.random.default_rng(0).normal(size=(64, 8)), jnp.float32)
    for bits, want in ((2, "ternary"), (4, "int4"), (8, "int8")):
        qt = quantize_weights(w, bits, 16)
        assert qt.fmt == want
    # legacy empty-fmt artifacts (pre-fix checkpoints) still resolve by
    # bits, and the defaults still point at the built-ins even though nf4
    # and mx are registered at the same widths
    legacy4 = dataclasses.replace(quantize_weights(w, 4, 16), fmt="")
    legacy8 = dataclasses.replace(quantize_weights(w, 8, 16), fmt="")
    assert format_of(legacy4).name == "int4"
    assert format_of(legacy8).name == "int8"


def test_new_formats_registered_without_stealing_defaults():
    """nf4 (bits=4) and mx (bits=8) are first-class registry citizens whose
    bit-widths collide with built-ins -- the registry must keep them
    name-addressed while bits stay with int4/int8."""
    assert {"nf4", "mx"} <= set(format_names())
    assert get_format("nf4").bits == 4 and format_for_bits(4).name == "int4"
    assert get_format("mx").bits == 8 and format_for_bits(8).name == "int8"
    assert get_format("mx").block_size == 32
    for name in ("nf4", "mx"):
        f = get_format(name)
        assert f.kernel is not None and f.fused_kernel is not None


def test_qat_ste_honors_named_format():
    """Regression: the QAT forward must fake-quantize on the NAMED format's
    grid (the one PTQ deploys on), not the bits-default uniform grid --
    silently training against int4's grid while serving nf4's LUT would
    lose the QAT benefit with no error."""
    from repro.core import ste
    from repro.quant.formats import fake_quantize_weights

    w = jnp.asarray(np.random.default_rng(0).normal(size=(64, 8)), jnp.float32)
    got = ste.weights_ste(w, 4, 16, fmt="nf4")
    want = fake_quantize_weights(w, 4, 16, fmt="nf4")
    assert np.array_equal(np.asarray(got), np.asarray(want))
    # the nf4 grid really differs from the bits-4 default grid
    assert not np.array_equal(
        np.asarray(got), np.asarray(fake_quantize_weights(w, 4, 16))
    )
    # and the straight-through gradient is still identity
    g = jax.grad(lambda m: ste.weights_ste(m, 4, 16, fmt="nf4").sum())(w)
    assert np.allclose(np.asarray(g), 1.0)


def test_custom_backend_dispatch():
    calls = []

    def null_backend(xq, xe, qt, **kw):
        calls.append(xq.shape)
        return jnp.zeros((xq.shape[0], qt.n), jnp.float32)

    try:
        register_backend("null_for_test", null_backend)
    except ValueError:
        pass
    qt = quantize_weights(jnp.ones((32, 8)), 2, 16)
    out = qmatmul(jnp.ones((3, 32)), qt, backend="null_for_test")
    assert out.shape == (3, 8) and calls


# ---------------------------------------------------------------------------
# quantize_activations: explicit three-way control flow (was dead logic).
# ---------------------------------------------------------------------------
def test_quantize_activations_ref_path_matches_oracle():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 32)), jnp.float32)
    q, e = quantize_activations(x, use_pallas=False)
    qr, er = ref.quantize_rows_ref(x, 8)
    assert (np.asarray(q) == np.asarray(qr)).all()
    assert (np.asarray(e) == np.asarray(er)).all()


def test_quantize_activations_pallas_interpret_matches_oracle():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(8, 32)), jnp.float32)
    q, e = quantize_activations(x, use_pallas=True)  # off-TPU -> interpret
    qr, er = ref.quantize_rows_ref(x, 8)
    assert (np.asarray(q) == np.asarray(qr)).all()
    assert (np.asarray(e) == np.asarray(er)).all()


def test_quantize_activations_dispatch_three_way(monkeypatch):
    """pallas-on-tpu / pallas-interpret / ref are each reachable and chosen
    by (use_pallas, on_tpu) exactly."""
    seen = {}

    def fake_quantize_rows(x, *, bits=8, interpret=False, **kw):
        seen["interpret"] = interpret
        return ref.quantize_rows_ref(x, bits)

    monkeypatch.setattr(backends_mod, "quantize_rows", fake_quantize_rows)
    x = jnp.ones((4, 16))

    monkeypatch.setattr(backends_mod, "_on_tpu", lambda: True)
    quantize_activations(x)  # default on TPU -> pallas, compiled
    assert seen.pop("interpret") is False

    monkeypatch.setattr(backends_mod, "_on_tpu", lambda: False)
    quantize_activations(x, use_pallas=True)  # forced pallas off-TPU
    assert seen.pop("interpret") is True

    quantize_activations(x)  # default off-TPU -> ref oracle, no pallas call
    assert "interpret" not in seen


# ---------------------------------------------------------------------------
# Plan compilation: identical resolutions to the legacy per-call resolve.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_plan_matches_legacy_resolve_every_family(arch):
    cfg = configs.get_smoke(arch, PTQ16)
    api = build_model(cfg)
    shapes = jax.eval_shape(lambda: api.init(KEY))
    pol = api.ctx.policy
    plan = pol.compile(shapes)
    assert plan.site_paths, arch
    for path, prec in plan.sites():
        assert prec == pol.resolve(path), path
        assert plan.resolve(path) == pol.resolve(path), path
    # off-table paths fall back to the regex rules (exact legacy semantics)
    for path in ("never/compiled/site", "blocks/99/made_up", "frontend/x"):
        assert plan.resolve(path) == pol.resolve(path)


def test_plan_paper_override_paths():
    params = {
        "embed": {"w": jnp.zeros((32, 16))},
        "blocks": {"attn": {"wq": {"w": jnp.zeros((32, 16))}}},
        "lm_head": {"w": jnp.zeros((32, 16))},
        "router": {"w": jnp.zeros((32, 16))},
    }
    pol = PrecisionPolicy.ternary(group_size=16)
    plan = compile_policy(pol, params)
    assert plan.resolve("embed").w_bits == 8  # C1 analogue
    assert plan.resolve("lm_head").w_bits == 8  # FC analogue
    assert plan.resolve("router").w_bits == 8  # MoE control path
    assert plan.resolve("blocks/attn/wq").w_bits == 2  # default ternary
    assert plan.resolve("blocks/ln/norm").w_bits == FULL_PRECISION  # fallback


def test_plan_first_match_wins_ordering():
    a = LayerPrecision(w_bits=8)
    b = LayerPrecision(w_bits=4)
    pol = PrecisionPolicy(
        default=LayerPrecision(w_bits=2),
        overrides=((r"blocks/x", a), (r"blocks", b)),
    )
    params = {"blocks": {"x": {"w": jnp.zeros((16, 4))},
                         "y": {"w": jnp.zeros((16, 4))}}}
    plan = compile_policy(pol, params)
    assert plan.resolve("blocks/x").w_bits == 8  # first pattern wins
    assert plan.resolve("blocks/y").w_bits == 4  # second catches the rest
    assert [p for p, _ in plan.sites()] == sorted(p for p, _ in plan.sites())


def test_iter_weight_sites_shapes_and_stacked_axes():
    params = {
        "a": {"w": jnp.zeros((5, 32, 16)), "b": jnp.zeros((16,))},  # stacked
        "n": {"scale": jnp.zeros((8,))},
        "c": {"w": jnp.zeros((7,))},  # 1-D 'w' is not a projection site
    }
    sites = dict(iter_weight_sites(params))
    assert set(sites) == {"a"}


def test_plan_compiles_under_eval_shape():
    cfg = configs.get_smoke("qwen3-8b", PTQ16)
    api = build_model(cfg)
    shapes = jax.eval_shape(lambda: api.init(KEY))
    plan_abs = api.ctx.policy.compile(shapes)
    params = api.init(KEY)
    plan_real = api.ctx.policy.compile(params)
    assert plan_abs.site_paths == plan_real.site_paths
    assert plan_abs.site_precisions == plan_real.site_precisions


# ---------------------------------------------------------------------------
# Plan serialization + pytree registration.
# ---------------------------------------------------------------------------
def _example_plan() -> QuantPlan:
    cfg = configs.get_smoke("qwen3-8b", PTQ16)
    api = build_model(cfg)
    shapes = jax.eval_shape(lambda: api.init(KEY))
    plan = api.ctx.policy.compile(shapes, mode="ptq", backend="xla_int8")
    return plan.with_act_exponents({"blocks/attn/wq": -3, "lm_head": 1})


def test_plan_json_roundtrip():
    plan = _example_plan()
    back = QuantPlan.from_json(plan.to_json())
    assert back == plan
    assert back.resolve("blocks/attn/wq") == plan.resolve("blocks/attn/wq")
    assert back.act_exponent("blocks/attn/wq") == -3
    assert back.act_exponent("blocks/mlp/up") is None
    assert back.policy == plan.policy


def test_plan_pytree_roundtrip():
    plan = _example_plan()
    leaves, treedef = jax.tree.flatten(plan)
    assert leaves == []  # all-static: free to close over in jit
    back = jax.tree.unflatten(treedef, leaves)
    assert back == plan
    # and it survives a jit closure without retracing hazards
    @jax.jit
    def f(x):
        prec = plan.resolve("blocks/attn/wq")
        return x * prec.w_bits

    assert float(f(jnp.float32(2.0))) == 4.0


def test_plan_static_act_opt_out():
    plan = _example_plan()
    assert plan.act_exponent("blocks/attn/wq") == -3
    # pin one site to dynamic per-row exponents
    precs = tuple(
        dataclasses.replace(p, static_act=False) if path == "blocks/attn/wq" else p
        for path, p in plan.sites()
    )
    pinned = dataclasses.replace(plan, site_precisions=precs)
    assert pinned.act_exponent("blocks/attn/wq") is None
    assert pinned.act_exponent("lm_head") == 1


# ---------------------------------------------------------------------------
# Calibration-aware PTQ (the paper's profiled static-DFP activation mode).
# ---------------------------------------------------------------------------
def test_observer_collects_sites_and_exponents():
    obs = Observer()
    obs.record("s", 3.0, 1.0)
    obs.record("s", 1.0, 2.0)
    assert obs["s"]["max_abs"] == 3.0 and obs["s"]["count"] == 2.0
    e = obs.exponents()["s"]
    assert 3.0 <= 127 * 2.0 ** e  # static exponent covers the seen range


def test_quantize_model_calibrates_and_serializes():
    cfg = configs.get_smoke("qwen3-8b", PTQ16)
    api = build_model(cfg)
    params = api.init(KEY)
    batches = [make_smoke_batch(jax.random.PRNGKey(i), cfg, 2, 16) for i in (1, 2)]
    qparams, plan = quantize_model(
        params, api.ctx.policy, backend="xla",
        calib_batches=batches,
        forward=lambda p, b, ctx: api.with_ctx(ctx).forward(p, b),
    )
    assert plan.calibrated
    # every compiled projection site was observed by the calibration pass
    assert set(plan.site_paths) <= {p for p, _ in plan.act_exponents}
    # the plan (with exponents) survives serialization
    assert QuantPlan.from_json(plan.to_json()) == plan
    # and quantize_model without calibration leaves exponents empty
    _, plan2 = quantize_model(params, api.ctx.policy)
    assert not plan2.calibrated


def test_static_exponents_match_dynamic_within_dfp_tolerance():
    """PTQ with calibrated static per-site exponents vs dynamic per-row:
    same integer pipeline, agreement within DFP rounding on a zoo model."""
    cfg = configs.get_smoke("qwen3-8b", QuantConfig(
        w_bits=8, group_size=16, mode="ptq", backend="xla"))
    api = build_model(cfg)
    params = api.init(KEY)
    batch = make_smoke_batch(jax.random.PRNGKey(3), cfg, 2, 16)
    qparams, plan, api_static = quantize_and_plan(
        api, params, calib_batches=[batch]
    )
    assert plan.calibrated
    api_dynamic = api.with_plan(plan.with_act_exponents({}))

    out_s = np.asarray(api_static.forward(qparams, batch), np.float32)
    out_d = np.asarray(api_dynamic.forward(qparams, batch), np.float32)
    scale = np.abs(out_d).max() + 1e-9
    # a per-tensor static exponent is coarser than per-row dynamic ones, so
    # agreement is to DFP rounding at the site scale, not bit-exact
    assert np.abs(out_s - out_d).max() / scale < 0.10
    # both agree with the fp forward to PTQ accuracy (sanity)
    out_fp = np.asarray(api.forward(params, batch), np.float32)
    assert np.abs(out_s - out_fp).max() / (np.abs(out_fp).max() + 1e-9) < 0.5


def test_qmatmul_static_exponent_covers_range_exactly():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    qt = quantize_weights(jnp.asarray(rng.normal(size=(64, 16)), jnp.float32), 8, 16)
    # a static exponent at least as large as every row's dynamic exponent
    _, xe = ref.quantize_rows_ref(x, 8)
    e_static = int(np.asarray(xe).max())
    got = qmatmul(x, qt, backend="ref", act_exponent=e_static)
    want = qmatmul(x, qt, backend="ref")
    scale = np.abs(np.asarray(want)).max() + 1e-9
    assert np.abs(np.asarray(got) - np.asarray(want)).max() / scale < 0.02


def test_ptq_serving_on_plan_quantized_params():
    """ServingEngine end-to-end on plan-quantized params (acceptance)."""
    from repro.serving import Request, ServingEngine

    cfg = configs.get_smoke("qwen3-8b", PTQ16)
    api = build_model(cfg)
    params = api.init(KEY)
    batch = make_smoke_batch(jax.random.PRNGKey(5), cfg, 2, 16)
    qparams, plan, api = quantize_and_plan(api, params, calib_batches=[batch])
    assert api.ctx.plan is plan
    eng = ServingEngine(api, qparams, n_slots=2, max_len=16)
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4))
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 4


def test_calibrated_exponents_use_per_site_act_bits():
    """Exponent finalization must use the act_bits each site is quantized
    with (LayerPrecision.act_bits), not one global width -- a 4-bit site's
    exponent from an 8-bit grid would saturate its mantissas 16x early."""
    from repro.core import dfp

    params = {"four": {"w": jnp.zeros((32, 8))}, "eight": {"w": jnp.zeros((32, 8))}}
    pol = PrecisionPolicy(
        default=LayerPrecision(w_bits=8, act_bits=8, group_size=16),
        overrides=((r"^four$", LayerPrecision(w_bits=8, act_bits=4, group_size=16)),),
    )

    def forward(p, batch, ctx):
        from repro.quant import observe_site

        for site in ("four", "eight"):
            observe_site(ctx.observer, site, batch)

    x = jnp.full((4, 32), 100.0)
    _, plan = quantize_model(params, pol, calib_batches=[x], forward=forward)
    e4, e8 = plan.act_exponent("four"), plan.act_exponent("eight")
    assert 100.0 <= dfp.qmax(4) * 2.0 ** e4
    assert 100.0 <= dfp.qmax(8) * 2.0 ** e8
    assert e4 > e8  # fewer mantissa bits -> coarser grid -> larger exponent


def test_quantize_model_requires_forward_for_calibration():
    cfg = configs.get_smoke("qwen3-8b", PTQ16)
    api = build_model(cfg)
    params = jax.eval_shape(lambda: api.init(KEY))
    with pytest.raises(ValueError):
        quantize_model(params, api.ctx.policy, calib_batches=[{}])
