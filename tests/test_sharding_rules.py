"""Sharding-rule unit tests on an abstract 16x16 mesh (no real devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.core import stats
from repro.parallel import sharding
from repro.roofline import analysis

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


class _Leaf:
    def __init__(self, shape):
        self.shape = shape
        self.ndim = len(shape)
        self.dtype = jnp.float32


def test_projection_rules_train():
    # N-sharded projection: K over data (FSDP), N over model (TP)
    assert sharding.param_spec("blocks/attn/wq/w", _Leaf((4096, 4096)), MESH, "train") == P("data", "model")
    # K-sharded pair member
    assert sharding.param_spec("blocks/attn/wo/w", _Leaf((4096, 4096)), MESH, "train") == P("model", "data")
    assert sharding.param_spec("blocks/mlp/down/w", _Leaf((12288, 4096)), MESH, "train") == P("model", "data")


def test_projection_rules_serve_replicates_data():
    assert sharding.param_spec("blocks/attn/wq/w", _Leaf((4096, 4096)), MESH, "serve") == P(None, "model")
    assert sharding.param_spec("blocks/mlp/down/w", _Leaf((12288, 4096)), MESH, "serve") == P("model", None)


def test_divisibility_fallback():
    # 100 not divisible by 16 -> replicated on that axis
    assert sharding.param_spec("blocks/attn/wq/w", _Leaf((100, 4096)), MESH, "train") == P(None, "model")
    assert sharding.param_spec("blocks/attn/wq/w", _Leaf((4096, 100)), MESH, "train") == P("data", None)


def test_expert_parallelism_when_divisible():
    # 128 experts over model=16 => EP; inner dims lose the model axis
    spec = sharding.param_spec("blocks/moe/experts/gate/w", _Leaf((35, 128, 7168, 4864)), MESH, "train")
    assert spec == P(None, "model", "data", None)
    # 8 experts cannot shard over 16 => TP within experts instead
    spec = sharding.param_spec("blocks/moe/experts/gate/w", _Leaf((64, 8, 6144, 32768)), MESH, "train")
    assert spec == P(None, None, "data", "model")


def test_embedding_and_scalars():
    assert sharding.param_spec("embed/table", _Leaf((131072, 6144)), MESH, "train") == P("model", "data")
    assert sharding.param_spec("embed/table", _Leaf((131072, 6144)), MESH, "serve") == P("model", None)
    assert sharding.param_spec("blocks/ln1/scale", _Leaf((6144,)), MESH, "train") == P(None)


def test_qtensor_fields_shard_like_dense():
    assert sharding.param_spec("blocks/attn/wq/packed", _Leaf((64, 256, 4096)), MESH, "serve") == P(None, None, "model")
    assert sharding.param_spec("blocks/attn/wq/scale_m", _Leaf((64, 64, 4096)), MESH, "serve") == P(None, None, "model")


# ---------------------------------------------------------------------------
# QTensor-aware specs: the decision runs on the logical shape, with packed
# and scale-table projections of K as extra divisibility constraints.
# ---------------------------------------------------------------------------
def _qt(k, n, bits=2, group=16, lead=()):
    """QTensor over ShapeDtypeStructs (no arrays needed for spec logic)."""
    from repro.core.quantizer import INT4_PER_WORD, TERNARY_PER_WORD, QTensor

    wpk = {2: TERNARY_PER_WORD, 4: INT4_PER_WORD, 8: 1}[bits]
    sds = lambda shape: jax.ShapeDtypeStruct(shape, jnp.int8)
    return QTensor(
        packed=sds(tuple(lead) + (k // wpk, n)),
        scale_m=sds(tuple(lead) + (k // group, n)),
        scale_e=sds(()),
        bits=bits, group_size=group, shape=(k, n),
    )


def test_qtensor_spec_dispatches_on_logical_shape():
    # logical K=4096 -> K/16 packed rows = 256, K/16 scale rows = 256: all
    # divisible by 16 -> the K-sharded member takes the model axis
    assert sharding.param_spec("blocks/attn/wo/w", _qt(4096, 4096), MESH, "serve") == P("model", None)
    assert sharding.param_spec("blocks/attn/wq/w", _qt(4096, 4096), MESH, "serve") == P(None, "model")


def test_qtensor_packed_dim_divisibility_fallback():
    # logical K=128 divides 16, but the int4 scale table has 128/16=8 rows
    # and the packed payload 128/8=16 rows: 8 % 16 != 0 -> the whole QTensor
    # falls back to replication on K (a dense 128-K leaf would too, but a
    # payload-shape check alone would wrongly shard scale_m here)
    assert sharding.param_spec(
        "blocks/mlp/down/w", _qt(128, 4096, bits=4, group=16), MESH, "serve"
    ) == P(None, None)
    # int8 (words_per_k=1) with the same logical K also falls back: the
    # scale table is the binding constraint
    assert sharding.param_spec(
        "blocks/mlp/down/w", _qt(128, 4096, bits=8, group=16), MESH, "serve"
    ) == P(None, None)


def test_qtensor_field_shardings_consistent():
    qt = _qt(4096, 4096)
    fs = sharding.qtensor_field_shardings("blocks/attn/wo/w", qt, MESH, "serve")
    assert fs.packed.spec == P("model", None)
    assert fs.scale_m.spec == P("model", None)  # scales follow the cluster axis
    assert fs.scale_e.spec == P()
    assert (fs.bits, fs.group_size, fs.shape) == (qt.bits, qt.group_size, qt.shape)


def test_qtensor_expert_stack_ep():
    # stacked experts (E=32, K, N): EP over model, inner dims drop the axis
    qt = _qt(7168, 4864, lead=(32,))
    spec = sharding.param_spec("blocks/moe/experts/gate/w", qt, MESH, "serve")
    assert spec == P("model", None, None)


def _qt_fmt(k, n, fmt, lead=()):
    """Abstract nf4/mx QTensor (the formats whose widths collide with
    built-ins): nf4 packs K/8 uint32 rows like int4; mx stores raw int8
    with a K/32 block-scale table."""
    from repro.core.quantizer import QTensor

    wpk = {"nf4": 8, "mx": 1}[fmt]
    group = 32 if fmt == "mx" else 16
    pdt = jnp.uint32 if fmt == "nf4" else jnp.int8
    sds = lambda shape, dt=jnp.int8: jax.ShapeDtypeStruct(shape, dt)
    return QTensor(
        packed=sds(tuple(lead) + (k // wpk, n), pdt),
        scale_m=sds(tuple(lead) + (k // group, n)),
        scale_e=sds(()),
        bits=4 if fmt == "nf4" else 8, group_size=group, shape=(k, n),
        fmt=fmt,
    )


def test_nf4_qtensor_rules():
    # nf4 halves K like int4 (K/8 packed words): K=4096 -> packed 512 and
    # scale 256 rows, all divisible by 16 -> K-sharded member takes model
    assert sharding.param_spec(
        "blocks/mlp/down/w", _qt_fmt(4096, 4096, "nf4"), MESH, "serve"
    ) == P("model", None)
    # K=128: scale rows 128/16=8 don't divide the 16-wide axis -> the whole
    # QTensor (payload included) falls back together
    assert sharding.param_spec(
        "blocks/mlp/down/w", _qt_fmt(128, 4096, "nf4"), MESH, "serve"
    ) == P(None, None)
    fs = sharding.qtensor_field_shardings(
        "blocks/attn/wq/w", _qt_fmt(4096, 4096, "nf4"), MESH, "serve"
    )
    assert fs.packed.spec == P(None, "model")
    assert fs.scale_m.spec == P(None, "model")
    assert (fs.bits, fs.group_size, fs.fmt) == (4, 16, "nf4")


def test_mx_qtensor_rules():
    # mx scale tables follow their 32-block cluster axis: K=4096 -> 128
    # scale rows, divisible -> K shards; payload (raw int8, words_per_k=1)
    # inherits the same spec
    qt = _qt_fmt(4096, 4096, "mx")
    assert sharding.param_spec("blocks/mlp/down/w", qt, MESH, "serve") == P("model", None)
    fs = sharding.qtensor_field_shardings("blocks/mlp/down/w", qt, MESH, "serve")
    assert fs.packed.spec == P("model", None)
    assert fs.scale_m.spec == P("model", None)  # block axis, not payload K
    assert fs.scale_e.spec == P()
    # K=256: logical and packed K divide 16 but the 256/32=8 scale rows do
    # not -> the 32-block table is the binding constraint, all fields fall
    # back together
    assert sharding.param_spec(
        "blocks/mlp/down/w", _qt_fmt(256, 4096, "mx"), MESH, "serve"
    ) == P(None, None)


def test_block_format_expert_stacks_ep():
    for fmt in ("nf4", "mx"):
        qt = _qt_fmt(7168, 4864, fmt, lead=(32,))
        spec = sharding.param_spec(
            "blocks/moe/experts/gate/w", qt, MESH, "serve"
        )
        assert spec == P("model", None, None), fmt


def test_qtensor_shardings_tree():
    from repro.core.quantizer import QTensor

    tree = {
        "blocks": {"attn": {"wq": {"w": _qt(4096, 4096)}}},
        "ln": {"scale": _Leaf((4096,))},
    }
    sh = sharding.qtensor_shardings(tree, MESH)
    wq = sh["blocks"]["attn"]["wq"]["w"]
    assert isinstance(wq, QTensor)  # QTensor-of-shardings, treedef-compatible
    assert wq.packed.spec == P(None, "model")
    assert sh["ln"]["scale"].spec == P(None)


def test_ep_divisible():
    from repro.quant import ep_divisible

    assert ep_divisible(4, 8, MESH3, "model", ()) is False  # 4 % 16 != 0
    assert ep_divisible(32, 32, MESH, "model", ()) is True
    assert ep_divisible(32, 32, MESH, "model", ("data",)) is False  # C % 512
    assert ep_divisible(32, 32, None) is False


# ---------------------------------------------------------------------------
# Block-format QTensors on a REAL forced 4-device mesh (subprocess: the host
# device count must be set before jax initializes, as in test_dryrun.py).
# ---------------------------------------------------------------------------
_FORCED_MESH_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import parse_mesh_spec
from repro.parallel import sharding
from repro.quant import dequantize_weights, quantize_weights

assert jax.device_count() == 4, jax.device_count()
mesh = parse_mesh_spec("dp=2,tp=2")  # data=2 x model=2

rng = np.random.default_rng(0)
w = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
for fmt in ("nf4", "mx"):
    qt = quantize_weights(w, group_size=32, fmt=fmt)
    tree = {"blocks": {"mlp": {"down": {"w": qt}}}}
    sh = sharding.qtensor_shardings(tree, mesh)
    fs = sh["blocks"]["mlp"]["down"]["w"]
    assert fs.packed.spec == P("model", None), (fmt, fs.packed.spec)
    assert fs.scale_m.spec == P("model", None), (fmt, fs.scale_m.spec)
    on_mesh = jax.device_put(tree, sh)
    qts = on_mesh["blocks"]["mlp"]["down"]["w"]
    # each device holds half the packed K rows and half the scale rows
    wpk = {"nf4": 8, "mx": 1}[fmt]
    for shard in qts.packed.addressable_shards:
        assert shard.data.shape == (128 // wpk // 2, 64), (fmt, shard.data.shape)
    for shard in qts.scale_m.addressable_shards:
        assert shard.data.shape == (128 // qt.group_size // 2, 64), fmt
    # the sharded tensor dequantizes bit-identically to the host original
    got = np.asarray(jax.jit(dequantize_weights)(qts))
    want = np.asarray(dequantize_weights(qt))
    assert np.array_equal(got, want), fmt
print("OK")
"""


@pytest.mark.slow  # fresh JAX subprocess (repo convention for forced-device cells)
def test_block_formats_on_forced_4_device_mesh():
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(repo, "src"),
        XLA_FLAGS="--xla_force_host_platform_device_count=4",
    )
    r = subprocess.run(
        [sys.executable, "-c", _FORCED_MESH_SCRIPT],
        capture_output=True, text=True, timeout=300, env=env, cwd=repo,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_paper_op_ratio_claims():
    """Sec. 3.3: ~85% multiplies replaced at N=4, ~98% at N=64."""
    approx4 = stats.paper_approximation(4)
    approx64 = stats.paper_approximation(64)
    assert 0.83 <= approx4 <= 0.90
    assert approx64 >= 0.98
    specs = stats.resnet101_specs()
    exact4 = stats.network_replaced_fraction(specs, 4)
    exact64 = stats.network_replaced_fraction(specs, 64)
    assert 0.80 <= exact4 <= 0.95
    assert exact64 >= 0.98


def test_gemm_ratio_and_weight_bytes():
    gemms = [stats.GemmSpec("qkv", 4096, 6144), stats.GemmSpec("attn", 4096, 4096, weight_quantized=False)]
    total, wq_frac, all_frac = stats.network_gemm_stats(gemms, 64)
    assert wq_frac == pytest.approx(1 - 1 / 64)
    assert all_frac < wq_frac
    b2 = stats.weight_bytes(gemms, 2, 64)
    b16 = 4096 * 6144 * 2
    assert b2 < b16 / 6  # >6x HBM compression vs bf16 incl. scale overhead


def test_collective_parse():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(%y), to_apply=%sum
  %t = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%a, %b)
  %rs = bf16[4,4]{1,0} reduce-scatter(%z), dimensions={0}
  %cp = u32[10]{0} collective-permute(%w)
  %not_a_collective = f32[999]{0} add(%p, %q)
"""
    got = analysis.collective_bytes(hlo)
    assert got["all-gather"] == 16 * 1024 * 2
    assert got["all-reduce"] == 256 * 4
    assert got["all-to-all"] == 2 * 64 * 4
    assert got["reduce-scatter"] == 16 * 2
    assert got["collective-permute"] == 40
