"""Paper Sec. 4 end-to-end: recover large-N ternary accuracy by fine-tuning
from the pre-initialized full-precision model (ternary STE forward, fp32
master weights, lr ~1e-4), with checkpoint/restart along the way.

  PYTHONPATH=src python examples/finetune_lowprecision.py [--steps 120]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
import dataclasses
import tempfile

from benchmarks.common import eval_loss_and_top1, tiny_lm, train_fp_baseline
from repro.configs.base import QuantConfig
from repro.models import build_model, quantize_and_plan
from repro.training import OptConfig, TrainConfig, Trainer
from repro.training.data import make_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--group", type=int, default=64)
    args = ap.parse_args()

    print("[1/3] pre-training the full-precision model...")
    cfg, api, params, dcfg, _ = train_fp_baseline(steps=150)
    fp_loss, fp_top1 = eval_loss_and_top1(api, params, cfg, dcfg)
    print(f"      fp: loss {fp_loss:.3f}, top1 {fp_top1:.3f}")

    qc = QuantConfig(w_bits=2, group_size=args.group, mode="ptq", backend="xla")
    qcfg = dataclasses.replace(tiny_lm(), quant=qc)
    ptq, _plan, qapi = quantize_and_plan(build_model(qcfg), params)
    ptq_loss, ptq_top1 = eval_loss_and_top1(qapi, ptq, qcfg, dcfg)
    print(f"      PTQ 2w N={args.group}: loss {ptq_loss:.3f}, top1 {ptq_top1:.3f} "
          f"(the large-N drop the paper says needs retraining)")

    print(f"[2/3] Sec.-4 fine-tune for {args.steps} steps (ternary STE fwd, "
          f"fp32 master, lr=1e-4)...")
    qat_cfg = dataclasses.replace(
        tiny_lm(), quant=QuantConfig(w_bits=2, group_size=args.group, mode="qat")
    )
    # compile the QAT policy against the param tree once: the trainer's STE
    # forward resolves per-site precision through the static plan table
    qat_api = build_model(qat_cfg).compiled(params)
    with tempfile.TemporaryDirectory() as ckdir:
        tcfg = TrainConfig(
            opt=OptConfig(lr=1e-4, warmup_steps=0, weight_decay=0.0,
                          decay_steps=args.steps),
            ckpt_dir=ckdir, ckpt_every=40,
        )
        tr = Trainer(qat_api.train_loss, params, tcfg)
        hist = tr.train(lambda i: make_batch(cfg, dcfg, 500 + i), args.steps)
        print(f"      qat loss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
              f"(checkpoints under {ckdir})")

        print("[3/3] re-quantize the fine-tuned master weights and evaluate...")
        ftq, _plan, ftq_api = quantize_and_plan(qapi, tr.params)
        qat_loss, qat_top1 = eval_loss_and_top1(ftq_api, ftq, qcfg, dcfg)
    print(f"      after fine-tune: loss {qat_loss:.3f}, top1 {qat_top1:.3f}")
    print(f"      recovery: {ptq_loss - qat_loss:+.3f} loss "
          f"({ptq_top1:.3f} -> {qat_top1:.3f} top1; paper recovered to "
          f"within ~6% of fp on ResNet-50 in 4 epochs)")


if __name__ == "__main__":
    main()
