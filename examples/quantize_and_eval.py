"""PTQ sweep driver: quantize a trained model at every (bits x cluster-size)
point and print the accuracy/compression frontier (paper Figs. 1 + Sec. 3.3).

  PYTHONPATH=src python examples/quantize_and_eval.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks.common import eval_loss_and_top1, tiny_lm, train_fp_baseline
from repro.configs.base import QuantConfig
from repro.models import build_model, quantize_and_plan


def main():
    print("training fp baseline...")
    cfg, api, params, dcfg, _ = train_fp_baseline(steps=150)
    fp_loss, fp_top1 = eval_loss_and_top1(api, params, cfg, dcfg)
    fp_bytes = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params))
    print(f"{'config':>16s} {'loss':>8s} {'top1':>7s} {'Δtop1':>7s} {'MB':>7s} {'x':>5s}")
    print(f"{'fp32':>16s} {fp_loss:8.3f} {fp_top1:7.3f} {0.0:+7.3f} "
          f"{fp_bytes / 1e6:7.2f} {1.0:5.1f}")
    for bits in (8, 4, 2):
        for n in (4, 16, 64):
            qc = QuantConfig(w_bits=bits, group_size=n, mode="ptq", backend="xla")
            qcfg = dataclasses.replace(tiny_lm(), quant=qc)
            qp, _plan, qapi = quantize_and_plan(build_model(qcfg), params)
            loss, top1 = eval_loss_and_top1(qapi, qp, qcfg, dcfg)
            qb = sum(np.asarray(l).nbytes for l in jax.tree.leaves(qp))
            print(f"{f'8a-{bits}w N={n}':>16s} {loss:8.3f} {top1:7.3f} "
                  f"{top1 - fp_top1:+7.3f} {qb / 1e6:7.2f} {fp_bytes / qb:5.1f}")


if __name__ == "__main__":
    main()
