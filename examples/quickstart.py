"""Quickstart: the paper's cluster-based ternarization in five minutes.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stats
from repro.quant import (
    dequantize_weights,
    format_names,
    qmatmul,
    quantize_weights,
    weight_quantization_error,
)


def main():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(1024, 512)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(8, 1024)).astype(np.float32))

    print("=== Algorithm 1: cluster-based ternarization (N=64) ===")
    print(f"registered formats: {', '.join(format_names())}")
    qt = quantize_weights(w, bits=2, group_size=64)
    print(f"packed weights : {qt.packed.shape} {qt.packed.dtype} "
          f"({np.asarray(qt.packed).nbytes} bytes vs {w.size * 2} bf16 bytes)")
    print(f"scale table    : {qt.scale_m.shape} int8 mantissas, "
          f"shared exponent 2^{int(qt.scale_e)}")
    rel = float(weight_quantization_error(w, 2, 64)) / float(jnp.sum(w * w))
    sparsity = float(jnp.mean(dequantize_weights(qt) == 0))
    print(f"rel recon error: {rel:.4f}   sparsity: {sparsity:.2%}")

    print("\n=== full integer matmul (int8 acts x ternary weights) ===")
    y_q = qmatmul(x, qt, backend="pallas", block_k=256)
    y_fp = x @ w
    cos = float(
        jnp.sum(y_q * y_fp)
        / (jnp.linalg.norm(y_q) * jnp.linalg.norm(y_fp))
    )
    print(f"output cosine vs fp32 matmul: {cos:.4f}")

    print("\n=== Sec. 3.3 arithmetic budget ===")
    for n in (4, 64):
        frac = stats.network_replaced_fraction(stats.resnet101_specs(), n)
        print(f"ResNet-101, N={n:3d}: {frac:.1%} of multiplies -> 8-bit accumulations"
              f"  (paper: {'~85%' if n == 4 else '~98%'})")

    print("\n=== 4-bit and 8-bit cluster DFP ===")
    for bits in (4, 8):
        rel = float(weight_quantization_error(w, bits, 64)) / float(jnp.sum(w * w))
        print(f"{bits}-bit rel recon error: {rel:.6f}")


if __name__ == "__main__":
    main()
