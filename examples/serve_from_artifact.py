"""Quantize once, serve many: the packed artifact as the unit of deployment.

Phase 1 (the expensive part, run once): train a small LM, PTQ it through the
unified ``repro.quant`` API with calibration, and persist the result as a
packed artifact -- QTensor payloads + 8-bit DFP scale tables + the compiled
``QuantPlan`` with profiled static activation exponents, every payload
sha256-checked.

Phase 2 (run on every serving node, every boot): cold-start straight from
the artifact.  No fp32 weights are materialized, no calibration re-runs --
the engine decodes from the packed 2-bit weights under the persisted plan,
and serves tokens bit-identical to the process that produced the artifact.

  PYTHONPATH=src python examples/serve_from_artifact.py [--bits 2] \
      [--artifact-dir DIR] [--boots 2]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
import dataclasses
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import tiny_lm, train_fp_baseline
from repro.configs.base import QuantConfig
from repro.models import build_model, quantize_and_plan, save_servable
from repro.serving import Request, SamplerConfig, ServingEngine
from repro.training import checkpoint
from repro.training.data import make_batch


def tree_mb(tree):
    return sum(np.asarray(l).nbytes for l in jax.tree.leaves(tree)) / 1e6


def quantize_once(artifact_dir: str, bits: int, train_steps: int) -> None:
    print(f"[quantize-once] training the fp baseline for {train_steps} steps...")
    cfg, api, params, dcfg, hist = train_fp_baseline(steps=train_steps)
    print(f"               final train loss {hist['loss'][-1]:.3f}")

    qc = QuantConfig(w_bits=bits, group_size=16, mode="ptq", backend="xla")
    qcfg = dataclasses.replace(tiny_lm(), quant=qc)
    calib = [make_batch(cfg, dcfg, 10_000 + i) for i in range(4)]
    qparams, plan, qapi = quantize_and_plan(
        build_model(qcfg), params, calib_batches=calib
    )
    out = save_servable(artifact_dir, qapi, qparams, plan)
    disk_mb = checkpoint.dir_bytes(artifact_dir) / 1e6
    print(f"[quantize-once] {tree_mb(params):.2f} MB fp32 -> {disk_mb:.2f} MB "
          f"on disk at {out} ({tree_mb(params) / disk_mb:.1f}x); "
          f"{len(plan.act_exponents)}/{len(plan.site_paths)} sites calibrated")


def serve_once(artifact_dir: str, boot: int, requests: int) -> list:
    t0 = time.time()
    eng = ServingEngine.from_artifact(
        artifact_dir, n_slots=4, max_len=96,
        sampler=SamplerConfig(temperature=0.0),
    )
    print(f"[serve #{boot}] cold-started from artifact in {time.time() - t0:.2f}s "
          f"(no fp32, no recalibration)")
    rng = np.random.default_rng(0)
    for i in range(requests):
        eng.submit(Request(
            uid=i, prompt=rng.integers(0, 512, 6).tolist(), max_new_tokens=12,
        ))
    t0 = time.time()
    done = eng.run()
    toks = sum(len(r.output) for r in done)
    print(f"[serve #{boot}] {len(done)} requests / {toks} tokens "
          f"in {time.time() - t0:.1f}s; req 0 -> {done[0].output}")
    return sorted((r.uid, tuple(r.output)) for r in done)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=2, choices=[2, 4, 8])
    ap.add_argument("--artifact-dir", default=None,
                    help="where to write the artifact (default: a temp dir)")
    ap.add_argument("--boots", type=int, default=2,
                    help="how many serving cold starts to simulate")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--train-steps", type=int, default=80)
    args = ap.parse_args()

    tmp = None
    artifact_dir = args.artifact_dir
    if artifact_dir is None:
        tmp = tempfile.TemporaryDirectory()
        artifact_dir = tmp.name
    try:
        quantize_once(artifact_dir, args.bits, args.train_steps)
        outputs = [
            serve_once(artifact_dir, b + 1, args.requests)
            for b in range(args.boots)
        ]
        assert all(o == outputs[0] for o in outputs[1:]), "boots disagreed!"
        if args.boots > 1:
            print(f"[done] {args.boots} cold starts served identical greedy "
                  f"tokens from one artifact")
    finally:
        if tmp is not None:
            tmp.cleanup()


if __name__ == "__main__":
    main()
