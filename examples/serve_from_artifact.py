"""Quantize once, serve many: the packed artifact as the unit of deployment.

Phase 1 (the expensive part, run once): train a small LM, PTQ it through the
unified ``repro.quant`` API with calibration, and persist the result as a
packed artifact -- QTensor payloads + 8-bit DFP scale tables + the compiled
``QuantPlan`` with profiled static activation exponents, every payload
sha256-checked.

Phase 2 (run on every serving node, every boot): cold-start the STAGED
engine straight from the artifact.  No fp32 weights are materialized, no
calibration re-runs -- prompts prefill in chunks through a dedicated graph,
finished prefixes are inserted into decode-cache slots, and the donated
decode tick streams tokens, bit-identical to the process that produced the
artifact.  Each boot reports per-request TTFT/TPOT/queue-wait percentiles
from ``engine.stats()["latency"]``, and the first boot cross-checks the
staged tokens against the lockstep oracle (see docs/SERVING.md).

  PYTHONPATH=src python examples/serve_from_artifact.py [--bits 2] \
      [--artifact-dir DIR] [--boots 2] [--prefill-chunk 16] \
      [--policy decode|prefill]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
import dataclasses
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import tiny_lm, train_fp_baseline
from repro.configs.base import QuantConfig
from repro.models import build_model, quantize_and_plan, save_servable
from repro.serving import (
    Request,
    SamplerConfig,
    SchedulerConfig,
    ServingEngine,
    StagedEngine,
)
from repro.training import checkpoint
from repro.training.data import make_batch


def tree_mb(tree):
    return sum(np.asarray(l).nbytes for l in jax.tree.leaves(tree)) / 1e6


def quantize_once(artifact_dir: str, bits: int, train_steps: int) -> None:
    print(f"[quantize-once] training the fp baseline for {train_steps} steps...")
    cfg, api, params, dcfg, hist = train_fp_baseline(steps=train_steps)
    print(f"               final train loss {hist['loss'][-1]:.3f}")

    qc = QuantConfig(w_bits=bits, group_size=16, mode="ptq", backend="xla")
    qcfg = dataclasses.replace(tiny_lm(), quant=qc)
    calib = [make_batch(cfg, dcfg, 10_000 + i) for i in range(4)]
    qparams, plan, qapi = quantize_and_plan(
        build_model(qcfg), params, calib_batches=calib
    )
    out = save_servable(artifact_dir, qapi, qparams, plan)
    disk_mb = checkpoint.dir_bytes(artifact_dir) / 1e6
    print(f"[quantize-once] {tree_mb(params):.2f} MB fp32 -> {disk_mb:.2f} MB "
          f"on disk at {out} ({tree_mb(params) / disk_mb:.1f}x); "
          f"{len(plan.act_exponents)}/{len(plan.site_paths)} sites calibrated")


def _workload(requests: int):
    """Mixed long/short prompts: the long ones exercise chunked prefill."""
    rng = np.random.default_rng(0)
    return [
        Request(
            uid=i,
            prompt=rng.integers(0, 512, 40 if i % 3 == 0 else 6).tolist(),
            max_new_tokens=12,
        )
        for i in range(requests)
    ]


def serve_once(artifact_dir: str, boot: int, requests: int, chunk: int,
               policy: str, engine: str = "staged") -> list:
    t0 = time.time()
    cls = StagedEngine if engine == "staged" else ServingEngine
    kw = {} if engine == "lockstep" else {
        "sched": SchedulerConfig(prefill_chunk=chunk, policy=policy)
    }
    eng = cls.from_artifact(
        artifact_dir, n_slots=4, max_len=96,
        sampler=SamplerConfig(temperature=0.0), **kw,
    )
    print(f"[serve #{boot}] {engine} engine cold-started from artifact in "
          f"{time.time() - t0:.2f}s (no fp32, no recalibration)")
    for req in _workload(requests):
        eng.submit(req)
    t0 = time.time()
    done = eng.run()
    toks = sum(len(r.output) for r in done)
    line = f"[serve #{boot}] {len(done)} requests / {toks} tokens " \
           f"in {time.time() - t0:.1f}s"
    s = eng.stats()
    if engine == "staged":
        c = s["counts"]
        line += (f"; {c['prefill_chunks']} prefill chunks + "
                 f"{c['inserts']} inserts + {c['generate_ticks']} decode ticks")
    req0 = next(r for r in done if r.uid == 0)
    print(f"{line}; req 0 -> {req0.output}")
    for name in ("queue_wait", "ttft", "tpot"):
        p = s["latency"][name]
        if p:
            print(f"[serve #{boot}]   {name:10s} p50={p['p50'] * 1e3:6.1f}ms "
                  f"p95={p['p95'] * 1e3:6.1f}ms p99={p['p99'] * 1e3:6.1f}ms")
    return sorted((r.uid, tuple(r.output)) for r in done)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=2, choices=[2, 4, 8])
    ap.add_argument("--artifact-dir", default=None,
                    help="where to write the artifact (default: a temp dir)")
    ap.add_argument("--boots", type=int, default=2,
                    help="how many serving cold starts to simulate")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--train-steps", type=int, default=80)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--policy", default="decode", choices=["decode", "prefill"])
    args = ap.parse_args()

    tmp = None
    artifact_dir = args.artifact_dir
    if artifact_dir is None:
        tmp = tempfile.TemporaryDirectory()
        artifact_dir = tmp.name
    try:
        quantize_once(artifact_dir, args.bits, args.train_steps)
        outputs = [
            serve_once(artifact_dir, b + 1, args.requests,
                       args.prefill_chunk, args.policy)
            for b in range(args.boots)
        ]
        assert all(o == outputs[0] for o in outputs[1:]), "boots disagreed!"
        oracle = serve_once(artifact_dir, 0, args.requests,
                            args.prefill_chunk, args.policy, engine="lockstep")
        assert oracle == outputs[0], "staged diverged from the lockstep oracle!"
        print(f"[done] {args.boots} staged cold start(s) served greedy tokens "
              f"identical to each other AND to the lockstep oracle")
    finally:
        if tmp is not None:
            tmp.cleanup()


if __name__ == "__main__":
    main()
