"""End-to-end serving driver (the paper is an inference paper): train a
small LM, PTQ it to the full sub-8-bit integer pipeline, and serve batched
requests through the continuous-batching engine.

  PYTHONPATH=src python examples/serve_quantized.py [--bits 2] [--group 64]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import tiny_lm, train_fp_baseline
from repro.configs.base import QuantConfig
from repro.models import build_model, quantize_and_plan
from repro.serving import Request, SamplerConfig, ServingEngine
from repro.training.data import make_batch


def tree_bytes(tree):
    return sum(np.asarray(l).nbytes for l in jax.tree.leaves(tree))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--group", type=int, default=64)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--train-steps", type=int, default=80)
    args = ap.parse_args()

    print(f"[1/4] training the fp baseline for {args.train_steps} steps...")
    cfg, api, params, dcfg, hist = train_fp_baseline(steps=args.train_steps)
    print(f"      final train loss {hist['loss'][-1]:.3f}")

    print(f"[2/4] PTQ: {args.bits}-bit weights, cluster N={args.group}, 8-bit acts "
          f"(static exponents profiled on 4 calibration batches)")
    qc = QuantConfig(w_bits=args.bits, group_size=min(args.group, 64),
                     mode="ptq", backend="xla")
    qcfg = dataclasses.replace(tiny_lm(), quant=qc)
    calib = [make_batch(cfg, dcfg, 10_000 + i) for i in range(4)]
    qparams, plan, qapi = quantize_and_plan(
        build_model(qcfg), params, calib_batches=calib
    )
    b_fp, b_q = tree_bytes(params), tree_bytes(qparams)
    print(f"      params: {b_fp / 1e6:.2f} MB fp32 -> {b_q / 1e6:.2f} MB packed "
          f"({b_fp / b_q:.1f}x); plan: {len(plan.site_paths)} sites, "
          f"{len(plan.act_exponents)} calibrated")

    print(f"[3/4] serving {args.requests} requests on {args.slots} slots "
          f"(continuous batching)...")
    eng = ServingEngine(
        qapi, qparams, n_slots=args.slots, max_len=96,
        sampler=SamplerConfig(temperature=0.7, top_k=40),
    )
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(3, 12))
        eng.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab, plen).tolist(),
            max_new_tokens=int(rng.integers(8, 24)),
        ))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"      {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s on 1 CPU core, interpret-free XLA path)")

    print("[4/4] sample outputs:")
    for r in done[:3]:
        print(f"      req {r.uid}: prompt={r.prompt[:6]}... -> {r.output}")


if __name__ == "__main__":
    main()
